"""Ambit Pallas kernels: bulk in-arena bitwise ops on TPU.

The TPU-native adaptation of Ambit (Seshadri et al., MICRO'17): bulk
AND/OR/NOT over whole arena pages, executed where the data lives instead
of streaming operands through the core.  Like the RowClone page kernels,
the page index lists are scalar-prefetched (the BlockSpec index_maps read
them — the TPU version of the POC consuming an instruction's row-address
operands) and the arena is aliased in/out so untouched pages never move.

Kernel family (all layer-batched, one launch per op batch):

* ``page_bitwise_batched`` — ``arena[:, dst[i]] <- op(arena[:, src[i]],
  arena[:, dst[i]])`` for op in {and, or}: the two-operand in-place
  semantics of the AMB_AND/AMB_OR instructions (dst <- src OP dst).
* ``page_not_batched``     — ``arena[:, dst[i]] <- ~arena[:, src[i]]``
  (the dual-contact-cell NOT).
* ``page_zero_scan``       — per-page nonzero reduction over all layers:
  the in-arena analogue of OR-reducing candidate rows into a B-group
  scratch row and testing the result.  Read-only; returns int32 flags.

All kernels operate on integer (bit-pattern) arenas; the ops wrappers
bitcast float arenas to a matching unsigned view first, so results are
bit-exact regardless of storage dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _page_bitwise_batched_kernel(src_idx_ref, dst_idx_ref, src_view_ref,
                                 dst_view_ref, out_ref, *, op: str):
    # Grid: (layers, n_ops, col_blocks).  The index_maps route the two
    # input views to arena[l, src[i]] / arena[l, dst[i]] and the output
    # block back onto arena[l, dst[i]]; the body is one VPU op.
    del src_idx_ref, dst_idx_ref
    if op == "and":
        out_ref[...] = src_view_ref[...] & dst_view_ref[...]
    else:
        out_ref[...] = src_view_ref[...] | dst_view_ref[...]


def page_bitwise_batched(arena: jax.Array, src_pages: jax.Array,
                         dst_pages: jax.Array, op: str, *,
                         block_cols: int = 4096,
                         interpret: bool = False) -> jax.Array:
    """``arena[:, dst[i]] <- op(arena[:, src[i]], arena[:, dst[i]])`` for
    all i across every layer in ONE launch.

    arena: (layers, num_pages, page_elems) integer dtype; src/dst_pages:
    (n,) int32.  The arena is passed as both operand views and aliased
    into the output, so only touched pages are rewritten.
    """
    if op not in ("and", "or"):
        raise ValueError(f"unknown ambit bitwise op {op!r}")
    layers, num_pages, page_elems = arena.shape
    n = src_pages.shape[0]
    bc = min(block_cols, page_elems)
    grid = (layers, n, pl.cdiv(page_elems, bc))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bc),
                         lambda l, i, j, src_idx, dst_idx: (l, src_idx[i], j)),
            pl.BlockSpec((1, 1, bc),
                         lambda l, i, j, src_idx, dst_idx: (l, dst_idx[i], j)),
        ],
        out_specs=pl.BlockSpec((1, 1, bc),
                               lambda l, i, j, src_idx, dst_idx: (l, dst_idx[i], j)),
    )
    return pl.pallas_call(
        functools.partial(_page_bitwise_batched_kernel, op=op),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={3: 0},  # dst view (after 2 prefetch args) -> out
        interpret=interpret,
    )(src_pages.astype(jnp.int32), dst_pages.astype(jnp.int32), arena, arena)


def _page_not_batched_kernel(src_idx_ref, dst_idx_ref, arena_ref, out_ref):
    del src_idx_ref, dst_idx_ref
    out_ref[...] = ~arena_ref[...]


def page_not_batched(arena: jax.Array, src_pages: jax.Array,
                     dst_pages: jax.Array, *, block_cols: int = 4096,
                     interpret: bool = False) -> jax.Array:
    """``arena[:, dst[i]] <- ~arena[:, src[i]]`` across all layers in one
    launch (the dual-contact-cell NOT on pages)."""
    layers, num_pages, page_elems = arena.shape
    n = src_pages.shape[0]
    bc = min(block_cols, page_elems)
    grid = (layers, n, pl.cdiv(page_elems, bc))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bc),
                         lambda l, i, j, src_idx, dst_idx: (l, src_idx[i], j)),
        ],
        out_specs=pl.BlockSpec((1, 1, bc),
                               lambda l, i, j, src_idx, dst_idx: (l, dst_idx[i], j)),
    )
    return pl.pallas_call(
        _page_not_batched_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(src_pages.astype(jnp.int32), dst_pages.astype(jnp.int32), arena)


def _page_zero_scan_kernel(page_idx_ref, arena_ref, out_ref):
    # Grid: (n_pages, layers, col_blocks) — the page index is OUTERMOST so
    # every revisit of a page's (1, 1) output flag is consecutive (the
    # standard Pallas accumulation pattern).
    del page_idx_ref
    l = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((l == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    nz = jnp.any(arena_ref[...] != 0).astype(jnp.int32)
    out_ref[0, 0] |= nz


def page_zero_scan(arena: jax.Array, pages: jax.Array, *,
                   block_cols: int = 4096,
                   interpret: bool = False) -> jax.Array:
    """Per-page nonzero flags: ``out[i] = any(arena[:, pages[i]] != 0)``.

    arena: (layers, num_pages, page_elems) integer dtype; pages: (n,)
    int32.  Returns (n, 1) int32 — 0 where the page is all-zero bits
    across every layer.  Read-only (no aliasing)."""
    layers, num_pages, page_elems = arena.shape
    n = pages.shape[0]
    bc = min(block_cols, page_elems)
    grid = (n, layers, pl.cdiv(page_elems, bc))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bc), lambda i, l, j, page_idx: (l, page_idx[i], j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, l, j, page_idx: (i, 0)),
    )
    return pl.pallas_call(
        _page_zero_scan_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(pages.astype(jnp.int32), arena)

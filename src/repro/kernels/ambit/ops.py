"""Jit'd public wrappers for the Ambit bitwise kernel family.

``use_pallas`` selects the Pallas kernel (TPU target; interpret-mode on
CPU) vs the pure-jnp reference, mirroring the RowClone wrappers.  Bitwise
ops are defined on *bit patterns*: float arenas are bitcast to a matching
unsigned view, operated on, and bitcast back, so both paths are bit-exact
for any storage dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ambit, ref

_ON_TPU = jax.default_backend() == "tpu"

_UINT_FOR_ITEMSIZE = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def _as_bits(arena: jax.Array):
    """Integer view of the arena plus the dtype to restore (or None)."""
    if jnp.issubdtype(arena.dtype, jnp.integer):
        return arena, None
    uint = _UINT_FOR_ITEMSIZE[arena.dtype.itemsize]
    return jax.lax.bitcast_convert_type(arena, uint), arena.dtype


@functools.partial(jax.jit, static_argnames=("op", "use_pallas", "interpret"),
                   donate_argnums=(0,))
def pim_page_bitwise_batched(arena: jax.Array, src_pages: jax.Array,
                             dst_pages: jax.Array, *, op: str,
                             use_pallas: bool = False,
                             interpret: bool = not _ON_TPU) -> jax.Array:
    """``arena[:, dst[i]] <- op(arena[:, src[i]], arena[:, dst[i]])``
    (op in {"and", "or"}) or ``<- ~arena[:, src[i]]`` (op == "not"),
    across all layers in one fused launch.  arena: (layers, pages, ...)."""
    if src_pages.shape[0] == 0:
        return arena
    bits, orig_dtype = _as_bits(arena)
    if not use_pallas:
        if op == "not":
            out = ref.page_not_batched(bits, src_pages, dst_pages)
        else:
            out = ref.page_bitwise_batched(bits, src_pages, dst_pages, op)
    else:
        L, P = bits.shape[:2]
        flat = bits.reshape(L, P, -1)
        if op == "not":
            out = ambit.page_not_batched(flat, src_pages, dst_pages,
                                         interpret=interpret)
        else:
            out = ambit.page_bitwise_batched(flat, src_pages, dst_pages, op,
                                             interpret=interpret)
        out = out.reshape(bits.shape)
    if orig_dtype is not None:
        out = jax.lax.bitcast_convert_type(out, orig_dtype)
    return out


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def pim_page_zero_scan(arena: jax.Array, pages: jax.Array, *,
                       use_pallas: bool = False,
                       interpret: bool = not _ON_TPU) -> jax.Array:
    """Per-page zero-compare: returns bool (n,), True where
    ``arena[:, pages[i]]`` is all-zero bits across every layer.

    Read-only (the arena is NOT donated) — this is the eviction/audit
    scan, not a mutation."""
    if pages.shape[0] == 0:
        return jnp.zeros((0,), jnp.bool_)
    bits, _ = _as_bits(arena)
    if not use_pallas:
        return ref.page_zero_scan(bits, pages)
    L, P = bits.shape[:2]
    flags = ambit.page_zero_scan(bits.reshape(L, P, -1), pages,
                                 interpret=interpret)
    return flags[:, 0] == 0

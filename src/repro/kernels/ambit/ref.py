"""Pure-jnp oracles for the Ambit bitwise kernels.

All functions expect an integer (bit-pattern) arena; the ops wrappers
bitcast float arenas before dispatching here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_OPS = {"and": jnp.bitwise_and, "or": jnp.bitwise_or}


def page_bitwise_batched(arena: jax.Array, src_pages: jax.Array,
                         dst_pages: jax.Array, op: str) -> jax.Array:
    """arena: (L, P, ...); dst <- src OP dst for each (src, dst) pair."""
    fn = _OPS[op]
    return arena.at[:, dst_pages].set(
        fn(arena[:, src_pages], arena[:, dst_pages]))


def page_not_batched(arena: jax.Array, src_pages: jax.Array,
                     dst_pages: jax.Array) -> jax.Array:
    return arena.at[:, dst_pages].set(~arena[:, src_pages])


def page_zero_scan(arena: jax.Array, pages: jax.Array) -> jax.Array:
    """Returns bool (n,): True where the page is all-zero bits across
    every layer."""
    sel = arena[:, pages]  # (L, n, ...)
    axes = (0,) + tuple(range(2, sel.ndim))
    return ~jnp.any(sel != 0, axis=axes)

"""D-RaNGe Pallas kernel: block-parallel true-random bit generation.

TPU adaptation of D-RaNGe (DESIGN.md SS2): the DRAM activation-failure
entropy source does not exist on TPU, so the *generator* is a
counter-based PRNG (Threefry2x32, 20 rounds) seeded from the D-RaNGe
entropy pool (the simulated-DRAM TRNG supplies seeds; on a PiM-equipped
deployment those seeds would be hardware-true-random).  What is preserved
from the paper is the *system shape*: a block generator that refills a
random-number buffer asynchronously, drained by `pimolib.pim_rand`.

The kernel computes one VMEM tile of uint32 randoms per grid step:
  counter = tile_base + iota  ->  threefry2x32(key, counter)  ->  out tile
It is embarrassingly parallel and write-bandwidth-bound, like the
hardware technique it models.

Threefry2x32 is implemented with 32-bit add/xor/rotate only, so the same
code runs on the TPU VPU and in interpret mode, and `ref.py` is the exact
same arithmetic in plain jnp — oracles match bit-for-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ROTATIONS = ((13, 15, 26, 6), (17, 29, 16, 24))
_PARITY = np.uint32(0x1BD11BDA)


def _rotl(x, r):
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def threefry2x32(k0, k1, x0, x1):
    """20-round Threefry2x32 on uint32 arrays (pure jnp; used by kernel
    body AND the reference oracle)."""
    ks = (k0, k1, k0 ^ k1 ^ _PARITY)
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for block in range(5):
        rots = _ROTATIONS[block % 2]
        for r in rots:
            x0 = x0 + x1
            x1 = _rotl(x1, r)
            x1 = x0 ^ x1
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + np.uint32(block + 1)
    return x0, x1


def _drange_kernel(seed_ref, out_ref, *, block_elems: int):
    tile = pl.program_id(0)
    base = (tile * block_elems).astype(jnp.uint32)
    # 2D iota (TPU requires >=2D); flattened counter per element.
    shape = out_ref.shape
    row = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    ctr = base + row * np.uint32(shape[1]) + col
    k0 = seed_ref[0]
    k1 = seed_ref[1]
    x0, _ = threefry2x32(k0, k1, ctr, ctr ^ np.uint32(0x9E3779B9))
    out_ref[...] = x0


def random_u32(seed: jax.Array, n_rows: int, n_cols: int,
               *, block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """Generate (n_rows, n_cols) uint32 randoms from a (2,) uint32 seed."""
    br = min(block_rows, n_rows)
    grid = (pl.cdiv(n_rows, br),)
    import functools
    kernel = functools.partial(_drange_kernel, block_elems=br * n_cols)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((br, n_cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, n_cols), jnp.uint32),
        interpret=interpret,
    )(seed.astype(jnp.uint32))

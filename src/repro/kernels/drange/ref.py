"""Reference oracle for the D-RaNGe kernel: identical Threefry2x32
arithmetic in plain jnp (bit-exact vs the kernel)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .drange import threefry2x32


def random_u32(seed: jax.Array, n_rows: int, n_cols: int) -> jax.Array:
    import numpy as np
    seed = seed.astype(jnp.uint32)
    ctr = jnp.arange(n_rows * n_cols, dtype=jnp.uint32).reshape(n_rows, n_cols)
    x0, _ = threefry2x32(seed[0], seed[1], ctr, ctr ^ np.uint32(0x9E3779B9))
    return x0

"""Jit'd wrappers for the D-RaNGe generator kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import drange, ref

_ON_TPU = jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("n_rows", "n_cols", "use_pallas", "interpret"))
def pim_random_u32(seed: jax.Array, n_rows: int, n_cols: int,
                   *, use_pallas: bool = False, interpret: bool = not _ON_TPU) -> jax.Array:
    if use_pallas:
        return drange.random_u32(seed, n_rows, n_cols, interpret=interpret)
    return ref.random_u32(seed, n_rows, n_cols)


@functools.partial(jax.jit, static_argnames=("n_rows", "n_cols", "use_pallas", "interpret"))
def pim_random_uniform(seed: jax.Array, n_rows: int, n_cols: int,
                       *, use_pallas: bool = False, interpret: bool = not _ON_TPU) -> jax.Array:
    """Uniform floats in [0, 1) from the top 24 bits."""
    u = pim_random_u32(seed, n_rows, n_cols, use_pallas=use_pallas, interpret=interpret)
    return (u >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))


def entropy_seed_from_trng(trng, stream: int = 0) -> jax.Array:
    """Fold 64 true-random bits from the (simulated-DRAM) D-RaNGe TRNG
    into a kernel seed — the bridge between the paper-faithful entropy
    source and the TPU block generator."""
    words = trng.random_u32(2)
    return jnp.asarray([words[0] ^ jnp.uint32(stream), words[1]], dtype=jnp.uint32)

"""SSM state-arena kernels: the RowClone-style mutation family for
paged recurrent state (constant-size per sequence, unlike KV pages).

Triple layout mirrors ``kernels/rowclone``: ``ssm_scan.py`` holds the
Pallas kernels, ``ref.py`` the pure-jnp references, ``ops.py`` the jit'd
public wrappers the serving cache and op registry dispatch through.
"""

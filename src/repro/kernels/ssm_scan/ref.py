"""Pure-jnp references for the SSM state-arena ops.

A state arena is ``(groups, sublayers, slots, elems)`` after the ops
layer flattens trailing dims — ``groups * sublayers`` is the "layer"
axis a launch streams over, ``slots`` the per-sequence state rows.  The
references here work on the flattened 3D ``(L, R, E)`` form.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def state_scatter(arena: jax.Array, rows: jax.Array,
                  new: jax.Array) -> jax.Array:
    """``arena[:, rows[b]] <- new[:, b]``.  arena: (L, R, E); rows: (B,);
    new: (L, B, E).  Duplicate rows carry identical payloads by the
    caller's contract (padded batches duplicate row 0), so scatter order
    does not matter."""
    return arena.at[:, rows].set(new.astype(arena.dtype))


def state_gather(arena: jax.Array, rows: jax.Array) -> jax.Array:
    """``arena[:, rows[b]]`` -> (L, B, E) — the scatter's inverse."""
    return arena[:, rows]


def row_copy(arena: jax.Array, src_rows: jax.Array,
             dst_rows: jax.Array) -> jax.Array:
    """Copy ``arena[:, src_rows[i]] -> arena[:, dst_rows[i]]`` — the
    copy-on-fork primitive.  All sources read pre-update state
    (destination rows are freshly allocated, so no chaining)."""
    return arena.at[:, dst_rows].set(arena[:, src_rows])


def row_init(arena: jax.Array, dst_rows: jax.Array, value) -> jax.Array:
    """Memset ``arena[:, dst_rows[i]] <- value`` — init-on-free."""
    shape = (arena.shape[0], dst_rows.shape[0]) + arena.shape[2:]
    return arena.at[:, dst_rows].set(jnp.full(shape, value, arena.dtype))

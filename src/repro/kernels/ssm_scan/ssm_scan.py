"""Pallas kernels for the SSM state arena.

The state arena is the recurrent-state analogue of the KV arena: one
constant-size row per live sequence, mutated in bulk once per decode
round.  The scatter below is the ``SSM_STATE_WRITE`` launch target —
``(layers, batch)`` grid, row coordinates scalar-prefetched so the
output BlockSpec lands each block exactly on its row (no
read-modify-write), arena aliased in/out so untouched rows never move.
"layers" here is ``groups * mamba_sublayers``: the whole depth of the
model writes in ONE launch, the same amortization argument as
``rowclone.kv_scatter``.

Row copy (copy-on-fork) and row init (init-on-free) do not get their own
kernels: a state row IS a page of a ``(L, R, E)`` arena, so they ride the
existing RowClone ``page_copy_batched`` / ``page_init_batched`` kernels
(see ``ops.py``) — which is the point: fork and free traffic is RowClone
traffic, priced as such on the model-face replay.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _state_scatter_kernel(row_idx_ref, new_ref, arena_ref, out_ref):
    # Grid: (layers, batch).  The output BlockSpec lands this (1,1,E)
    # block exactly on arena[l, rows[b]]; the body is a pure row write.
    del row_idx_ref, arena_ref
    out_ref[...] = new_ref[...].reshape(out_ref.shape)


def state_scatter(arena: jax.Array, rows: jax.Array, new: jax.Array,
                  *, interpret: bool = False) -> jax.Array:
    """Scatter fresh state rows: ``arena[l, rows[b]] <- new[l, b]``.

    arena: (layers, num_rows, elems); rows: (batch,) int32; new:
    (layers, batch, elems).  One launch covers every sublayer's state
    for every sequence in the round.  Duplicate rows are only valid with
    identical payloads (last grid iteration wins).
    """
    layers, num_rows, elems = arena.shape
    batch = rows.shape[0]
    grid = (layers, batch)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, elems), lambda l, b, rw: (l, b, 0)),  # new
            pl.BlockSpec(memory_space=pl.ANY),     # arena (aliased, unread)
        ],
        out_specs=pl.BlockSpec((1, 1, elems),
                               lambda l, b, rw: (l, rw[b], 0)),
    )
    return pl.pallas_call(
        _state_scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={2: 0},  # arena (after 1 prefetch + new) -> out
        interpret=interpret,
    )(rows.astype(jnp.int32), new.astype(arena.dtype), arena)

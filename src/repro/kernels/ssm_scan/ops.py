"""Jit'd public wrappers for the SSM state-arena op family.

State arenas are ``(groups, sublayers, slots, ...)`` — conv windows and
SSD states keep their natural trailing dims; the wrappers flatten to the
kernels' ``(L, R, E)`` form and restore on return.  As with RowClone,
``use_pallas`` selects the Pallas kernel (TPU target; interpret-mode on
CPU) vs the pure-jnp reference, and an empty op batch is a no-op (no
launch; the scheduler never dispatches for it).

Row copy/init reuse the RowClone ``page_copy_batched`` /
``page_init_batched`` kernels — a state row is just a page of the
flattened arena, so copy-on-fork and init-on-free are literally RowClone
traffic (and the trace prices them as such).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rowclone import rowclone as rc_kernels

from . import ref, ssm_scan

_ON_TPU = jax.default_backend() == "tpu"


def _flat3(a: jax.Array) -> jax.Array:
    """(groups, sublayers, slots, ...) -> (groups*sublayers, slots, E)."""
    G, M, R = a.shape[:3]
    return a.reshape(G * M, R, -1)


def state_scatter_inline(arena: jax.Array, rows: jax.Array,
                         new: jax.Array, *, use_pallas: bool = False,
                         interpret: bool = not _ON_TPU) -> jax.Array:
    """Write ``arena[:, :, rows[b]] <- new[:, :, b]`` in one launch.

    arena: (groups, sublayers, slots, ...); new: (groups, sublayers,
    batch, ...).  Un-jitted body so the engine's fused steps can trace
    it without a nested donation; ``pim_state_scatter`` is the
    jitted/donating wrapper the ``ssm_state_write`` flush executor uses.
    """
    if rows.shape[0] == 0:
        return arena
    a3 = _flat3(arena)
    n3 = new.reshape(a3.shape[0], rows.shape[0], -1)
    if not use_pallas:
        out = ref.state_scatter(a3, rows, n3)
    else:
        out = ssm_scan.state_scatter(a3, rows, n3.astype(arena.dtype),
                                     interpret=interpret)
    return out.reshape(arena.shape)


pim_state_scatter = functools.partial(
    jax.jit, static_argnames=("use_pallas", "interpret"),
    donate_argnums=(0,))(state_scatter_inline)


def state_gather_inline(arena: jax.Array, rows: jax.Array) -> jax.Array:
    """Read ``arena[:, :, rows[b]]`` -> (groups, sublayers, batch, ...).
    Reads have no Pallas variant (XLA fuses the gather into the
    surrounding step); only mutations are RowClone hot spots."""
    return arena[:, :, rows]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"),
                   donate_argnums=(0,))
def pim_state_copy(arena: jax.Array, src_rows: jax.Array,
                   dst_rows: jax.Array, *, use_pallas: bool = False,
                   interpret: bool = not _ON_TPU) -> jax.Array:
    """Copy-on-fork: ``arena[:, :, src_rows[i]] -> arena[:, :, dst_rows[i]]``
    across every sublayer in one RowClone launch."""
    if src_rows.shape[0] == 0:
        return arena
    a3 = _flat3(arena)
    if not use_pallas:
        out = ref.row_copy(a3, src_rows, dst_rows)
    else:
        out = rc_kernels.page_copy_batched(a3, src_rows, dst_rows,
                                           interpret=interpret)
    return out.reshape(arena.shape)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"),
                   donate_argnums=(0,))
def pim_state_init(arena: jax.Array, dst_rows: jax.Array, value,
                   *, use_pallas: bool = False,
                   interpret: bool = not _ON_TPU) -> jax.Array:
    """Init-on-free: memset ``arena[:, :, dst_rows[i]] <- value`` in one
    RowClone-Init launch (no cross-sequence state leakage)."""
    if dst_rows.shape[0] == 0:
        return arena
    a3 = _flat3(arena)
    if not use_pallas:
        out = ref.row_init(a3, dst_rows, value)
    else:
        out = rc_kernels.page_init_batched(a3, dst_rows, value,
                                           interpret=interpret)
    return out.reshape(arena.shape)

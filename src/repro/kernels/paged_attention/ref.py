"""Pure-jnp oracle for paged decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_attention(q: jax.Array, k_arena: jax.Array, v_arena: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    sm_scale: float | None = None) -> jax.Array:
    bsz, h, d = q.shape
    pages, page_size, kvh, _ = k_arena.shape
    groups = h // kvh
    if sm_scale is None:
        sm_scale = d ** -0.5
    max_pages = block_tables.shape[1]
    max_len = max_pages * page_size

    # Gather each sequence's logical KV from its pages.
    k = k_arena[block_tables]                    # (B, P, page, KVH, D)
    v = v_arena[block_tables]
    k = k.reshape(bsz, max_len, kvh, d)
    v = v.reshape(bsz, max_len, kvh, d)

    qg = q.reshape(bsz, kvh, groups, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * sm_scale
    pos = jnp.arange(max_len)[None, None, None, :]
    s = jnp.where(pos < lengths[:, None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(bsz, h, d).astype(q.dtype)

"""Pure-jnp oracle for paged decode attention.

Mirrors the Pallas kernel's contract, including the fusion hooks: an
optional fresh current-token K/V (``k_self``/``v_self``) merged at
position ``lengths[b]``, and optional ``(m, l)`` running log-sum-exp
statistics (``return_lse``) defined exactly as the kernel accumulates
them (masked scores clamp to ``-1e30``; a fully-masked row has
``m = -1e30, l = 0``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def paged_attention(q: jax.Array, k_arena: jax.Array, v_arena: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    sm_scale: float | None = None,
                    k_self: jax.Array | None = None,
                    v_self: jax.Array | None = None,
                    return_lse: bool = False):
    bsz, h, d = q.shape
    pages, page_size, kvh, _ = k_arena.shape
    groups = h // kvh
    if sm_scale is None:
        sm_scale = d ** -0.5
    max_pages = block_tables.shape[1]
    max_len = max_pages * page_size

    # Gather each sequence's logical KV from its pages.
    k = k_arena[block_tables]                    # (B, P, page, KVH, D)
    v = v_arena[block_tables]
    k = k.reshape(bsz, max_len, kvh, d)
    v = v.reshape(bsz, max_len, kvh, d)
    valid = jnp.arange(max_len)[None, :] < lengths[:, None]   # (B, S)
    if k_self is not None:
        # current token appended after the history; always attended
        k = jnp.concatenate([k, k_self[:, None].astype(k.dtype)], axis=1)
        v = jnp.concatenate([v, v_self[:, None].astype(v.dtype)], axis=1)
        valid = jnp.concatenate(
            [valid, jnp.ones((bsz, 1), bool)], axis=1)

    qg = q.reshape(bsz, kvh, groups, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32)) * sm_scale
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    m = jnp.maximum(jnp.max(s, axis=-1), _NEG_INF)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    out = out / jnp.where(l == 0.0, 1.0, l)[..., None]
    out = out.reshape(bsz, h, d).astype(q.dtype)
    if return_lse:
        return out, m.reshape(bsz, h), l.reshape(bsz, h)
    return out

"""Jit'd wrapper for paged decode attention.

``paged_attention`` is the jitted public entry; ``paged_attention_inline``
is the same dispatch logic without the jit wrapper, for callers that are
already inside a compiled computation (the serving engine's fused decode
step traces it inside one outer ``jax.jit``).

Multi-round contract: the engine's persistent decode loop
(``decode_block_rounds=K``) traces this kernel inside a
``jax.lax.while_loop`` body, so ``lengths`` may be a *loop carry* (each
in-loop round advances live rows' lengths on device) while
``block_tables`` stays a loop constant spanning the pages reserved for
the whole K-token block.  Both are ordinary traced operands here —
nothing in the dispatch may specialize on their values, only on shapes;
use the ``_inline`` form for this (the jitted wrapper would nest a jit
inside the loop body).  Positions at or beyond ``lengths[b]`` are
masked, so the over-reserved tail pages of a mid-block sequence are
never attended.
"""

from __future__ import annotations

import functools

import jax

from . import paged_attention as pa, ref

_ON_TPU = jax.default_backend() == "tpu"


def paged_attention_inline(q: jax.Array, k_arena: jax.Array,
                           v_arena: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, *,
                           sm_scale: float | None = None,
                           use_pallas: bool = True,
                           interpret: bool = not _ON_TPU,
                           k_self: jax.Array | None = None,
                           v_self: jax.Array | None = None,
                           return_lse: bool = False):
    """Pallas-or-reference dispatch; see the kernel for the contract.

    ``k_self``/``v_self`` (B, KVH, D) merge the fresh current token
    in-kernel; ``return_lse`` also returns the (m, l) softmax stats.
    """
    if use_pallas:
        return pa.paged_attention(q, k_arena, v_arena, block_tables, lengths,
                                  sm_scale=sm_scale, interpret=interpret,
                                  k_self=k_self, v_self=v_self,
                                  return_lse=return_lse)
    return ref.paged_attention(q, k_arena, v_arena, block_tables, lengths,
                               sm_scale=sm_scale, k_self=k_self,
                               v_self=v_self, return_lse=return_lse)


paged_attention = functools.partial(
    jax.jit,
    static_argnames=("sm_scale", "use_pallas", "interpret", "return_lse"),
)(paged_attention_inline)

"""Jit'd wrapper for paged decode attention."""

from __future__ import annotations

import functools

import jax

from . import paged_attention as pa, ref

_ON_TPU = jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("sm_scale", "use_pallas", "interpret"))
def paged_attention(q: jax.Array, k_arena: jax.Array, v_arena: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    sm_scale: float | None = None,
                    use_pallas: bool = True, interpret: bool = not _ON_TPU) -> jax.Array:
    if use_pallas:
        return pa.paged_attention(q, k_arena, v_arena, block_tables, lengths,
                                  sm_scale=sm_scale, interpret=interpret)
    return ref.paged_attention(q, k_arena, v_arena, block_tables, lengths,
                               sm_scale=sm_scale)

"""Paged decode attention kernel over the PiM KV arena.

This is where PiDRAM's memory-management contribution meets the serving
path: the KV cache lives in a page arena managed by the subarray-aware
allocator (`repro.serving.kv_cache`), and decode attention walks each
sequence's *block table* — pages are never copied or compacted; forking a
sequence is a `pim_page_copy` (RowClone) and freeing is a `pim_page_init`.

Kernel layout (decode: one query token per sequence):

  grid = (batch, max_pages_per_seq)

Scalar-prefetched operands: block_tables (batch, max_pages) and context
lengths (batch,).  For grid step (b, p) the k/v BlockSpecs select arena
page ``block_tables[b, p]``; flash-style running (m, l, acc) scratch
accumulates across the page axis.  Pages beyond ``ceil(len/page)`` are
masked out entirely.

q: (B, H, D) single token per sequence; kv arena: (pages, page_size, KVH, D).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, page_size: int, sm_scale: float,
                  groups: int):
    b = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    ctx_len = len_ref[b]

    @pl.when(p * page_size < ctx_len)
    def _attend():
        q = q_ref[0].astype(jnp.float32) * sm_scale          # (H, D)
        k = k_ref[0].astype(jnp.float32)                     # (page, KVH, D)
        v = v_ref[0].astype(jnp.float32)                     # (page, KVH, D)
        h, d = q.shape
        kvh = k.shape[1]
        qg = q.reshape(kvh, groups, d)                       # (KVH, G, D)
        # scores: (KVH, G, page)
        s = jnp.einsum("kgd,pkd->kgp", qg, k)
        pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(pos < ctx_len, s, _NEG_INF)

        m_prev = m_scr[...]                                  # (H, 1)
        m_cur = jnp.max(s, axis=2).reshape(h, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        ps = jnp.exp(s - m_new.reshape(kvh, groups, 1))
        l_scr[...] = alpha * l_scr[...] + jnp.sum(ps, axis=2).reshape(h, 1)
        pv = jnp.einsum("kgp,pkd->kgd", ps, v).reshape(h, d)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(p == np_ - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention(q: jax.Array, k_arena: jax.Array, v_arena: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    sm_scale: float | None = None,
                    interpret: bool = False) -> jax.Array:
    """Decode attention over a paged KV arena.

    q: (B, H, D); k_arena/v_arena: (pages, page_size, KVH, D);
    block_tables: (B, max_pages) int32; lengths: (B,) int32.
    """
    bsz, h, d = q.shape
    pages, page_size, kvh, _ = k_arena.shape
    groups = h // kvh
    if sm_scale is None:
        sm_scale = d ** -0.5
    max_pages = block_tables.shape[1]
    grid = (bsz, max_pages)

    kernel = functools.partial(
        _paged_kernel, page_size=page_size, sm_scale=sm_scale, groups=groups)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b, p, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, page_size, kvh, d), lambda b, p, bt, ln: (bt[b, p], 0, 0, 0)),
            pl.BlockSpec((1, page_size, kvh, d), lambda b, p, bt, ln: (bt[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b, p, bt, ln: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), q, k_arena, v_arena)

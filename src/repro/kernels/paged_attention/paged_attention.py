"""Paged decode attention kernel over the PiM KV arena.

This is where PiDRAM's memory-management contribution meets the serving
path: the KV cache lives in a page arena managed by the subarray-aware
allocator (`repro.serving.kv_cache`), and decode attention walks each
sequence's *block table* — pages are never copied or compacted; forking a
sequence is a `pim_page_copy` (RowClone) and freeing is a `pim_page_init`.

Kernel layout (decode: one query token per sequence):

  grid = (batch, max_pages_per_seq)

Scalar-prefetched operands: block_tables (batch, max_pages) and context
lengths (batch,).  For grid step (b, p) the k/v BlockSpecs select arena
page ``block_tables[b, p]``; flash-style running (m, l, acc) scratch
accumulates across the page axis.  Pages beyond ``ceil(len/page)`` are
masked out entirely.

Two fusion hooks keep a decode round a single dispatch:

* ``k_self`` / ``v_self`` — the current token's fresh K/V (not yet
  written to the arena) are folded into the running softmax in the
  finalize step, so the engine needs no separate history-re-reading
  merge pass after the kernel;
* ``return_lse`` — the running log-sum-exp statistics ``(m, l)`` are
  emitted alongside the output so callers that *do* merge externally can
  combine without recomputing history scores.

q: (B, H, D) single token per sequence; kv arena: (pages, page_size, KVH, D).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *rest,
                  page_size: int, sm_scale: float, groups: int,
                  has_self: bool, return_lse: bool):
    # Optional refs unpack in in_specs/out_specs order: inputs
    # [k_self, v_self], outputs [o, m, l], then the three scratch refs.
    i = 0
    if has_self:
        ks_ref, vs_ref = rest[0], rest[1]
        i = 2
    o_ref = rest[i]
    i += 1
    if return_lse:
        m_ref, l_ref = rest[i], rest[i + 1]
        i += 2
    m_scr, l_scr, acc_scr = rest[i:i + 3]

    b = pl.program_id(0)
    p = pl.program_id(1)
    np_ = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    ctx_len = len_ref[b]

    @pl.when(p * page_size < ctx_len)
    def _attend():
        q = q_ref[0].astype(jnp.float32) * sm_scale          # (H, D)
        k = k_ref[0].astype(jnp.float32)                     # (page, KVH, D)
        v = v_ref[0].astype(jnp.float32)                     # (page, KVH, D)
        h, d = q.shape
        kvh = k.shape[1]
        qg = q.reshape(kvh, groups, d)                       # (KVH, G, D)
        # scores: (KVH, G, page)
        s = jnp.einsum("kgd,pkd->kgp", qg, k)
        pos = p * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        s = jnp.where(pos < ctx_len, s, _NEG_INF)

        m_prev = m_scr[...]                                  # (H, 1)
        m_cur = jnp.max(s, axis=2).reshape(h, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        ps = jnp.exp(s - m_new.reshape(kvh, groups, 1))
        l_scr[...] = alpha * l_scr[...] + jnp.sum(ps, axis=2).reshape(h, 1)
        pv = jnp.einsum("kgp,pkd->kgd", ps, v).reshape(h, d)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new

    @pl.when(p == np_ - 1)
    def _finalize():
        m = m_scr[...]
        l = l_scr[...]
        acc = acc_scr[...]
        if has_self:
            # fold the current token (position ctx_len, always attended)
            # into the running softmax — the in-kernel self-token merge
            q = q_ref[0].astype(jnp.float32) * sm_scale      # (H, D)
            h, d = q.shape
            ks = ks_ref[0].astype(jnp.float32)               # (KVH, D)
            vs = vs_ref[0].astype(jnp.float32)
            kvh = ks.shape[0]
            qg = q.reshape(kvh, groups, d)
            s_self = jnp.einsum("kgd,kd->kg", qg, ks).reshape(h, 1)
            m_new = jnp.maximum(m, s_self)
            alpha = jnp.exp(m - m_new)
            p_self = jnp.exp(s_self - m_new)                 # (H, 1)
            l = l * alpha + p_self
            vsg = jnp.broadcast_to(vs[:, None, :], (kvh, groups, d))
            acc = acc * alpha + p_self * vsg.reshape(h, d)
            m = m_new
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc / l_safe).astype(o_ref.dtype)
        if return_lse:
            m_ref[0] = m[:, 0]
            l_ref[0] = l[:, 0]


def paged_attention(q: jax.Array, k_arena: jax.Array, v_arena: jax.Array,
                    block_tables: jax.Array, lengths: jax.Array, *,
                    sm_scale: float | None = None,
                    interpret: bool = False,
                    k_self: jax.Array | None = None,
                    v_self: jax.Array | None = None,
                    return_lse: bool = False):
    """Decode attention over a paged KV arena.

    q: (B, H, D); k_arena/v_arena: (pages, page_size, KVH, D);
    block_tables: (B, max_pages) int32; lengths: (B,) int32;
    k_self/v_self: optional (B, KVH, D) fresh current-token KV, merged
    in-kernel at position ``lengths[b]``.

    Returns o (B, H, D), or (o, m, l) with m/l (B, H) float32 running
    softmax stats when ``return_lse``.
    """
    bsz, h, d = q.shape
    pages, page_size, kvh, _ = k_arena.shape
    groups = h // kvh
    if sm_scale is None:
        sm_scale = d ** -0.5
    max_pages = block_tables.shape[1]
    grid = (bsz, max_pages)
    has_self = k_self is not None

    kernel = functools.partial(
        _paged_kernel, page_size=page_size, sm_scale=sm_scale, groups=groups,
        has_self=has_self, return_lse=return_lse)

    in_specs = [
        pl.BlockSpec((1, h, d), lambda b, p, bt, ln: (b, 0, 0)),
        pl.BlockSpec((1, page_size, kvh, d), lambda b, p, bt, ln: (bt[b, p], 0, 0, 0)),
        pl.BlockSpec((1, page_size, kvh, d), lambda b, p, bt, ln: (bt[b, p], 0, 0, 0)),
    ]
    operands = [q, k_arena, v_arena]
    if has_self:
        in_specs += [
            pl.BlockSpec((1, kvh, d), lambda b, p, bt, ln: (b, 0, 0)),
            pl.BlockSpec((1, kvh, d), lambda b, p, bt, ln: (b, 0, 0)),
        ]
        operands += [k_self, v_self]

    out_specs = pl.BlockSpec((1, h, d), lambda b, p, bt, ln: (b, 0, 0))
    out_shape = jax.ShapeDtypeStruct(q.shape, q.dtype)
    if return_lse:
        lse_spec = pl.BlockSpec((1, h), lambda b, p, bt, ln: (b, 0))
        lse_shape = jax.ShapeDtypeStruct((bsz, h), jnp.float32)
        out_specs = [out_specs, lse_spec, lse_spec]
        out_shape = [out_shape, lse_shape, lse_shape]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, 1), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), *operands)
    if return_lse:
        return tuple(out)
    return out

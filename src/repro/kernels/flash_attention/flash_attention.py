"""Blockwise fused (flash) attention forward kernel for TPU.

The perf-critical compute hot-spot of every assigned LM architecture.
Online-softmax attention with (bq, bk) tiling:

  grid = (batch, q_heads, num_q_blocks, num_kv_blocks)

The kv-block axis is the minor-most grid dimension, so for a fixed
(b, h, i) the kernel visits kv blocks sequentially while running
max / sum / weighted-accumulator live in VMEM scratch — the classic
flash-attention recurrence.  Causal masking is applied per-tile from
global row/col indices.  GQA/MQA is supported by mapping query head h to
kv head h // group_size in the k/v BlockSpec index maps.

Per-sequence length masking (the fused bucketed-prefill contract): with
``lengths`` (B,) the kernel additionally masks key columns at or beyond
``lengths[b]`` — prompts padded up to a power-of-two bucket attend only
to their real tokens.  ``lengths`` rides in as a scalar-prefetch operand
(the same mechanism the paged-attention kernel uses for block tables),
so the mask costs one SMEM read per tile, not a VMEM operand.

Prefix-KV masking (the chunked-prefill contract): with ``k_prefix`` /
``v_prefix`` (B, KVH, Sp, D) and ``prefix_lengths`` (B,), the chunk's
queries additionally attend over a sequence's *already-committed* KV —
the caller's gather of the paged arena — prepended to the chunk's own
keys.  Prefix columns are NOT causally masked (every real prefix
position precedes every chunk query position by construction); they are
masked only by ``prefix_lengths[b]``.  Chunk columns keep the causal +
``lengths`` mask, shifted by the static prefix capacity.  Both length
vectors ride as scalar-prefetch operands; a row with
``prefix_lengths[b] == 0`` degenerates exactly to the prefix-less
kernel.

Block sizes default to (bq, bk) = (256, 512) with head_dim up to 256:
q-tile 256x256xf32 (256 KB) + k,v tiles 512x256 (2x512 KB) + acc scratch
well under the ~16 MiB VMEM budget, MXU-aligned (multiples of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(*refs, sm_scale: float, causal: bool, bq: int, bk: int,
                  seq_k: int, has_lengths: bool, seq_prefix: int = 0,
                  has_prefix: bool = False):
    if has_prefix:
        len_ref, plen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
    elif has_lengths:
        len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs

    b = pl.program_id(0)
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, _NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    col = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = col < seq_k                                   # padding mask
    if has_prefix:
        # keys are [prefix ; chunk]: prefix columns mask only by the
        # per-sequence committed length (every real prefix position
        # precedes every chunk query); chunk columns keep the causal +
        # chunk-length mask, shifted by the static prefix capacity
        row = i * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cc = col - seq_prefix                            # chunk-local column
        chunk_ok = cc < len_ref[b]
        if causal:
            chunk_ok = chunk_ok & (cc <= row)
        mask = mask & jnp.where(col < seq_prefix, col < plen_ref[b], chunk_ok)
    else:
        if has_lengths:
            mask = mask & (col < len_ref[b])             # per-sequence length
        if causal:
            row = i * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (col <= row)
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                  # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: float | None = None,
                    block_q: int = 256, block_k: int = 512,
                    lengths: jax.Array | None = None,
                    k_prefix: jax.Array | None = None,
                    v_prefix: jax.Array | None = None,
                    prefix_lengths: jax.Array | None = None,
                    interpret: bool = False) -> jax.Array:
    """Fused attention forward.

    q: (B, H, Sq, D);  k, v: (B, KVH, Sk, D) with H % KVH == 0.
    ``lengths``: optional (B,) int32 valid kv lengths — columns at or
    beyond ``lengths[b]`` are masked (length-padded prefill batches; for
    well-defined rows every length must be >= 1 under ``causal``).
    ``k_prefix``/``v_prefix``: optional (B, KVH, Sp, D) already-committed
    KV the queries may attend over in full (no causal mask — the chunked
    prefill contract: every query sits at a position after the whole
    prefix), masked per row by ``prefix_lengths`` (B,) int32; rows with
    ``prefix_lengths[b] == 0`` see no prefix at all.  Requires
    ``lengths``.  Returns (B, H, Sq, D) in q.dtype.
    """
    has_prefix = k_prefix is not None
    sp = 0
    if has_prefix:
        assert v_prefix is not None and prefix_lengths is not None
        assert lengths is not None, "prefix-KV path requires lengths"
        sp = k_prefix.shape[2]
        k = jnp.concatenate([k_prefix, k], axis=2)
        v = jnp.concatenate([v_prefix, v], axis=2)
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    if sm_scale is None:
        sm_scale = d ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    # explicit zero padding to block multiples: padded kv columns are
    # masked by seq_k below; padded q rows are sliced off the output.
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    sq_p, sk_p = sq + pq, sk + pk
    grid = (b, h, pl.cdiv(sq_p, bq), pl.cdiv(sk_p, bk))

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, bq=bq, bk=bk,
        seq_k=sk, has_lengths=lengths is not None, seq_prefix=sp,
        has_prefix=has_prefix)

    out_shape = jax.ShapeDtypeStruct(q.shape, q.dtype)
    scratch_shapes = [
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, d), jnp.float32),
    ]
    # index maps shared by both dispatch modes: the trailing *_ absorbs
    # the scalar-prefetch ref PrefetchScalarGridSpec appends
    q_map = lambda b_, h_, i, j, *_: (b_, h_, i, 0)           # noqa: E731
    kv_map = lambda b_, h_, i, j, *_, g=group: (b_, h_ // g, j, 0)  # noqa: E731
    in_specs = [
        pl.BlockSpec((1, 1, bq, d), q_map),
        pl.BlockSpec((1, 1, bk, d), kv_map),
        pl.BlockSpec((1, 1, bk, d), kv_map),
    ]
    out_specs = pl.BlockSpec((1, 1, bq, d), q_map)
    if lengths is None:
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            interpret=interpret,
        )(q, k, v)
    else:
        # lengths (and, on the chunked path, prefix_lengths) ride as
        # scalar-prefetch operands (SMEM), the same mechanism the
        # paged-attention kernel uses for block tables
        scalars = [lengths.astype(jnp.int32)]
        if has_prefix:
            scalars.append(prefix_lengths.astype(jnp.int32))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(scalars),
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch_shapes,
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(*scalars, q, k, v)
    return out[:, :, :sq]

"""Jit'd wrapper for the flash-attention kernel family.

``attention`` is the jitted public entry; ``attention_inline`` is the
same dispatch logic without the jit wrapper, for callers already inside
a compiled computation (the serving engine's fused prefill step traces
it inside one outer ``jax.jit``).
"""

from __future__ import annotations

import functools

import jax

from . import flash_attention as fa, ref

_ON_TPU = jax.default_backend() == "tpu"


def attention_inline(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, sm_scale: float | None = None,
                     block_q: int = 256, block_k: int = 512,
                     lengths: jax.Array | None = None,
                     k_prefix: jax.Array | None = None,
                     v_prefix: jax.Array | None = None,
                     prefix_lengths: jax.Array | None = None,
                     use_pallas: bool = True,
                     interpret: bool = not _ON_TPU) -> jax.Array:
    """Pallas-or-reference dispatch; see the kernel for the contract.

    ``lengths`` (B,) masks key columns at or beyond each sequence's
    valid length (length-padded prefill batches).  ``k_prefix`` /
    ``v_prefix`` (B, KVH, Sp, D) + ``prefix_lengths`` (B,) add the
    chunked-prefill prefix-KV path: queries attend the committed prefix
    in full (no causal mask) and the chunk keys causally.
    """
    if use_pallas:
        return fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                                  block_q=block_q, block_k=block_k,
                                  lengths=lengths, k_prefix=k_prefix,
                                  v_prefix=v_prefix,
                                  prefix_lengths=prefix_lengths,
                                  interpret=interpret)
    return ref.attention(q, k, v, causal=causal, sm_scale=sm_scale,
                         lengths=lengths, k_prefix=k_prefix,
                         v_prefix=v_prefix, prefix_lengths=prefix_lengths)


attention = functools.partial(
    jax.jit, static_argnames=(
        "causal", "sm_scale", "block_q", "block_k", "use_pallas", "interpret"),
)(attention_inline)

"""Jit'd wrapper for the flash-attention kernel family."""

from __future__ import annotations

import functools

import jax

from . import flash_attention as fa, ref

_ON_TPU = jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "sm_scale", "block_q", "block_k", "use_pallas", "interpret"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, sm_scale: float | None = None,
              block_q: int = 256, block_k: int = 512,
              use_pallas: bool = True, interpret: bool = not _ON_TPU) -> jax.Array:
    if use_pallas:
        return fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
    return ref.attention(q, k, v, causal=causal, sm_scale=sm_scale)

"""Pure-jnp oracle for flash attention (naive softmax attention)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, sm_scale: float | None = None,
              lengths: jax.Array | None = None) -> jax.Array:
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    group = h // kvh
    if sm_scale is None:
        sm_scale = d ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * sm_scale
    col = jnp.arange(sk)
    if lengths is not None:
        # per-sequence valid-length mask (length-padded prefill batches)
        s = jnp.where(col[None, None, None, :] < lengths[:, None, None, None],
                      s, _NEG_INF)
    if causal:
        row = jnp.arange(sq)[:, None]
        s = jnp.where(col[None, :] <= row, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

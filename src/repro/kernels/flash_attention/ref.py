"""Pure-jnp oracle for flash attention (naive softmax attention)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, sm_scale: float | None = None,
              lengths: jax.Array | None = None,
              k_prefix: jax.Array | None = None,
              v_prefix: jax.Array | None = None,
              prefix_lengths: jax.Array | None = None) -> jax.Array:
    """See :func:`..flash_attention.flash_attention` for the contract.

    With ``k_prefix``/``v_prefix`` (B, KVH, Sp, D) the queries attend
    over the prefix in full (masked per row by ``prefix_lengths``, never
    causally — chunk queries all sit after the committed prefix) plus
    the chunk keys under the usual causal + ``lengths`` mask.
    """
    sp = 0
    if k_prefix is not None:
        assert v_prefix is not None and prefix_lengths is not None
        assert lengths is not None, "prefix-KV path requires lengths"
        sp = k_prefix.shape[2]
        k = jnp.concatenate([k_prefix, k], axis=2)
        v = jnp.concatenate([v_prefix, v], axis=2)
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    group = h // kvh
    if sm_scale is None:
        sm_scale = d ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * sm_scale
    col = jnp.arange(sk)
    row = jnp.arange(sq)[:, None]
    if sp:
        # keys are [prefix ; chunk]: prefix columns mask only by the
        # committed length; chunk columns keep causal + lengths, shifted
        cc = col[None, :] - sp
        chunk_ok = cc < lengths[:, None]                 # (B, sk)
        if causal:
            chunk_ok = chunk_ok[:, None, :] & (cc[None] <= row)  # (B, sq, sk)
        else:
            chunk_ok = jnp.broadcast_to(chunk_ok[:, None, :], (b, sq, sk))
        pref_ok = jnp.broadcast_to(
            (col[None, :] < prefix_lengths[:, None])[:, None, :], (b, sq, sk))
        mask = jnp.where(col[None, None, :] < sp, pref_ok, chunk_ok)
        s = jnp.where(mask[:, None], s, _NEG_INF)
    else:
        if lengths is not None:
            # per-sequence valid-length mask (length-padded prefill batches)
            s = jnp.where(col[None, None, None, :] < lengths[:, None, None, None],
                          s, _NEG_INF)
        if causal:
            s = jnp.where(col[None, :] <= row, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

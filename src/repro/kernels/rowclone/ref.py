"""Pure-jnp oracles for the RowClone kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def copy_2d(src: jax.Array) -> jax.Array:
    return src + jnp.zeros((), src.dtype)  # forces a materialized copy


def init_2d(shape, value, dtype=jnp.float32) -> jax.Array:
    return jnp.full(shape, value, dtype)


def page_copy(arena: jax.Array, src_pages: jax.Array, dst_pages: jax.Array) -> jax.Array:
    return arena.at[dst_pages].set(arena[src_pages])


def page_init(arena: jax.Array, dst_pages: jax.Array, value) -> jax.Array:
    page = jnp.full((dst_pages.shape[0], arena.shape[1]), value, arena.dtype)
    return arena.at[dst_pages].set(page)


# Layer-batched variants: arena carries a leading (layers,) axis and every
# layer moves in the one logical op.


def page_copy_batched(arena: jax.Array, src_pages: jax.Array,
                      dst_pages: jax.Array) -> jax.Array:
    return arena.at[:, dst_pages].set(arena[:, src_pages])


def page_init_batched(arena: jax.Array, dst_pages: jax.Array, value) -> jax.Array:
    fill = jnp.full((arena.shape[0], dst_pages.shape[0]) + arena.shape[2:],
                    value, arena.dtype)
    return arena.at[:, dst_pages].set(fill)


def kv_scatter(arena: jax.Array, pages: jax.Array, slots: jax.Array,
               new: jax.Array) -> jax.Array:
    """arena: (L, P, S, E); pages/slots: (B,); new: (L, B, E)."""
    return arena.at[:, pages, slots].set(new.astype(arena.dtype))


def kv_gather(arena: jax.Array, pages: jax.Array, slots: jax.Array) -> jax.Array:
    """Read back ``arena[:, pages[b], slots[b]]`` — the scatter's inverse.
    arena: (L, P, S, E); pages/slots: (B,).  Returns (L, B, E)."""
    return arena[:, pages, slots]

"""Pure-jnp oracles for the RowClone kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def copy_2d(src: jax.Array) -> jax.Array:
    return src + jnp.zeros((), src.dtype)  # forces a materialized copy


def init_2d(shape, value, dtype=jnp.float32) -> jax.Array:
    return jnp.full(shape, value, dtype)


def page_copy(arena: jax.Array, src_pages: jax.Array, dst_pages: jax.Array) -> jax.Array:
    return arena.at[dst_pages].set(arena[src_pages])


def page_init(arena: jax.Array, dst_pages: jax.Array, value) -> jax.Array:
    page = jnp.full((dst_pages.shape[0], arena.shape[1]), value, arena.dtype)
    return arena.at[dst_pages].set(page)

"""RowClone Pallas kernels: bulk in-memory copy / initialization on TPU.

The TPU-native adaptation of RowClone (DESIGN.md SS2): bulk data movement
that never occupies the MXU/VPU with useful work — a pure streaming
HBM -> VMEM -> HBM pipeline.  Pallas double-buffers the grid automatically,
so with row-sized blocks this runs at HBM bandwidth, the TPU equivalent of
"copy at row-buffer speed instead of through the core".

Kernel family:

* ``copy``      — tile-streamed tensor copy.
* ``init``      — tile memset from an SMEM scalar (no read traffic at all).
* ``page_copy`` — arena page copy: ``arena[dst_page] <- arena[src_page]``
  for a batch of page pairs, with the page index list scalar-prefetched
  (the BlockSpec index_map reads it — the TPU version of the POC consuming
  a PiDRAM instruction's row-address operands).  The arena is aliased
  in/out, so untouched pages are never moved: this is the RowClone
  "data never leaves the memory device" property at the XLA buffer level.

Layer-batched variants (the batched PiM op scheduler's launch targets —
one fused dispatch regardless of layer count or batch size, the TPU
analogue of amortizing the POC handshake over a whole command batch):

* ``page_copy_batched`` / ``page_init_batched`` — the same page ops over
  a ``(layers, pages, elems)`` arena with a 3D grid: every layer's pages
  move in one launch instead of ``O(layers)`` separate calls.
* ``kv_scatter`` — write ``(layers, batch)`` fresh KV slots
  ``arena[l, pages[b], slots[b]] <- new[l, b]`` in one launch; the
  (page, slot) coordinates are scalar-prefetched so the output BlockSpec
  lands each block exactly on its slot (no read-modify-write).

Block shapes are chosen so a block is a multiple of the (8, 128) f32 /
(16, 128) bf16 VMEM tile and comfortably fits VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile: 8 MiB of f32 per block-pair (in+out) incl. double buffering
# stays well under the ~16 MiB v5e VMEM budget at (512, 1024) f32;
# bf16 halves it.
_BLOCK_ROWS = 512
_BLOCK_COLS = 1024


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def copy_2d(src: jax.Array, *, block_rows: int = _BLOCK_ROWS,
            block_cols: int = _BLOCK_COLS, interpret: bool = False) -> jax.Array:
    """Streamed copy of a 2D array (rows, cols)."""
    rows, cols = src.shape
    br, bc = min(block_rows, rows), min(block_cols, cols)
    grid = (pl.cdiv(rows, br), pl.cdiv(cols, bc))
    return pl.pallas_call(
        _copy_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(src.shape, src.dtype),
        interpret=interpret,
    )(src)


def _init_kernel(val_ref, dst_ref):
    dst_ref[...] = jnp.full(dst_ref.shape, val_ref[0], dst_ref.dtype)


def init_2d(shape, value, dtype=jnp.float32, *, block_rows: int = _BLOCK_ROWS,
            block_cols: int = _BLOCK_COLS, interpret: bool = False) -> jax.Array:
    """Memset: write ``value`` into a fresh (rows, cols) buffer.

    Unlike ``jnp.full`` followed by ops, this is a single write-only pass
    (the calloc-vs-RowClone-Init distinction: no read-for-ownership).
    """
    rows, cols = shape
    br, bc = min(block_rows, rows), min(block_cols, cols)
    grid = (pl.cdiv(rows, br), pl.cdiv(cols, bc))
    val = jnp.asarray([value], dtype)
    return pl.pallas_call(
        _init_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(shape, dtype),
        interpret=interpret,
    )(val)


def _page_copy_kernel(src_idx_ref, dst_idx_ref, arena_ref, out_ref):
    # Grid: (num_copies, col_blocks).  BlockSpec index_maps below select
    # arena[src_idx[i]] as input block and arena[dst_idx[i]] as output
    # block, so the kernel body is a pure tile move.
    del src_idx_ref, dst_idx_ref
    out_ref[...] = arena_ref[...]


def page_copy(arena: jax.Array, src_pages: jax.Array, dst_pages: jax.Array,
              *, block_cols: int = 4096, interpret: bool = False) -> jax.Array:
    """Copy ``arena[src_pages[i]] -> arena[dst_pages[i]]`` for all i.

    arena: (num_pages, page_elems); src/dst_pages: (n,) int32.
    The arena buffer is donated/aliased: XLA updates pages in place.
    """
    num_pages, page_elems = arena.shape
    n = src_pages.shape[0]
    bc = min(block_cols, page_elems)
    grid = (n, pl.cdiv(page_elems, bc))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc), lambda i, j, src_idx, dst_idx: (src_idx[i], j)),
        ],
        out_specs=pl.BlockSpec((1, bc), lambda i, j, src_idx, dst_idx: (dst_idx[i], j)),
    )
    return pl.pallas_call(
        _page_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={2: 0},  # arena (after 2 scalar-prefetch args) -> out
        interpret=interpret,
    )(src_pages.astype(jnp.int32), dst_pages.astype(jnp.int32), arena)


def _page_copy_batched_kernel(src_idx_ref, dst_idx_ref, arena_ref, out_ref):
    # Grid: (layers, num_copies, col_blocks); index_maps route
    # arena[l, src_idx[i]] -> arena[l, dst_idx[i]].
    del src_idx_ref, dst_idx_ref
    out_ref[...] = arena_ref[...]


def page_copy_batched(arena: jax.Array, src_pages: jax.Array,
                      dst_pages: jax.Array, *, block_cols: int = 4096,
                      interpret: bool = False) -> jax.Array:
    """Copy ``arena[:, src_pages[i]] -> arena[:, dst_pages[i]]`` for all i
    across every layer in ONE launch.

    arena: (layers, num_pages, page_elems); src/dst_pages: (n,) int32.
    The arena is aliased in/out, so the launch cost is independent of the
    number of layers (grid iterations stream, nothing re-dispatches).
    """
    layers, num_pages, page_elems = arena.shape
    n = src_pages.shape[0]
    bc = min(block_cols, page_elems)
    grid = (layers, n, pl.cdiv(page_elems, bc))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bc),
                         lambda l, i, j, src_idx, dst_idx: (l, src_idx[i], j)),
        ],
        out_specs=pl.BlockSpec((1, 1, bc),
                               lambda l, i, j, src_idx, dst_idx: (l, dst_idx[i], j)),
    )
    return pl.pallas_call(
        _page_copy_batched_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(src_pages.astype(jnp.int32), dst_pages.astype(jnp.int32), arena)


def _page_init_batched_kernel(dst_idx_ref, val_ref, arena_ref, out_ref):
    del dst_idx_ref, arena_ref
    out_ref[...] = jnp.full(out_ref.shape, val_ref[0], out_ref.dtype)


def page_init_batched(arena: jax.Array, dst_pages: jax.Array, value,
                      *, block_cols: int = 4096,
                      interpret: bool = False) -> jax.Array:
    """Memset ``arena[:, dst_pages[i]] <- value`` across all layers in one
    launch (layer-batched RowClone-Init)."""
    layers, num_pages, page_elems = arena.shape
    n = dst_pages.shape[0]
    bc = min(block_cols, page_elems)
    grid = (layers, n, pl.cdiv(page_elems, bc))
    val = jnp.asarray([value], arena.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # value
            pl.BlockSpec(memory_space=pl.ANY),       # arena (aliased, unread)
        ],
        out_specs=pl.BlockSpec((1, 1, bc),
                               lambda l, i, j, dst_idx: (l, dst_idx[i], j)),
    )
    return pl.pallas_call(
        _page_init_batched_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(dst_pages.astype(jnp.int32), val, arena)


def _kv_scatter_kernel(page_idx_ref, slot_idx_ref, new_ref, arena_ref, out_ref):
    # Grid: (layers, batch).  The output BlockSpec lands this (1,1,1,E)
    # block exactly on arena[l, pages[b], slots[b]], so the body is a pure
    # slot write — no surrounding-page read traffic.
    del page_idx_ref, slot_idx_ref, arena_ref
    out_ref[...] = new_ref[...].reshape(out_ref.shape)


def kv_scatter(arena: jax.Array, pages: jax.Array, slots: jax.Array,
               new: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Scatter fresh KV vectors: ``arena[l, pages[b], slots[b]] <- new[l, b]``.

    arena: (layers, num_pages, page_size, elems); pages/slots: (batch,)
    int32; new: (layers, batch, elems).  One launch writes every layer's
    slot for every sequence in the batch — the decode-round KV write is a
    single dispatch independent of ``layers`` and ``batch``.  Duplicate
    (page, slot) pairs are undefined (last grid iteration wins).
    """
    layers, num_pages, page_size, elems = arena.shape
    batch = pages.shape[0]
    grid = (layers, batch)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, elems), lambda l, b, pg, sl: (l, b, 0)),  # new
            pl.BlockSpec(memory_space=pl.ANY),       # arena (aliased, unread)
        ],
        out_specs=pl.BlockSpec((1, 1, 1, elems),
                               lambda l, b, pg, sl: (l, pg[b], sl[b], 0)),
    )
    return pl.pallas_call(
        _kv_scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={3: 0},
        interpret=interpret,
    )(pages.astype(jnp.int32), slots.astype(jnp.int32),
      new.astype(arena.dtype), arena)


def _page_init_kernel(dst_idx_ref, val_ref, arena_ref, out_ref):
    del dst_idx_ref, arena_ref
    out_ref[...] = jnp.full(out_ref.shape, val_ref[0], out_ref.dtype)


def page_init(arena: jax.Array, dst_pages: jax.Array, value,
              *, block_cols: int = 4096, interpret: bool = False) -> jax.Array:
    """Memset ``arena[dst_pages[i]] <- value`` (RowClone-Init on pages)."""
    num_pages, page_elems = arena.shape
    n = dst_pages.shape[0]
    bc = min(block_cols, page_elems)
    grid = (n, pl.cdiv(page_elems, bc))
    val = jnp.asarray([value], arena.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # value
            pl.BlockSpec(memory_space=pl.ANY),       # arena (aliased, unread)
        ],
        out_specs=pl.BlockSpec((1, bc), lambda i, j, dst_idx: (dst_idx[i], j)),
    )
    return pl.pallas_call(
        _page_init_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(arena.shape, arena.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(dst_pages.astype(jnp.int32), val, arena)

"""Jit'd public wrappers for the RowClone kernel family.

``use_pallas`` selects the Pallas kernel (TPU target; interpret-mode on
CPU) vs the pure-jnp reference.  Distribution-level code (dry-run, train,
serve) defaults to the jnp path — XLA already emits a fused copy/memset
for it — while the Pallas path is the TPU hot-spot implementation
validated against the reference in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref, rowclone

_ON_TPU = jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def pim_copy(src: jax.Array, *, use_pallas: bool = False, interpret: bool = not _ON_TPU) -> jax.Array:
    """Bulk copy. 2D inputs stream through the Pallas kernel; other ranks
    reshape to 2D first (row-major pages)."""
    if not use_pallas:
        return ref.copy_2d(src)
    x2 = src.reshape(src.shape[0], -1) if src.ndim != 2 else src
    out = rowclone.copy_2d(x2, interpret=interpret)
    return out.reshape(src.shape)


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "use_pallas", "interpret"))
def pim_init(shape, value, dtype=jnp.float32, *, use_pallas: bool = False,
             interpret: bool = not _ON_TPU) -> jax.Array:
    if not use_pallas:
        return ref.init_2d(shape, value, dtype)
    import numpy as np
    flat = (int(np.prod(shape[:-1])), shape[-1]) if len(shape) != 2 else shape
    out = rowclone.init_2d(flat, value, dtype, interpret=interpret)
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"), donate_argnums=(0,))
def pim_page_copy(arena: jax.Array, src_pages: jax.Array, dst_pages: jax.Array,
                  *, use_pallas: bool = False, interpret: bool = not _ON_TPU) -> jax.Array:
    """RowClone page copy inside a donated arena buffer."""
    if not use_pallas:
        return ref.page_copy(arena, src_pages, dst_pages)
    return rowclone.page_copy(arena, src_pages, dst_pages, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"), donate_argnums=(0,))
def pim_page_init(arena: jax.Array, dst_pages: jax.Array, value,
                  *, use_pallas: bool = False, interpret: bool = not _ON_TPU) -> jax.Array:
    if not use_pallas:
        return ref.page_init(arena, dst_pages, value)
    return rowclone.page_init(arena, dst_pages, value, interpret=interpret)


# ------------------------------------------------------------------ #
# Layer-batched launches — the batched PiM op scheduler's primitives.
# Arenas may carry arbitrary trailing dims: (L, P, ...) is flattened to
# (L, P, E) for the kernel and restored on return.  An empty op batch is
# a no-op (no launch at all; the scheduler never dispatches for it).
# ------------------------------------------------------------------ #


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"), donate_argnums=(0,))
def pim_page_copy_batched(arena: jax.Array, src_pages: jax.Array,
                          dst_pages: jax.Array, *, use_pallas: bool = False,
                          interpret: bool = not _ON_TPU) -> jax.Array:
    """Copy ``arena[:, src_pages] -> arena[:, dst_pages]`` across all
    layers in one fused launch.  arena: (layers, pages, ...)."""
    if src_pages.shape[0] == 0:
        return arena
    if not use_pallas:
        return ref.page_copy_batched(arena, src_pages, dst_pages)
    L, P = arena.shape[:2]
    out = rowclone.page_copy_batched(arena.reshape(L, P, -1), src_pages,
                                     dst_pages, interpret=interpret)
    return out.reshape(arena.shape)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"), donate_argnums=(0,))
def pim_page_init_batched(arena: jax.Array, dst_pages: jax.Array, value,
                          *, use_pallas: bool = False,
                          interpret: bool = not _ON_TPU) -> jax.Array:
    if dst_pages.shape[0] == 0:
        return arena
    if not use_pallas:
        return ref.page_init_batched(arena, dst_pages, value)
    L, P = arena.shape[:2]
    out = rowclone.page_init_batched(arena.reshape(L, P, -1), dst_pages,
                                     value, interpret=interpret)
    return out.reshape(arena.shape)


def kv_scatter_inline(arena: jax.Array, pages: jax.Array, slots: jax.Array,
                      new: jax.Array, *, use_pallas: bool = False,
                      interpret: bool = not _ON_TPU) -> jax.Array:
    """Write ``arena[:, pages[b], slots[b]] <- new[:, b]`` in one launch.

    arena: (layers, pages, page_size, ...); new: (layers, batch, ...).
    Un-jitted body, so callers already inside a compiled computation
    (the serving engine's fused decode step) can trace it without a
    nested donation; ``pim_kv_scatter`` is the jitted/donating wrapper.
    """
    if pages.shape[0] == 0:
        return arena
    L, P, S = arena.shape[:3]
    B = pages.shape[0]
    a4 = arena.reshape(L, P, S, -1)
    n3 = new.reshape(L, B, -1)
    if not use_pallas:
        out = ref.kv_scatter(a4, pages, slots, n3)
    else:
        out = rowclone.kv_scatter(a4, pages, slots, n3, interpret=interpret)
    return out.reshape(arena.shape)


pim_kv_scatter = functools.partial(
    jax.jit, static_argnames=("use_pallas", "interpret"),
    donate_argnums=(0,))(kv_scatter_inline)


def kv_gather_inline(arena: jax.Array, pages: jax.Array,
                     slots: jax.Array) -> jax.Array:
    """Read ``arena[:, pages[b], slots[b]]`` -> (layers, batch, ...) —
    the scatter's inverse, for callers already inside a compiled
    computation.

    The serving engine's multi-round decode loop uses this for its
    masked write-back: a sequence that stopped (EOS/budget) mid-block
    writes the value *already in its slot* back to it, so the scatter
    stays a structural no-op for dead rows and the arena is bit-identical
    to a round-at-a-time run.  Reads have no Pallas variant (XLA fuses
    the gather into the surrounding step); only mutations are RowClone
    hot spots.
    """
    L, P, S = arena.shape[:3]
    a4 = arena.reshape(L, P, S, -1)
    out = ref.kv_gather(a4, pages, slots)
    return out.reshape((L, pages.shape[0]) + arena.shape[3:])

"""Sharded, mesh-elastic checkpointing with async save.

Checkpoints are stored as *logical* arrays (one ``.npy`` per pytree leaf,
path-encoded filenames) plus a JSON manifest (step, config fingerprint).
Because the on-disk format is mesh-agnostic, restore can target a
different mesh shape/axis layout — `load` re-`device_put`s every leaf
with the CURRENT param spec, which is the elastic-rescale path
(checkpoint saved on 16x16 restores onto 8x8 or 2x16x16 unchanged).

At real pod scale each host would write only its addressable shards
(process-local subset of `arr.addressable_shards`); the gather-to-host
write below is the single-process specialization of that layout, and the
manifest format (leaf path -> shape/dtype) is unchanged.  Saves run on a
background thread (training continues); `wait()` joins before the next
save or at shutdown.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------ save ------------------------------- #

    def save(self, step: int, state: Any, *, blocking: bool = False,
             extra: Optional[Dict] = None) -> None:
        self.wait()
        # Snapshot to host memory synchronously (cheap vs device compute),
        # then write files on a background thread.
        leaves_with_paths = jax.tree_util.tree_flatten_with_path(state)[0]
        host = [(_leaf_name(p), np.asarray(x)) for p, x in leaves_with_paths]

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "time": time.time(),
                        "leaves": {}, "extra": extra or {}}
            for name, arr in host:
                np.save(os.path.join(tmp, name + ".npy"), arr)
                manifest["leaves"][name] = {"shape": list(arr.shape),
                                            "dtype": str(arr.dtype)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------ load ------------------------------- #

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load(self, state_like: Any, step: Optional[int] = None,
             sharding_fn: Optional[Callable[[Any, Any], Any]] = None
             ) -> Tuple[Any, int]:
        """Restore into the structure of ``state_like``.

        ``sharding_fn(path_name, host_array)`` may return a device-put
        array with the current mesh sharding (elastic restore); default
        is plain jnp.asarray.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(state_like)
        new_leaves = []
        for p, like in leaves_with_paths:
            name = _leaf_name(p)
            arr = np.load(os.path.join(d, name + ".npy"))
            assert tuple(arr.shape) == tuple(like.shape), (name, arr.shape, like.shape)
            if sharding_fn is not None:
                new_leaves.append(sharding_fn(name, arr))
            else:
                import jax.numpy as jnp
                new_leaves.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, new_leaves), step

"""PiDRAM memory-controller model: a modular DDR3 command scheduler.

The hardware PiDRAM memory controller is a Verilog scheduler that (a)
implements conventional DRAM operation and (b) can be extended with ~60-200
lines to issue *violated-timing* command sequences for PiM techniques.  This
module is its software twin:

* a command-level timing model (every DDR3 command advances a bank-state
  machine and a cycle clock),
* a scheduler with pluggable **PiM sequence extensions** — RowClone and
  D-RaNGe register themselves as sequences, mirroring the paper's
  "easy-to-make modifications to the scheduler" design goal,
* end-to-end cost accounting used to reproduce the paper's Table-level
  results (speedups over memcpy/calloc, TRNG latency/throughput).

The model executes against a :class:`repro.core.dram_model.SimulatedDRAM`
device so functional behaviour (did the copy actually happen? what bits did
the TRNG read return?) and timing are produced together.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dram_model import SimulatedDRAM
from .timing import (
    DDR3Timings,
    PrototypeParams,
    ViolatedTimings,
    DEFAULT_PROTOTYPE,
    DEFAULT_TIMINGS,
    DEFAULT_VIOLATIONS,
)


class Cmd(enum.Enum):
    ACT = "ACT"
    PRE = "PRE"
    RD = "RD"
    WR = "WR"
    REF = "REF"
    NOP = "NOP"


# Sentinel row ids for commands that do not target an addressable row:
# REF targets the whole bank; the Ambit B-group rows (T0/T1/T2, the
# dual-contact-cell row, and the C0/C1 control rows) live outside the
# allocator-visible address space.
ROW_REF = -1
ROW_T0 = -2
ROW_T1 = -3
ROW_T2 = -4
ROW_DCC = -5
ROW_CTRL = -6


@dataclass
class IssuedCmd:
    cmd: Cmd
    row: int
    at_ns: float
    note: str = ""


@dataclass
class SequenceResult:
    """Outcome of executing one (PiM or standard) command sequence."""

    elapsed_ns: float
    commands: List[IssuedCmd]
    ok: bool = True
    data: Optional[np.ndarray] = None

    def __post_init__(self) -> None:
        # Device predicates return numpy truth values; callers compare
        # ``ok`` with ``is True`` and JSON-serialize it, so normalize to
        # a Python bool here.
        self.ok = bool(self.ok)


PimSequence = Callable[["MemoryController", int, int], SequenceResult]


class MemoryController:
    """Command-level DDR3 scheduler with PiM sequence extensions."""

    def __init__(
        self,
        device: SimulatedDRAM,
        timings: DDR3Timings = DEFAULT_TIMINGS,
        violations: ViolatedTimings = DEFAULT_VIOLATIONS,
        proto: PrototypeParams = DEFAULT_PROTOTYPE,
    ) -> None:
        self.device = device
        self.t = timings
        self.v = violations
        self.proto = proto
        self.now_ns: float = 0.0
        self.open_row: Optional[int] = None
        # Bank state: when the most recent ACT was issued.  tRAS (ACT->PRE)
        # and tRC (ACT->ACT) are enforced against this timestamp.
        self._bank_act_ns: Optional[float] = None
        # Refresh schedule: one REF is due every tREFI; the bank is busy
        # for tRFC while it runs.
        self.next_ref_ns: float = timings.tREFI
        self.trace: List[IssuedCmd] = []
        self._sequences: Dict[str, PimSequence] = {}
        self.stats: Dict[str, float] = {"commands": 0, "pim_ops": 0,
                                        "pim_batches": 0, "refreshes": 0}

        # Built-in PiM extensions (the paper's two case studies, plus the
        # Ambit bulk-bitwise triple).
        self.register_sequence("rowclone_copy", _seq_rowclone_copy)
        self.register_sequence("drange_read", _seq_drange_read)
        self.register_sequence("ambit_and", _seq_ambit_and)
        self.register_sequence("ambit_or", _seq_ambit_or)
        self.register_sequence("ambit_not", _seq_ambit_not)

    # ------------------------------------------------------------------ #
    # Extension registry — the "60 additional lines of Verilog" analogue.
    # ------------------------------------------------------------------ #

    def register_sequence(self, name: str, fn: PimSequence) -> None:
        self._sequences[name] = fn

    def has_sequence(self, name: str) -> bool:
        return name in self._sequences

    def run_sequence(self, name: str, a: int, b: int) -> SequenceResult:
        if name not in self._sequences:
            raise KeyError(f"unknown PiM sequence {name!r}")
        self._refresh_if_due()
        self.stats["pim_ops"] += 1
        return self._sequences[name](self, a, b)

    def run_sequence_batch(self, name: str,
                           pairs: Sequence[Tuple[int, int]]) -> SequenceResult:
        """Execute one registered sequence per operand pair back-to-back
        as a single batched command sequence (ComputeDRAM-style batching:
        the POC dispatch handshake is paid once for the whole batch; the
        per-pair DRAM command timings still accrue individually).

        Returns one combined :class:`SequenceResult` whose ``commands``
        cover every pair, ``ok`` is the conjunction, and ``data`` the
        concatenation of any per-pair payloads."""
        if name not in self._sequences:
            raise KeyError(f"unknown PiM sequence {name!r}")
        t0 = self.now_ns
        cmds_start = len(self.trace)
        ok = True
        datas = []
        for a, b in pairs:
            self._refresh_if_due()
            res = self._sequences[name](self, a, b)
            ok = bool(ok and res.ok)
            if res.data is not None:
                datas.append(res.data)
        self.stats["pim_ops"] += len(pairs)
        self.stats["pim_batches"] += 1
        data = np.concatenate(datas) if datas else None
        return SequenceResult(self.now_ns - t0, self.trace[cmds_start:],
                              ok=ok, data=data)

    # ------------------------------------------------------------------ #
    # Primitive command issue (advances the clock per DDR3 timing rules)
    # ------------------------------------------------------------------ #

    def _issue(self, cmd: Cmd, row: int, gap_ns: float, note: str = "") -> None:
        self.now_ns += gap_ns
        self.trace.append(IssuedCmd(cmd, row, self.now_ns, note))
        self.stats["commands"] += 1
        if cmd is Cmd.ACT:
            self.open_row = row
            self._bank_act_ns = self.now_ns
        elif cmd is Cmd.PRE:
            self.open_row = None

    def _wait_until(self, t_ns: float) -> None:
        """Stall (no command issued) until the bank-state clock reaches t_ns."""
        if t_ns > self.now_ns:
            self.now_ns = t_ns

    def _refresh_if_due(self) -> None:
        """Catch up on the refresh schedule: one REF every tREFI, bank
        busy for tRFC.  Called between sequences / spec operations so PiM
        command sequences themselves stay atomic (a real controller
        defers REF across an in-flight sequence, then catches up)."""
        while self.now_ns >= self.next_ref_ns:
            if self.open_row is not None:
                self.precharge()  # banks must be precharged before REF
            self._issue(Cmd.REF, ROW_REF, self.t.tRFC, "refresh (tRFC busy)")
            self.stats["refreshes"] += 1
            self.next_ref_ns += self.t.tREFI

    # Standard (spec-compliant) operations ------------------------------ #
    #
    # Timing enforcement: ACT may not follow a previous ACT within tRC,
    # PRE may not follow the row's ACT within tRAS, and column commands
    # wait out tRCD.  A standard ACT -> PRE round-trip therefore takes
    # exactly tRAS + tRP = tRC (48.75 ns for DDR3-800), not tRCD + tRP.

    def activate(self, row: int) -> None:
        self._refresh_if_due()
        if self.open_row is not None:
            self.precharge()
        if self._bank_act_ns is not None:
            self._wait_until(self._bank_act_ns + self.t.tRC)
        self._issue(Cmd.ACT, row, 0.0, "spec")

    def read_burst(self, row: int) -> None:
        if self.open_row != row:
            self.activate(row)
        self._wait_until(self._bank_act_ns + self.t.tRCD)
        self._issue(Cmd.RD, row, self.t.tCL + self.t.tBL, "64B burst")

    def write_burst(self, row: int) -> None:
        if self.open_row != row:
            self.activate(row)
        self._wait_until(self._bank_act_ns + self.t.tRCD)
        self._issue(Cmd.WR, row, self.t.tCWL + self.t.tBL, "64B burst")

    def precharge(self) -> None:
        self._close_open_row("spec")

    def _close_open_row(self, note: str = "spec") -> None:
        if self.open_row is None:
            return
        if self._bank_act_ns is not None:
            self._wait_until(self._bank_act_ns + self.t.tRAS)
        self._issue(Cmd.PRE, self.open_row, self.t.tRP, note)

    # ------------------------------------------------------------------ #
    # Cost functions for CPU-side baselines (memcpy / calloc / CLFLUSH)
    # — forward-computed from PrototypeParams, see DESIGN.md SS5.
    # ------------------------------------------------------------------ #

    def memcpy_ns(self, nbytes: int) -> float:
        p = self.proto
        words = nbytes / p.word_bytes
        lines = nbytes / p.cacheline_bytes
        cycles = (
            words * p.memcpy_cycles_per_word
            + 2.0 * lines * p.miss_stall_cycles  # src read miss + dst allocate
        )
        return cycles * p.cycle_ns

    def memset_ns(self, nbytes: int) -> float:
        p = self.proto
        words = nbytes / p.word_bytes
        lines = nbytes / p.cacheline_bytes
        cycles = words * p.memset_cycles_per_word + lines * p.miss_stall_cycles
        return cycles * p.cycle_ns

    def clflush_ns(self, nbytes: int) -> float:
        """Flush dirty source-operand blocks (pipelined writebacks)."""
        return (nbytes / self.proto.cacheline_bytes) * self.proto.clflush_ns_per_block

    def clinval_ns(self, nbytes: int) -> float:
        """Invalidate destination-operand blocks (no writeback data)."""
        return (nbytes / self.proto.cacheline_bytes) * self.proto.clinval_ns_per_block

    def bitwise_ns(self, nbytes: int) -> float:
        """CPU bulk-bitwise baseline: read-modify-write loop (2 loads +
        op + store per word; src read miss + dst RMW miss per line)."""
        p = self.proto
        words = nbytes / p.word_bytes
        lines = nbytes / p.cacheline_bytes
        cycles = words * p.bitwise_cycles_per_word + 2.0 * lines * p.miss_stall_cycles
        return cycles * p.cycle_ns

    def scan_ns(self, nbytes: int) -> float:
        """CPU zero-compare baseline: load + compare + branch per word."""
        p = self.proto
        words = nbytes / p.word_bytes
        lines = nbytes / p.cacheline_bytes
        cycles = words * p.scan_cycles_per_word + lines * p.miss_stall_cycles
        return cycles * p.cycle_ns

    def poc_handshake_ns(self) -> float:
        """pimolib register protocol: 2 MMIO stores (insn, Start) +
        2 MMIO polls (Ack, Fin) + syscall/library overhead."""
        p = self.proto
        cycles = 2 * p.mmio_store_cycles + 2 * p.mmio_load_cycles + p.syscall_cycles
        return cycles * p.cycle_ns


# ---------------------------------------------------------------------- #
# PiM sequence extensions
# ---------------------------------------------------------------------- #


def _seq_rowclone_copy(mc: MemoryController, src: int, dst: int) -> SequenceResult:
    """ComputeDRAM-style RowClone: ACT(src) -o- PRE -o- ACT(dst).

    The two gaps violate tRAS and tRP; after the second ACT the controller
    waits a full spec tRAS+tRP to restore and close the destination row.
    """
    t0 = mc.now_ns
    cmds_start = len(mc.trace)
    mc._close_open_row("close before PiM")
    mc._issue(Cmd.ACT, src, 0.0, "rowclone ACT src")
    mc._issue(Cmd.PRE, src, mc.v.t1_act_pre, "violated tRAS")
    mc._issue(Cmd.ACT, dst, mc.v.t2_pre_act, "violated tRP")
    ok = mc.device.rowclone(src, dst)
    # restore + close destination row (spec timings)
    mc._issue(Cmd.PRE, dst, mc.t.tRAS, "restore dst")
    mc.now_ns += mc.t.tRP
    return SequenceResult(mc.now_ns - t0, mc.trace[cmds_start:], ok=ok)


def _aap(mc: MemoryController, src: int, dst: int, note: str) -> None:
    """Ambit AAP (ACT-ACT-PRE) primitive: a violated-timing row copy with
    the same command train and cost as one RowClone (ACT -o- PRE -o- ACT,
    then a spec restore+close of the destination)."""
    mc._issue(Cmd.ACT, src, 0.0, f"{note} ACT")
    mc._issue(Cmd.PRE, src, mc.v.t1_act_pre, f"{note} violated tRAS")
    mc._issue(Cmd.ACT, dst, mc.v.t2_pre_act, f"{note} violated tRP")
    mc._issue(Cmd.PRE, dst, mc.t.tRAS, f"{note} restore")
    mc.now_ns += mc.t.tRP


def _seq_ambit_bitwise(mc: MemoryController, src: int, dst: int,
                       op: str) -> SequenceResult:
    """Ambit AND/OR: stage operands + control row into the B-group with
    three AAPs, one triple-row activation (TRA) for the majority compute,
    then one AAP copying the result over dst (dst <- src OP dst)."""
    t0 = mc.now_ns
    cmds_start = len(mc.trace)
    mc._close_open_row("close before PiM")
    _aap(mc, src, ROW_T0, "ambit src->T0")
    _aap(mc, dst, ROW_T1, "ambit dst->T1")
    _aap(mc, ROW_CTRL, ROW_T2, f"ambit C{0 if op == 'and' else 1}->T2")
    ok = mc.device.ambit_bitwise(src, dst, op)
    # TRA: all three B-group wordlines raised at once; charge sharing
    # settles to MAJ(T0, T1, T2), restored over a full spec tRAS.
    mc._issue(Cmd.ACT, ROW_T0, 0.0, "ambit TRA T0/T1/T2")
    mc._issue(Cmd.PRE, ROW_T0, mc.t.tRAS, "ambit TRA restore")
    mc.now_ns += mc.t.tRP
    _aap(mc, ROW_T0, dst, "ambit T0->dst")
    return SequenceResult(mc.now_ns - t0, mc.trace[cmds_start:], ok=ok)


def _seq_ambit_and(mc: MemoryController, src: int, dst: int) -> SequenceResult:
    return _seq_ambit_bitwise(mc, src, dst, "and")


def _seq_ambit_or(mc: MemoryController, src: int, dst: int) -> SequenceResult:
    return _seq_ambit_bitwise(mc, src, dst, "or")


def _seq_ambit_not(mc: MemoryController, src: int, dst: int) -> SequenceResult:
    """Ambit NOT: activate src against the dual-contact cell (couples the
    negated value into the DCC row), then AAP the DCC row over dst."""
    t0 = mc.now_ns
    cmds_start = len(mc.trace)
    mc._close_open_row("close before PiM")
    _aap(mc, src, ROW_DCC, "ambit src->DCC")
    ok = mc.device.ambit_not(src, dst)
    _aap(mc, ROW_DCC, dst, "ambit DCC->dst")
    return SequenceResult(mc.now_ns - t0, mc.trace[cmds_start:], ok=ok)


def _seq_drange_read(mc: MemoryController, row: int, n_bits: int) -> SequenceResult:
    """D-RaNGe: ACT with violated tRCD, immediate RD, sample metastable cells."""
    t0 = mc.now_ns
    cmds_start = len(mc.trace)
    mc._close_open_row("close before PiM")
    mc._issue(Cmd.ACT, row, 0.0, "drange ACT")
    mc._issue(Cmd.RD, row, mc.v.tRCD_viol, "violated tRCD read")
    bits = mc.device.drange_read(row, n_bits)
    mc.now_ns += mc.t.tCL + mc.t.tBL          # data return
    mc._issue(Cmd.PRE, row, mc.t.tRAS, "restore row")
    mc.now_ns += mc.t.tRP
    return SequenceResult(mc.now_ns - t0, mc.trace[cmds_start:], ok=True, data=bits)


# ---------------------------------------------------------------------- #
# End-to-end analytical paths (used by benchmarks/paper_tables.py)
# ---------------------------------------------------------------------- #


@dataclass
class EndToEndCosts:
    """End-to-end latency model for the four paper comparisons (one row)."""

    mc: MemoryController

    def cpu_copy_ns(self) -> float:
        return self.mc.memcpy_ns(self.mc.proto.row_bytes)

    def cpu_init_ns(self) -> float:
        return self.mc.memset_ns(self.mc.proto.row_bytes)

    def rowclone_copy_ns(self, coherent: bool = False) -> float:
        seq = _sequence_time_only(self.mc, "rowclone_copy")
        total = self.mc.poc_handshake_ns() + seq
        if coherent:
            total += self.mc.clflush_ns(self.mc.proto.row_bytes)
        return total

    def rowclone_init_ns(self, coherent: bool = False) -> float:
        # Initialization = RowClone copy from a reserved all-zeros row.
        seq = _sequence_time_only(self.mc, "rowclone_copy")
        total = self.mc.poc_handshake_ns() + seq
        if coherent:
            total += self.mc.clinval_ns(self.mc.proto.row_bytes)
        return total

    def speedups(self) -> Dict[str, float]:
        return {
            "copy_no_coherence": self.cpu_copy_ns() / self.rowclone_copy_ns(False),
            "init_no_coherence": self.cpu_init_ns() / self.rowclone_init_ns(False),
            "copy_coherence": self.cpu_copy_ns() / self.rowclone_copy_ns(True),
            "init_coherence": self.cpu_init_ns() / self.rowclone_init_ns(True),
        }

    # Batched dispatch (one POC handshake amortized over n row ops) ------ #

    def rowclone_copy_batched_ns(self, n: int, coherent: bool = False) -> float:
        """End-to-end cost of an n-row batched RowClone copy: one POC
        handshake + n command sequences (+ per-row coherence flushes)."""
        seq = _sequence_time_only(self.mc, "rowclone_copy")
        total = self.mc.poc_handshake_ns() + n * seq
        if coherent:
            total += n * self.mc.clflush_ns(self.mc.proto.row_bytes)
        return total

    def rowclone_init_batched_ns(self, n: int, coherent: bool = False) -> float:
        seq = _sequence_time_only(self.mc, "rowclone_copy")
        total = self.mc.poc_handshake_ns() + n * seq
        if coherent:
            total += n * self.mc.clinval_ns(self.mc.proto.row_bytes)
        return total

    def batched_speedups(self, n: int) -> Dict[str, float]:
        """Per-row speedups for an n-row batch vs the CPU moving the same
        n rows.  At n=1 this matches :meth:`speedups`; as n grows the
        handshake amortizes toward the pure command-sequence bound."""
        cpu_copy = n * self.cpu_copy_ns()
        cpu_init = n * self.cpu_init_ns()
        return {
            "copy_no_coherence": cpu_copy / self.rowclone_copy_batched_ns(n, False),
            "init_no_coherence": cpu_init / self.rowclone_init_batched_ns(n, False),
            "copy_coherence": cpu_copy / self.rowclone_copy_batched_ns(n, True),
            "init_coherence": cpu_init / self.rowclone_init_batched_ns(n, True),
        }

    # Ambit bulk bitwise ------------------------------------------------ #

    def cpu_bitwise_ns(self) -> float:
        return self.mc.bitwise_ns(self.mc.proto.row_bytes)

    def cpu_scan_ns(self) -> float:
        return self.mc.scan_ns(self.mc.proto.row_bytes)

    def ambit_bitwise_ns(self, op: str = "and", coherent: bool = False) -> float:
        """One in-DRAM bitwise row op: POC handshake + the TRA command
        sequence (4 AAPs + 1 TRA for AND/OR, 2 AAPs for NOT)."""
        seq = _sequence_time_only(self.mc, f"ambit_{op}")
        total = self.mc.poc_handshake_ns() + seq
        if coherent:
            # both operand rows may hold dirty cache lines
            total += 2 * self.mc.clflush_ns(self.mc.proto.row_bytes)
        return total

    def ambit_bitwise_batched_ns(self, n: int, op: str = "and",
                                 coherent: bool = False) -> float:
        seq = _sequence_time_only(self.mc, f"ambit_{op}")
        total = self.mc.poc_handshake_ns() + n * seq
        if coherent:
            total += 2 * n * self.mc.clflush_ns(self.mc.proto.row_bytes)
        return total

    def zero_scan_batched_ns(self, n: int) -> float:
        """Zero-compare scan of n rows: OR-reduce the candidates into a
        B-group scratch row (n ambit_or sequences, one handshake), then
        one CPU pass over the single result row."""
        seq = _sequence_time_only(self.mc, "ambit_or")
        return (self.mc.poc_handshake_ns() + n * seq
                + self.mc.scan_ns(self.mc.proto.row_bytes))

    # D-RaNGe ----------------------------------------------------------- #

    def drange_latency_ns(self) -> float:
        return self.mc.proto.drange_latency_ns

    def drange_throughput_mbps(self) -> float:
        bits = self.mc.proto.drange_bits_per_read
        return bits / self.mc.proto.drange_sustained_ns * 1e3  # ns -> Mb/s


def _sequence_time_only(mc: MemoryController, name: str) -> float:
    """Run a sequence on a scratch clock to get its isolated duration."""
    probe = MemoryController(mc.device, mc.t, mc.v, mc.proto)
    # Rows 0 -> 0; timing is row-independent.  Most sequences are a data
    # no-op on src == dst (copy, AND, OR), but e.g. ambit_not is not —
    # restore the probe row so costing never perturbs device contents.
    saved = mc.device.read_row(0)
    res = probe.run_sequence(name, 0, 0)
    mc.device.write_row(0, saved)
    return res.elapsed_ns

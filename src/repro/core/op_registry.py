"""Opcode-keyed PiM op registry — pimolib v2's single extension point.

Every PiM operation the framework knows is one :class:`PimOpSpec` keyed
by its :class:`repro.core.isa.Opcode`, carrying per-face executors:

* **model face** (``"device"``): ``device_seq`` names the
  :class:`repro.core.memctrl.MemoryController` command sequence the POC
  runs when it decodes this opcode, and ``device_insns`` builds the
  :class:`Instruction` list a :class:`repro.core.pimolib.DeviceLib` call
  stages in the POC instruction buffer.  ``poc_post`` (optional) runs on
  the POC after the sequence (e.g. D-RaNGe deposits generated bits into
  the random-number buffer).

* **JAX face** (``"jax"``): ``jax_kind`` + ``jax_flush`` register a
  deferred op kind on every :class:`repro.core.pim_queue.PimOpQueue`,
  flushed as one coalesced Pallas/XLA launch per kind.

Registering a new PiM op is ONE :func:`register_pim_op` call plus its
executors on whichever faces support it — the software mirror of the
paper's "60 additional lines of Verilog" extensibility argument.  Faces
a spec does not implement are visible through :func:`supports`, so
callers degrade gracefully (``KV_WRITE`` has no DDR3 command sequence;
the model face accounts it as a CPU write instead).

Worked example — registering "Ambit-XOR" (an in-DRAM bitwise XOR)
---------------------------------------------------------------------

The Ambit AND/OR/NOT triple graduated to built-in specs (``AMB_AND`` /
``AMB_OR`` / ``AMB_NOT`` below) — this walkthrough registers the next
op up, SIMDRAM-style XOR, and is runnable (CI executes it via ``pytest
--doctest-modules``).  Pick an unused opcode value (add a real member to
:class:`repro.core.isa.Opcode` when upstreaming; a plain int serves the
demo), write a JAX-face flush executor, and register:

>>> from repro.core.op_registry import (PimOpSpec, register_pim_op,
...                                     unregister_pim_op, get_op,
...                                     supports)
>>> AMB_XOR = 0x40                        # unused opcode value
>>> def _flush_xor(q, arenas, ops):
...     # ONE coalesced launch for the whole pending batch (a real op
...     # dispatches its Pallas kernel over `arenas` here and returns
...     # the updated buffers)
...     q._count_launch("page_xor", 1)
...     return arenas
>>> _ = register_pim_op(PimOpSpec(
...     opcode=AMB_XOR, name="ambit_xor",
...     jax_kind="page_xor", jax_flush=_flush_xor))

Capability flags answer per face — no ``device_seq`` was given, so the
model face reports the op unsupported and callers fall back gracefully:

>>> supports(AMB_XOR, "jax"), supports(AMB_XOR, "device")
(True, False)
>>> get_op(AMB_XOR).name
'ambit_xor'

Every :class:`repro.core.pim_queue.PimOpQueue` built after registration
knows the new kind and coalesces it exactly like the built-ins:

>>> from repro.core.pim_queue import PimOpQueue
>>> q = PimOpQueue()
>>> q.enqueue("page_xor", (3, 5)); q.enqueue("page_xor", (4, 6))
>>> _ = q.flush()                         # both ops, one launch
>>> q.launches_by_kind["page_xor"], q.stats["ops_enqueued"]
(1, 2)

A real op stays registered, of course — the demo tidies up with the
public inverse so this example is re-runnable and later-built queues
don't carry it:

>>> unregister_pim_op(AMB_XOR).name
'ambit_xor'
>>> supports(AMB_XOR, "jax")
False

To light up the model face too, add two fields to the spec:
``device_seq`` naming the :class:`repro.core.memctrl.MemoryController`
command sequence the POC runs when it decodes the opcode, and
``device_insns`` building the :class:`Instruction` batch a
:class:`repro.core.pimolib.DeviceLib` call stages (see the built-in
``RC_COPY`` and ``AMB_AND`` specs at the bottom of this module for the
shape).  ``examples/quickstart.py`` tours the resulting protocol end to
end on both faces.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ambit import ops as amb_ops
from repro.kernels.rowclone import ops as rc_ops

from .isa import Instruction, Opcode

FACE_DEVICE = "device"
FACE_JAX = "jax"


@dataclass(frozen=True)
class PimOpSpec:
    """One PiM op: opcode + per-face executors (None = face
    unsupported).  The fields below are everything a new technique
    needs; the module docstring walks a registration end to end."""

    opcode: Opcode
    name: str                                  # OpReceipt.op on every face
    device_seq: Optional[str] = None           # MemoryController sequence
    device_insns: Optional[Callable] = None    # (lib, *operands) -> [Instruction]
    poc_post: Optional[Callable] = None        # (poc, SequenceResult) -> None
    jax_kind: Optional[str] = None             # PimOpQueue kind name
    jax_flush: Optional[Callable] = None       # FlushFn (see pim_queue)
    jax_direct: bool = False                   # JAX-face op dispatched directly
                                               # (own kernel, no queue kind) —
                                               # e.g. D-RaNGe generation

    def supports(self, face: str) -> bool:
        if face == FACE_DEVICE:
            return self.device_seq is not None
        if face == FACE_JAX:
            return self.jax_kind is not None or self.jax_direct
        raise ValueError(f"unknown face {face!r}")


_REGISTRY: Dict[Opcode, PimOpSpec] = {}


def register_pim_op(spec: PimOpSpec, *, override: bool = False) -> PimOpSpec:
    """Register ``spec`` as THE implementation of its opcode — the one
    extension point for new PiM techniques (see the worked Ambit-AND
    example in the module docstring).  Queues built afterwards pick up
    the spec's JAX kind automatically; ``override=True`` replaces an
    existing registration (tests), otherwise a duplicate opcode is an
    error.  Returns the spec for assignment convenience."""
    if spec.opcode in _REGISTRY and not override:
        raise ValueError(f"opcode {spec.opcode!r} already registered "
                         f"as {_REGISTRY[spec.opcode].name!r}")
    if (spec.jax_kind is None) != (spec.jax_flush is None):
        raise ValueError("jax_kind and jax_flush must be given together")
    _REGISTRY[spec.opcode] = spec
    return spec


def unregister_pim_op(opcode: Opcode) -> Optional[PimOpSpec]:
    """Remove and return the spec registered for ``opcode`` (None if it
    was not registered) — the public inverse of :func:`register_pim_op`
    for tests, doctests, and plug-in teardown.  Queues built while the
    op was live keep their kind registration (flushing an already-empty
    kind is a no-op); queues built afterwards don't see it."""
    return _REGISTRY.pop(opcode, None)


def get_op(opcode: Opcode) -> Optional[PimOpSpec]:
    return _REGISTRY.get(opcode)


def ops_for_face(face: str) -> List[PimOpSpec]:
    return [s for s in _REGISTRY.values() if s.supports(face)]


def supports(opcode: Opcode, face: str) -> bool:
    spec = _REGISTRY.get(opcode)
    return spec is not None and spec.supports(face)


def queue_kinds() -> List[Tuple[str, Callable]]:
    """(kind, flush_fn) pairs every new PimOpQueue registers at birth,
    in registry insertion order."""
    return [(s.jax_kind, s.jax_flush) for s in _REGISTRY.values()
            if s.jax_kind is not None]


# ---------------------------------------------------------------------- #
# Model-face executors: Instruction builders + POC post hooks
# ---------------------------------------------------------------------- #


def _insns_rc_copy(lib, src, dst) -> List[Instruction]:
    return [Instruction(Opcode.RC_COPY, s, d)
            for s, d in zip(src.rows, dst.rows)]


def _insns_bulk_copy(lib, src, dst) -> List[Instruction]:
    return [Instruction(Opcode.BULK_COPY, s, d)
            for s, d in zip(src.rows, dst.rows)]


def _insns_rc_init(lib, src, dst) -> List[Instruction]:
    # src is unused: RowClone-Init copies the reserved all-zeros row of
    # the destination's subarray over each destination row.
    zero = lib.reserve_zero_row(dst.group)
    return [Instruction(Opcode.RC_INIT, zero, d) for d in dst.rows]


def _make_insns_ambit(opcode: Opcode) -> Callable:
    """Instruction builder for the two-operand Ambit ops
    (operand0 = src row, operand1 = dst row; dst <- src OP dst)."""
    def _build(lib, src, dst) -> List[Instruction]:
        return [Instruction(opcode, s, d) for s, d in zip(src.rows, dst.rows)]
    return _build


def _poc_deposit_rng(poc, res) -> None:
    """D-RaNGe post hook: sampled bits land in the POC RNG buffer."""
    if res.data is not None:
        for b in res.data:
            poc.rng_buffer.append(int(b))


# ---------------------------------------------------------------------- #
# JAX-face executors: PimOpQueue flush functions (one coalesced launch
# per kind per arena).  ``q`` is the flushing PimOpQueue (duck-typed:
# only ``use_pallas`` and ``_count_launch`` are touched).
# ---------------------------------------------------------------------- #


@dataclass
class KVWriteBatch:
    """Pending slot writes: full-depth K/V for a batch of tokens,
    kept stacked as (layers, batch, ...) so enqueue/flush do O(1) host
    work in the batch size (no per-token slicing or re-stacking)."""

    pages: List[int]
    slots: List[int]
    k: jax.Array      # (layers, batch, kvh, hd)
    v: jax.Array

    @property
    def n(self) -> int:
        return len(self.pages)


def _flush_page_copy(q, arenas, ops):
    src = jnp.asarray([s for s, _ in ops], jnp.int32)
    dst = jnp.asarray([d for _, d in ops], jnp.int32)
    arenas = tuple(rc_ops.pim_page_copy_batched(a, src, dst,
                                                use_pallas=q.use_pallas)
                   for a in arenas)
    q._count_launch("page_copy", len(arenas))
    return arenas


def group_inits_by_value(ops) -> Dict[float, List[int]]:
    """(page, value) records -> {value: pages}: the one-launch-per-
    distinct-fill-value contract, shared by the flush executor and the
    trace recorder so recorded events always match actual launches."""
    by_value: Dict[float, List[int]] = {}
    for page, value in ops:
        by_value.setdefault(value, []).append(page)
    return by_value


def _flush_page_init(q, arenas, ops):
    # one launch per arena per distinct value (in practice a single 0.0
    # group — the calloc analogue)
    for value, pages in group_inits_by_value(ops).items():
        dst = jnp.asarray(pages, jnp.int32)
        arenas = tuple(rc_ops.pim_page_init_batched(a, dst, value,
                                                    use_pallas=q.use_pallas)
                       for a in arenas)
        q._count_launch("page_init", len(arenas))
    return arenas


def _make_flush_bitwise(op: str, kind: str) -> Callable:
    """Flush executor for the Ambit bitwise kinds: one coalesced
    layer-batched launch per arena for the whole pending (src, dst)
    batch (dst <- src OP dst elementwise on bit patterns)."""
    def _flush(q, arenas, ops):
        src = jnp.asarray([s for s, _ in ops], jnp.int32)
        dst = jnp.asarray([d for _, d in ops], jnp.int32)
        arenas = tuple(amb_ops.pim_page_bitwise_batched(
            a, src, dst, op=op, use_pallas=q.use_pallas) for a in arenas)
        q._count_launch(kind, len(arenas))
        return arenas
    return _flush


@dataclass
class StateWriteBatch:
    """Pending SSM recurrent-state writes: full-depth conv + SSD state
    for a batch of sequences, stacked as (groups, mamba_sublayers,
    batch, ...) so enqueue/flush do O(1) host work in the batch size."""

    rows: List[int]   # state-arena rows, one per batch entry
    conv: jax.Array   # (G, M, B, conv_width-1, channels)
    ssm: jax.Array    # (G, M, B, nheads, head_dim, state_dim)

    @property
    def n(self) -> int:
        return len(self.rows)


def _flush_ssm_state_write(q, arenas, ops: List[StateWriteBatch]):
    """Registry default: SSM state lives in a dedicated state arena, not
    the (k, v) arena pair a generic queue flushes — a serving cache
    rebinds this kind to an arena-bound closure via
    ``queue.register_kind`` (see serving.kv_cache.PagedStateArena)."""
    raise RuntimeError(
        "ssm_state_write ops were enqueued on a queue with no bound "
        "state arena; rebind the kind via queue.register_kind(...)")


def _flush_kv_write(q, arenas, ops: List[KVWriteBatch]):
    assert len(arenas) == 2, "kv_write flushes a (k, v) arena pair"
    k_arena, v_arena = arenas
    pages = jnp.asarray([p for o in ops for p in o.pages], jnp.int32)
    slots = jnp.asarray([s for o in ops for s in o.slots], jnp.int32)
    if len(ops) == 1:              # the common case: already stacked
        k_new, v_new = ops[0].k, ops[0].v
    else:
        k_new = jnp.concatenate([o.k for o in ops], axis=1)  # (L, B, ...)
        v_new = jnp.concatenate([o.v for o in ops], axis=1)
    k_arena = rc_ops.pim_kv_scatter(k_arena, pages, slots,
                                    k_new.astype(k_arena.dtype),
                                    use_pallas=q.use_pallas)
    v_arena = rc_ops.pim_kv_scatter(v_arena, pages, slots,
                                    v_new.astype(v_arena.dtype),
                                    use_pallas=q.use_pallas)
    q._count_launch("kv_write", 2)
    return (k_arena, v_arena)


# ---------------------------------------------------------------------- #
# Built-in ops (the paper's case studies + the serving KV scatter)
# ---------------------------------------------------------------------- #

register_pim_op(PimOpSpec(
    opcode=Opcode.RC_COPY, name="rowclone_copy",
    device_seq="rowclone_copy", device_insns=_insns_rc_copy,
    jax_kind="page_copy", jax_flush=_flush_page_copy))

register_pim_op(PimOpSpec(
    opcode=Opcode.RC_INIT, name="rowclone_init",
    device_seq="rowclone_copy", device_insns=_insns_rc_init,
    jax_kind="page_init", jax_flush=_flush_page_init))

register_pim_op(PimOpSpec(
    opcode=Opcode.BULK_COPY, name="rowclone_bulk_copy",
    device_seq="rowclone_copy", device_insns=_insns_bulk_copy))

register_pim_op(PimOpSpec(
    opcode=Opcode.DR_GEN, name="drange_rand",
    device_seq="drange_read", poc_post=_poc_deposit_rng,
    jax_direct=True))   # TpuLib.rand dispatches the D-RaNGe kernel itself

# JAX-face only: slot-granular KV scatter has no violated-timing DDR3
# sequence — the model face reports it unsupported (graceful fallback to
# the CPU write path, see serving.trace.replay_on_device).
register_pim_op(PimOpSpec(
    opcode=Opcode.KV_WRITE, name="kv_write",
    jax_kind="kv_write", jax_flush=_flush_kv_write))

# JAX-face only: the constant-size SSM recurrent-state scatter (paged
# hybrid serving).  The default flush demands an arena-bound rebind, so
# the registration here is mostly the capability flag: the model face
# reports the op unsupported and DeviceLib callers fall back to the CPU
# write path, exactly like KV_WRITE.
register_pim_op(PimOpSpec(
    opcode=Opcode.SSM_STATE_WRITE, name="ssm_state_write",
    jax_kind="ssm_state_write", jax_flush=_flush_ssm_state_write))

# Ambit bulk bitwise (Seshadri et al., MICRO'17).  Model face: TRA
# command sequences against the B-group compute rows (same-subarray
# constraint, like RowClone).  JAX face: layer-batched Pallas bitwise
# kernels over arena pages.
register_pim_op(PimOpSpec(
    opcode=Opcode.AMB_AND, name="ambit_and",
    device_seq="ambit_and", device_insns=_make_insns_ambit(Opcode.AMB_AND),
    jax_kind="page_and", jax_flush=_make_flush_bitwise("and", "page_and")))

register_pim_op(PimOpSpec(
    opcode=Opcode.AMB_OR, name="ambit_or",
    device_seq="ambit_or", device_insns=_make_insns_ambit(Opcode.AMB_OR),
    jax_kind="page_or", jax_flush=_make_flush_bitwise("or", "page_or")))

register_pim_op(PimOpSpec(
    opcode=Opcode.AMB_NOT, name="ambit_not",
    device_seq="ambit_not", device_insns=_make_insns_ambit(Opcode.AMB_NOT),
    jax_kind="page_not", jax_flush=_make_flush_bitwise("not", "page_not")))

"""DDR3 timing parameters and violated-timing command sequences.

This module is the timing vocabulary of the PiDRAM memory-controller model
(`repro.core.memctrl`).  All parameters default to the values of the PiDRAM
FPGA prototype (Xilinx ZC706, Rocket @ 50 MHz, DDR3-800 SO-DIMM, 64-bit bus,
8 KB rows) as described in the paper and its extended arXiv version.

Two kinds of sequences are expressed here:

* **Standard sequences** honour manufacturer-recommended timings
  (tRCD, tRAS, tRP, tCL, ...).
* **Violated sequences** shrink selected parameters far below spec — the
  physical mechanism of commodity-DRAM PiM (RowClone via ComputeDRAM
  ACT->PRE->ACT, D-RaNGe via tRCD violation).

All times are expressed in nanoseconds; the memory controller model converts
to CPU cycles where needed.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class DDR3Timings:
    """Manufacturer-recommended DDR3-800 timing parameters (ns)."""

    tCK: float = 2.5        # DRAM bus clock period (400 MHz IO clock)
    tRCD: float = 13.75     # ACT -> column command
    tRAS: float = 35.0      # ACT -> PRE (row restore)
    tRP: float = 13.75      # PRE -> next ACT
    tCL: float = 13.75      # read CAS latency
    tCWL: float = 10.0      # write CAS latency
    tBL: float = 10.0       # burst of 8 on 64-bit bus = 64 bytes
    tCCD: float = 10.0      # column-to-column
    tWR: float = 15.0       # write recovery
    tRFC: float = 160.0     # refresh cycle
    tREFI: float = 7800.0   # refresh interval

    @property
    def tRC(self) -> float:
        """Row cycle: back-to-back ACTs to the same bank."""
        return self.tRAS + self.tRP

    def scaled(self, **overrides: float) -> "DDR3Timings":
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class ViolatedTimings:
    """Reduced timing parameters used by PiM command sequences.

    ComputeDRAM-style RowClone issues ACT -> PRE -> ACT where the gaps
    t1 (ACT->PRE) and t2 (PRE->ACT) are just 1-2 bus cycles, far below
    tRAS/tRP.  D-RaNGe issues a column read only ~1 cycle after ACT,
    far below tRCD, sampling cells mid-sense-amplification.
    """

    t1_act_pre: float = 2.5    # RowClone: ACT->PRE gap (violates tRAS)
    t2_pre_act: float = 2.5    # RowClone: PRE->ACT gap (violates tRP)
    tRCD_viol: float = 2.5     # D-RaNGe: ACT->RD gap (violates tRCD)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclass(frozen=True)
class PrototypeParams:
    """Calibrated cost parameters of the PiDRAM FPGA prototype.

    The CPU-side parameters are calibrated once against the paper's
    reported end-to-end numbers (118.5x / 88.7x / 14.6x / 12.6x for
    RowClone and 220 ns / 8.30 Mb/s for D-RaNGe) and then *frozen*; the
    benchmark suite computes every paper number forward from this single
    parameter set.  Calibration rationale (see DESIGN.md SS5):

    * Rocket is a 50 MHz in-order core: byte-moving loops cost ~2-3
      cycles per 8-byte word, DRAM miss stalls are only a few CPU cycles
      because the CPU clock is 8x slower than the DRAM bus clock.
    * MMIO accesses to the POC's uncached registers cross the TileLink
      fabric: ~7 CPU cycles each.
    * CLFLUSH-style writebacks are pipelined by the memory controller and
      bounded by DRAM write bandwidth, ~35 ns per 64-byte block.
    """

    cpu_freq_hz: float = 50e6            # Rocket chip on ZC706
    row_bytes: int = 8192                # one DRAM row (= one page operand)
    cacheline_bytes: int = 64
    word_bytes: int = 8                  # RV64 load/store width

    # memcpy: ld + sd + amortized loop control, per 8-byte word (cycles)
    memcpy_cycles_per_word: float = 2.5
    # memset/calloc zeroing loop, per 8-byte word (cycles)
    memset_cycles_per_word: float = 2.148
    # bitwise read-modify-write loop (2 ld + op + sd), per word (cycles)
    bitwise_cycles_per_word: float = 3.6
    # zero-compare scan loop (ld + cmp + branch), per word (cycles)
    scan_cycles_per_word: float = 2.25
    # additional CPU stall per cache miss (cycles @ 50 MHz)
    miss_stall_cycles: float = 4.5
    # MMIO register access to POC (cycles)
    mmio_store_cycles: float = 6.5
    mmio_load_cycles: float = 6.5
    # pimolib call + supervisor syscall overhead (cycles)
    syscall_cycles: float = 2.6
    # coherence ops, per 64-byte cache block (ns)
    clflush_ns_per_block: float = 34.84  # dirty writeback (copy source)
    clinval_ns_per_block: float = 29.53  # invalidate (init destination)

    # D-RaNGe pipeline
    drange_bits_per_read: int = 4        # RNG cells harvested per access
    drange_latency_ns: float = 220.0     # first 4 bits (ACT_viol+RD+MMIO)
    drange_sustained_ns: float = 482.0   # steady-state per 4-bit chunk

    @property
    def cycle_ns(self) -> float:
        return 1e9 / self.cpu_freq_hz

    @property
    def lines_per_row(self) -> int:
        return self.row_bytes // self.cacheline_bytes

    @property
    def words_per_row(self) -> int:
        return self.row_bytes // self.word_bytes


DEFAULT_TIMINGS = DDR3Timings()
DEFAULT_VIOLATIONS = ViolatedTimings()
DEFAULT_PROTOTYPE = PrototypeParams()

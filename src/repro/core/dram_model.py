"""Simulated DRAM device with subarray structure and PiM cell physics.

PiDRAM operates on *real* DDR3 chips whose internal organization
(row->subarray mapping, per-cell reliability under violated timings) is
proprietary and chip-specific.  This module provides the software stand-in
for that device so the framework's system layers (subarray discovery,
allocator, POC, D-RaNGe characterization) operate against the same opaque
interface they would have on hardware:

* rows grouped into subarrays with a *hidden, scrambled* row->subarray map
  (the framework must discover it, exactly like on a real chip);
* RowClone (ACT->PRE->ACT) succeeds **iff** source and destination rows sit
  in the same subarray (charge sharing happens over shared bitlines and
  sense amplifiers; rows in different subarrays do not share them);
* D-RaNGe: under violated tRCD each cell fails with a fixed per-cell
  probability; most cells are deterministic (p ~ 0 or ~ 1), a small
  fraction are metastable (p ~ 0.5) — the "RNG cells" that D-RaNGe
  characterizes and harvests.

The model is deliberately numpy-based (it is a device model, not a
differentiable program).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class DRAMGeometry:
    num_subarrays: int = 64
    rows_per_subarray: int = 512
    row_bytes: int = 8192

    @property
    def num_rows(self) -> int:
        return self.num_subarrays * self.rows_per_subarray

    @property
    def total_bytes(self) -> int:
        return self.num_rows * self.row_bytes


@dataclass
class CellPhysics:
    """Per-cell activation-failure behaviour under violated tRCD.

    ``rng_cell_fraction`` of cells are metastable with failure probability
    drawn near 0.5; the rest are deterministic.  Matches the qualitative
    characterization in D-RaNGe (Kim et al., HPCA'19): cells are
    overwhelmingly deterministic, with a sparse population of true-random
    cells whose behaviour is stable across time but spatially random.
    """

    rng_cell_fraction: float = 0.004
    rng_prob_low: float = 0.40
    rng_prob_high: float = 0.60
    deterministic_flip_fraction: float = 0.03  # cells that always fail


class SimulatedDRAM:
    """A simulated DDR3 device exposing PiM-relevant behaviours.

    Only row-granularity data movement is modelled with real data (that is
    what RowClone needs); column reads model D-RaNGe's bit sampling.
    """

    def __init__(
        self,
        geometry: DRAMGeometry = DRAMGeometry(),
        physics: CellPhysics = CellPhysics(),
        seed: int = 0xD12A,
    ) -> None:
        self.geometry = geometry
        self.physics = physics
        self._rng = np.random.default_rng(seed)

        # Hidden row -> subarray map.  Real chips scramble row addresses;
        # we emulate that with a keyed permutation of row indices so that
        # consecutive physical row numbers are NOT guaranteed to share a
        # subarray (the discovery methodology has to cope with this).
        perm = self._rng.permutation(geometry.num_rows)
        self._row_to_subarray = perm % geometry.num_subarrays

        # Backing store, row-major.
        self._data = np.zeros((geometry.num_rows, geometry.row_bytes), np.uint8)

        # D-RaNGe cell physics: per-cell failure probability for the first
        # `drange_region_bytes` of each row (characterizing the whole device
        # would be slow and is unnecessary for the case study).
        self.drange_region_bytes = 128
        n_cells = geometry.num_rows * self.drange_region_bytes * 8
        u = self._rng.random(n_cells, dtype=np.float32)
        probs = np.zeros(n_cells, dtype=np.float32)
        det_flip = u < physics.deterministic_flip_fraction
        probs[det_flip] = 1.0
        is_rng = (u >= physics.deterministic_flip_fraction) & (
            u < physics.deterministic_flip_fraction + physics.rng_cell_fraction
        )
        probs[is_rng] = self._rng.uniform(
            physics.rng_prob_low, physics.rng_prob_high, int(is_rng.sum())
        ).astype(np.float32)
        self._fail_prob = probs.reshape(
            geometry.num_rows, self.drange_region_bytes * 8
        )

    # ------------------------------------------------------------------ #
    # Introspection (test-only; the framework must not peek)
    # ------------------------------------------------------------------ #

    def _true_subarray_of(self, row: int) -> int:
        return int(self._row_to_subarray[row])

    # ------------------------------------------------------------------ #
    # Standard DRAM operation
    # ------------------------------------------------------------------ #

    def read_row(self, row: int) -> np.ndarray:
        return self._data[row].copy()

    def write_row(self, row: int, payload: np.ndarray) -> None:
        assert payload.shape == (self.geometry.row_bytes,)
        self._data[row] = payload

    # ------------------------------------------------------------------ #
    # PiM operations (issued by the memory controller with violated
    # timings; success/behaviour is governed by device physics)
    # ------------------------------------------------------------------ #

    def rowclone(self, src_row: int, dst_row: int) -> bool:
        """ACT(src) -> PRE -> ACT(dst) with violated tRAS/tRP.

        Returns True when the copy actually happened (same subarray).
        When rows are in different subarrays the destination row's charge
        is restored by its own sense amplifiers and the data is unchanged
        — exactly the observable failure mode used by the paper's
        subarray-discovery methodology.
        """
        if self._row_to_subarray[src_row] != self._row_to_subarray[dst_row]:
            return False
        self._data[dst_row] = self._data[src_row]
        return True

    def drange_read(self, row: int, n_bits: int, rng: Optional[np.random.Generator] = None) -> np.ndarray:
        """Read ``n_bits`` cells of ``row`` with violated tRCD.

        Each sampled bit equals the stored bit XOR a Bernoulli(fail_prob)
        failure.  Rows under test are written with a known pattern by the
        characterization pass, so failures are observable.
        """
        rng = rng or self._rng
        n_bits = min(n_bits, self.drange_region_bytes * 8)
        stored = np.unpackbits(self._data[row, : self.drange_region_bytes])[:n_bits]
        flips = rng.random(n_bits) < self._fail_prob[row, :n_bits]
        return (stored ^ flips.astype(np.uint8)).astype(np.uint8)


@dataclass
class DeviceHandle:
    """What the rest of the framework sees: an opaque device + geometry."""

    device: SimulatedDRAM
    geometry: DRAMGeometry = field(init=False)

    def __post_init__(self) -> None:
        self.geometry = self.device.geometry

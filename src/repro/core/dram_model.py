"""Simulated DRAM device with subarray structure and PiM cell physics.

PiDRAM operates on *real* DDR3 chips whose internal organization
(row->subarray mapping, per-cell reliability under violated timings) is
proprietary and chip-specific.  This module provides the software stand-in
for that device so the framework's system layers (subarray discovery,
allocator, POC, D-RaNGe characterization) operate against the same opaque
interface they would have on hardware:

* rows grouped into subarrays with a *hidden, scrambled* row->subarray map
  (the framework must discover it, exactly like on a real chip);
* RowClone (ACT->PRE->ACT) succeeds **iff** source and destination rows sit
  in the same subarray (charge sharing happens over shared bitlines and
  sense amplifiers; rows in different subarrays do not share them);
* D-RaNGe: under violated tRCD each cell fails with a fixed per-cell
  probability; most cells are deterministic (p ~ 0 or ~ 1), a small
  fraction are metastable (p ~ 0.5) — the "RNG cells" that D-RaNGe
  characterizes and harvests.

The model is deliberately numpy-based (it is a device model, not a
differentiable program).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class DRAMGeometry:
    num_subarrays: int = 64
    rows_per_subarray: int = 512
    row_bytes: int = 8192

    @property
    def num_rows(self) -> int:
        return self.num_subarrays * self.rows_per_subarray

    @property
    def total_bytes(self) -> int:
        return self.num_rows * self.row_bytes


@dataclass(frozen=True)
class CellPhysics:
    """Per-cell activation-failure behaviour under violated tRCD.

    ``rng_cell_fraction`` of cells are metastable with failure probability
    drawn near 0.5; the rest are deterministic.  Matches the qualitative
    characterization in D-RaNGe (Kim et al., HPCA'19): cells are
    overwhelmingly deterministic, with a sparse population of true-random
    cells whose behaviour is stable across time but spatially random.

    Frozen: one ``CellPhysics`` may be shared by many devices, so it must
    not carry mutable per-device state.
    """

    rng_cell_fraction: float = 0.004
    rng_prob_low: float = 0.40
    rng_prob_high: float = 0.60
    deterministic_flip_fraction: float = 0.03  # cells that always fail


class SimulatedDRAM:
    """A simulated DDR3 device exposing PiM-relevant behaviours.

    Only row-granularity data movement is modelled with real data (that is
    what RowClone needs); column reads model D-RaNGe's bit sampling.
    """

    def __init__(
        self,
        geometry: Optional[DRAMGeometry] = None,
        physics: Optional[CellPhysics] = None,
        seed: int = 0xD12A,
    ) -> None:
        # Defaults are constructed per call: a single mutable default
        # instance evaluated at def-time would alias state across every
        # default-constructed device.
        self.geometry = geometry = DRAMGeometry() if geometry is None else geometry
        self.physics = physics = CellPhysics() if physics is None else physics
        self._rng = np.random.default_rng(seed)

        # Hidden row -> subarray map.  Real chips scramble row addresses;
        # we emulate that with a keyed permutation of row indices so that
        # consecutive physical row numbers are NOT guaranteed to share a
        # subarray (the discovery methodology has to cope with this).
        perm = self._rng.permutation(geometry.num_rows)
        self._row_to_subarray = perm % geometry.num_subarrays

        # Backing store, row-major.
        self._data = np.zeros((geometry.num_rows, geometry.row_bytes), np.uint8)

        # Ambit B-group: designated compute rows per subarray, *outside*
        # the addressable row space (the allocator can never hand them
        # out).  Slots 0-2 are the triple-row-activation operands
        # T0/T1/T2; slot 3 is the dual-contact-cell (DCC) row used for
        # in-DRAM NOT.  See Seshadri et al., "Ambit" (MICRO'17).
        self._bgroup = np.zeros(
            (geometry.num_subarrays, 4, geometry.row_bytes), np.uint8
        )

        # D-RaNGe cell physics: per-cell failure probability for the first
        # `drange_region_bytes` of each row (characterizing the whole device
        # would be slow and is unnecessary for the case study).
        self.drange_region_bytes = 128
        n_cells = geometry.num_rows * self.drange_region_bytes * 8
        u = self._rng.random(n_cells, dtype=np.float32)
        probs = np.zeros(n_cells, dtype=np.float32)
        det_flip = u < physics.deterministic_flip_fraction
        probs[det_flip] = 1.0
        is_rng = (u >= physics.deterministic_flip_fraction) & (
            u < physics.deterministic_flip_fraction + physics.rng_cell_fraction
        )
        probs[is_rng] = self._rng.uniform(
            physics.rng_prob_low, physics.rng_prob_high, int(is_rng.sum())
        ).astype(np.float32)
        self._fail_prob = probs.reshape(
            geometry.num_rows, self.drange_region_bytes * 8
        )

    # ------------------------------------------------------------------ #
    # Introspection (test-only; the framework must not peek)
    # ------------------------------------------------------------------ #

    def _true_subarray_of(self, row: int) -> int:
        return int(self._row_to_subarray[row])

    # ------------------------------------------------------------------ #
    # Standard DRAM operation
    # ------------------------------------------------------------------ #

    def read_row(self, row: int) -> np.ndarray:
        return self._data[row].copy()

    def write_row(self, row: int, payload: np.ndarray) -> None:
        assert payload.shape == (self.geometry.row_bytes,)
        self._data[row] = payload

    # ------------------------------------------------------------------ #
    # PiM operations (issued by the memory controller with violated
    # timings; success/behaviour is governed by device physics)
    # ------------------------------------------------------------------ #

    def rowclone(self, src_row: int, dst_row: int) -> bool:
        """ACT(src) -> PRE -> ACT(dst) with violated tRAS/tRP.

        Returns True when the copy actually happened (same subarray).
        When rows are in different subarrays the destination row's charge
        is restored by its own sense amplifiers and the data is unchanged
        — exactly the observable failure mode used by the paper's
        subarray-discovery methodology.
        """
        if self._row_to_subarray[src_row] != self._row_to_subarray[dst_row]:
            return False
        self._data[dst_row] = self._data[src_row]
        return True

    def ambit_bitwise(self, src_row: int, dst_row: int, op: str) -> bool:
        """Ambit bulk AND/OR via triple-row activation (TRA).

        The controller stages both operands and a control row into the
        subarray's B-group (T0/T1/T2), simultaneously activates all three,
        and charge sharing drives the bitlines to the *majority* of the
        three cells: MAJ(a, b, 0) = a & b, MAJ(a, b, 1) = a | b.  The
        result is copied back over ``dst_row`` (two-operand in-place
        semantics: dst <- src OP dst).

        Like RowClone, TRA only works over shared bitlines: returns False
        (destination unchanged) when the rows sit in different subarrays.
        """
        if op not in ("and", "or"):
            raise ValueError(f"unknown ambit bitwise op {op!r}")
        sa = self._row_to_subarray[src_row]
        if sa != self._row_to_subarray[dst_row]:
            return False
        t = self._bgroup[int(sa)]
        t[0] = self._data[src_row]                    # AAP src -> T0
        t[1] = self._data[dst_row]                    # AAP dst -> T1
        t[2] = 0x00 if op == "and" else 0xFF          # AAP C0/C1 -> T2
        maj = (t[0] & t[1]) | (t[0] & t[2]) | (t[1] & t[2])
        t[0] = t[1] = t[2] = maj                      # TRA: all three rows
        self._data[dst_row] = maj                     # AAP T0 -> dst
        return True

    def ambit_not(self, src_row: int, dst_row: int) -> bool:
        """Ambit NOT via the dual-contact-cell (DCC) row: activating the
        source row with the DCC's negated wordline couples the inverted
        value into the DCC cell; copying the DCC row out yields ~src.
        Same-subarray constraint applies (shared bitlines)."""
        sa = self._row_to_subarray[src_row]
        if sa != self._row_to_subarray[dst_row]:
            return False
        t = self._bgroup[int(sa)]
        t[3] = ~self._data[src_row]                   # ACT src couples DCC
        self._data[dst_row] = t[3]                    # AAP DCC -> dst
        return True

    def drange_read(self, row: int, n_bits: int, rng: Optional[np.random.Generator] = None) -> np.ndarray:
        """Read ``n_bits`` cells of ``row`` with violated tRCD.

        Each sampled bit equals the stored bit XOR a Bernoulli(fail_prob)
        failure.  Rows under test are written with a known pattern by the
        characterization pass, so failures are observable.
        """
        rng = rng or self._rng
        n_bits = min(n_bits, self.drange_region_bytes * 8)
        stored = np.unpackbits(self._data[row, : self.drange_region_bytes])[:n_bits]
        flips = rng.random(n_bits) < self._fail_prob[row, :n_bits]
        return (stored ^ flips.astype(np.uint8)).astype(np.uint8)


@dataclass
class DeviceHandle:
    """What the rest of the framework sees: an opaque device + geometry."""

    device: SimulatedDRAM
    geometry: DRAMGeometry = field(init=False)

    def __post_init__(self) -> None:
        self.geometry = self.device.geometry

"""D-RaNGe: DRAM-latency true random number generation, end to end.

Implements the paper's second case study as a full pipeline over the
simulated device + POC:

  1. **Characterization**: write known patterns, sample every candidate
     cell many times under violated tRCD, estimate per-cell failure
     probability, select *RNG cells* (p in [lo, hi] around 0.5).
  2. **Generation**: repeatedly issue DR_GEN instructions on rows that
     contain >= 4 RNG cells, harvest the selected cells' bits, and push
     them through the POC's random-number buffer.
  3. **Consumption**: `rand_dram()` — the pimolib call — drains the buffer
     via the data register, exactly as in the paper's workflow.

Throughput/latency figures come from the memory-controller timing model
(validated against the paper's 220 ns / 8.30 Mb/s in benchmarks).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from .isa import Instruction, Opcode
from .memctrl import MemoryController
from .poc import PimOpsController


@dataclass
class RngCellMap:
    """Characterization output: per-row indices of RNG cells."""

    cells: Dict[int, List[int]] = field(default_factory=dict)
    samples_per_cell: int = 0

    def rows_with(self, min_cells: int) -> List[int]:
        return [r for r, cs in self.cells.items() if len(cs) >= min_cells]

    @property
    def total_cells(self) -> int:
        return sum(len(c) for c in self.cells.values())


def characterize(
    mc: MemoryController,
    rows: List[int],
    n_bits: int = 64,
    samples: int = 200,
    p_lo: float = 0.35,
    p_hi: float = 0.65,
    seed: int = 11,
) -> RngCellMap:
    """Estimate per-cell failure probability; select metastable cells.

    Cells are written with zeros so any 1 read back is an activation
    failure.  (A second pass with ones would reject stuck-at cells; the
    simulated physics has no asymmetric stuck-ats, and on hardware the
    paper uses both patterns — noted in DESIGN.md.)
    """
    geo = mc.device.geometry
    zero = np.zeros(geo.row_bytes, np.uint8)
    cmap = RngCellMap(samples_per_cell=samples)
    for row in rows:
        mc.device.write_row(row, zero)
        counts = np.zeros(n_bits, np.int64)
        for _ in range(samples):
            res = mc.run_sequence("drange_read", row, n_bits)
            counts += res.data.astype(np.int64)
        p = counts / samples
        sel = np.nonzero((p >= p_lo) & (p <= p_hi))[0]
        if sel.size:
            cmap.cells[row] = sel.tolist()
    return cmap


class DRangeTRNG:
    """End-to-end TRNG using the POC protocol (pimolib `rand_dram`)."""

    def __init__(
        self,
        poc: PimOpsController,
        cmap: RngCellMap,
        bits_per_read: int = 4,
    ) -> None:
        self.poc = poc
        self.cmap = cmap
        self.bits_per_read = bits_per_read
        self.rows = cmap.rows_with(bits_per_read)
        if not self.rows:
            raise ValueError("characterization found no usable RNG rows")
        self._row_idx = 0
        self.stats = {"reads": 0, "bits": 0}

    def _refill(self, want_bits: int) -> None:
        zero_written: set = set()
        while self.poc.rng_bits_available() < want_bits:
            row = self.rows[self._row_idx % len(self.rows)]
            self._row_idx += 1
            if row not in zero_written:
                # RNG rows hold zeros; failures are the entropy.
                self.poc.mc.device.write_row(
                    row, np.zeros(self.poc.mc.device.geometry.row_bytes, np.uint8)
                )
                zero_written.add(row)
            n_bits = max(self.cmap.cells[row][-1] + 1, 1)
            held = list(self.poc.rng_buffer)          # previously harvested bits
            self.poc.rng_buffer.clear()
            insn = Instruction(Opcode.DR_GEN, operand0=row, operand1=n_bits)
            self.poc.store_instruction(insn.encode())
            self.poc.store_start()
            # Keep only characterized RNG cells (the scheduler's cell mask).
            raw = list(self.poc.rng_buffer)
            self.poc.rng_buffer.clear()
            kept = [raw[i] for i in self.cmap.cells[row] if i < len(raw)]
            self.poc.rng_buffer.extend(held + kept)
            self.stats["reads"] += 1

    def random_bits(self, n: int) -> np.ndarray:
        """Return ``n`` true-random bits via the POC buffer protocol."""
        out: List[int] = []
        while len(out) < n:
            self._refill(min(64, n - len(out)))
            take = min(64, self.poc.rng_bits_available(), n - len(out))
            insn = Instruction(Opcode.READ_BUF, operand0=take)
            self.poc.store_instruction(insn.encode())
            self.poc.store_start()
            word = self.poc.load_data()
            out.extend((word >> i) & 1 for i in range(take))
        self.stats["bits"] += n
        return np.array(out[:n], np.uint8)

    def random_u32(self, n: int) -> np.ndarray:
        bits = self.random_bits(32 * n).reshape(n, 32)
        return (bits.astype(np.uint64) << np.arange(32, dtype=np.uint64)).sum(axis=1).astype(np.uint32)


# -------------------- statistical quality checks ------------------------ #


def monobit_fraction(bits: np.ndarray) -> float:
    """Fraction of ones; ideal 0.5."""
    return float(bits.mean())


def runs_count(bits: np.ndarray) -> int:
    """Number of runs; for n fair bits expected ~ n/2 + 1."""
    return int(1 + np.count_nonzero(np.diff(bits)))


def serial_correlation(bits: np.ndarray) -> float:
    x = bits.astype(np.float64) - bits.mean()
    denom = float((x * x).sum())
    if denom == 0.0:
        return 1.0
    return float((x[:-1] * x[1:]).sum() / denom)

"""pimolib v2 — PiDRAM's extensible PiM operations library (component ③).

One protocol, two faces:

* **Model face** (:class:`DeviceLib`, ``face="device"``): executes ops
  against the simulated DDR3 device through the POC register protocol,
  with end-to-end latency accounting from the memory-controller timing
  model.  This is the faithful reproduction path (paper workflow Fig. 2,
  steps ①-⑩).

* **JAX face** (:class:`TpuLib`, ``face="jax"``): the same operations
  over JAX HBM arena buffers, dispatched through the batched PiM op
  scheduler (:class:`repro.core.pim_queue.PimOpQueue`) onto the Pallas
  kernel layer (or XLA reference paths).  The POC handshake maps onto
  JAX's asynchronous dispatch: ``Ack`` = op dispatched, ``Fin`` = result
  buffer committed (``block_until_ready``).

Both faces implement the :class:`PimLib` protocol — ``copy / init /
rand / read / write / flush`` with uniform :class:`Blocking` semantics —
and every mutation returns a unified :class:`OpReceipt`: ``latency_ns``
carries the model-face timing account, ``launches`` the JAX-face kernel
dispatch count, ``n_ops`` the logical row/page ops either way.  Op
behaviour is defined once, in the opcode-keyed registry
(:mod:`repro.core.op_registry`): each :class:`repro.core.isa.Opcode`
maps to per-face executors (model face → :class:`Instruction` sequences
through the MemoryController/POC; JAX face → ``PimOpQueue`` flush
executors), so registering a new PiM op is one registry entry plus its
executors on whichever faces support it — the software mirror of the
paper's "60 additional lines of Verilog" extensibility argument.
Capability flags (:meth:`PimLib.supports`) let callers fall back
gracefully on faces that lack an op.
"""

from __future__ import annotations

import abc
import enum
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.drange import ops as dr_ops

from . import op_registry
from .allocator import Allocation, SubarrayAllocator
from .coherence import CoherenceModel, CoherencePolicy
from .isa import Instruction, Opcode
from .memctrl import MemoryController
from .pim_queue import PimOpQueue
from .poc import PimOpsController


class Blocking(enum.Enum):
    ACK = "ack"    # return once the op is dispatched (POC Ack / async JAX)
    FIN = "fin"    # block until the op's effects are committed


@dataclass
class OpReceipt:
    """What every pimolib mutation returns, on every face.

    ``latency_ns`` is the model-face end-to-end account (POC handshake +
    command sequence + coherence maintenance); ``launches`` is the
    JAX-face kernel dispatch count this call issued (0 with
    ``deferred=True`` until the coalescing flush pays it); ``n_ops``
    counts logical row/page operations on both faces.
    """

    ok: bool
    op: str                      # registry op name (or baseline path name)
    face: str = "device"
    n_ops: int = 1
    latency_ns: float = 0.0      # model-face accounting
    launches: int = 0            # JAX-face dispatches issued by this call
    deferred: bool = False       # queued for a later coalescing flush


class PimLib(abc.ABC):
    """The pimolib protocol: one op vocabulary over both substrates.

    Uniform semantics: ``copy``/``init``/``write`` mutate pages named by
    :class:`Allocation` handles and return an :class:`OpReceipt`;
    ``read`` returns page contents (flushing deferred work first);
    ``flush`` drains any deferred backlog; ``rand`` draws true-random
    bits from the face's D-RaNGe implementation.  ``Blocking.FIN`` is a
    full synchronization point on every face.

    Op behaviour is NOT defined here: every call resolves through the
    opcode-keyed registry (:mod:`repro.core.op_registry` — see its
    module docstring for the worked one-call extension recipe), so a
    newly registered op is immediately callable on every face that got
    an executor.  ``docs/ARCHITECTURE.md`` maps which path each call
    takes per face and where its accounting lands.
    """

    face: str = "?"

    @abc.abstractmethod
    def copy(self, src: Allocation, dst: Allocation,
             blocking: Blocking = Blocking.ACK) -> OpReceipt: ...

    @abc.abstractmethod
    def init(self, dst: Allocation, value: float = 0.0,
             blocking: Blocking = Blocking.ACK) -> OpReceipt: ...

    @abc.abstractmethod
    def read(self, alloc: Allocation): ...

    @abc.abstractmethod
    def write(self, alloc: Allocation, values) -> OpReceipt: ...

    @abc.abstractmethod
    def flush(self, blocking: Blocking = Blocking.ACK) -> OpReceipt: ...

    def bitwise(self, op: str, src: Allocation, dst: Allocation,
                blocking: Blocking = Blocking.ACK) -> OpReceipt:
        """Ambit bulk bitwise: ``dst <- src OP dst`` for op in
        {"and", "or"}, ``dst <- ~src`` for "not" (the two-operand
        in-place semantics of AMB_AND/AMB_OR/AMB_NOT).  Concrete default
        so third-party faces predating the op keep importing; both
        built-in faces override it."""
        raise NotImplementedError(
            f"face {self.face!r} has no bitwise() implementation")

    @abc.abstractmethod
    def rand(self, n_bits: int, seed=None) -> Tuple[np.ndarray, OpReceipt]: ...

    def supports(self, opcode: Opcode) -> bool:
        """Capability flag: does this face implement ``opcode``?"""
        return op_registry.supports(opcode, self.face)


# ---------------------------------------------------------------------- #
# Model face — drives the simulated prototype
# ---------------------------------------------------------------------- #


class DeviceLib(PimLib):
    """pimolib over the simulated DDR3 prototype."""

    face = op_registry.FACE_DEVICE

    def __init__(
        self,
        poc: PimOpsController,
        allocator: SubarrayAllocator,
        coherence: CoherencePolicy = CoherencePolicy.PRECISE,
        trng=None,
    ) -> None:
        self.poc = poc
        self.allocator = allocator
        self.coherence = CoherenceModel(coherence, poc.mc)
        self.trng = trng    # DRangeTRNG; required for rand()
        self.zero_rows: Dict[int, int] = {}  # group -> reserved all-zeros row
        self.stats = {"copies": 0, "inits": 0, "bitwises": 0, "reads": 0,
                      "writes": 0, "rand_bits": 0}

    def supports(self, opcode: Opcode) -> bool:
        if opcode is Opcode.DR_GEN and self.trng is None:
            return False    # needs a characterized TRNG attached
        return super().supports(opcode)

    # -- supervisor-software services ----------------------------------- #

    def attach_trng(self, trng) -> None:
        """Attach a characterized D-RaNGe TRNG; enables :meth:`rand`."""
        self.trng = trng

    def reserve_zero_row(self, group: int) -> int:
        """RowClone-Init copies from a reserved all-zeros row per subarray."""
        if group not in self.zero_rows:
            alloc = self.allocator.alloc(1, group=group, tag="zero-row")
            row = alloc.rows[0]
            geo = self.poc.mc.device.geometry
            self.poc.mc.device.write_row(row, np.zeros(geo.row_bytes, np.uint8))
            self.zero_rows[group] = row
        return self.zero_rows[group]

    # -- the four-step pimolib protocol ---------------------------------- #

    def _start_and_poll(self, blocking: Blocking) -> None:
        self.poc.store_start()                      # (ii) set Start flag
        flags = self.poc.load_flags()               # (iii) poll Ack / Fin
        want = flags.ack if blocking is Blocking.ACK else flags.fin
        assert want, "POC handshake failed"

    def _issue(self, insn: Instruction, blocking: Blocking) -> None:
        self.poc.store_instruction(insn.encode())   # (i) write instruction reg
        self._start_and_poll(blocking)

    def _dispatch(self, insns: list, blocking: Blocking,
                  batch: bool) -> Tuple[bool, float]:
        """Issue an instruction sequence; returns (ok, handshake_ns).

        ``batch=True`` stages the whole sequence in the POC instruction
        buffer and pays ONE register handshake; ``batch=False`` is the
        legacy one-handshake-per-instruction dispatch (the looped
        baseline the benchmarks compare against)."""
        ok = True
        if batch:
            self.poc.store_instruction_buffer([i.encode() for i in insns])
            self._start_and_poll(blocking)
            return self.poc.last_ok, self.poc.mc.poc_handshake_ns()
        for insn in insns:
            self._issue(insn, blocking)
            ok &= self.poc.last_ok
        return ok, len(insns) * self.poc.mc.poc_handshake_ns()

    def _run_op(self, opcode: Opcode, src: Optional[Allocation],
                dst: Allocation, blocking: Blocking, batch: bool,
                *, write_back: bool, coherence_on: Allocation) -> OpReceipt:
        """Registry-driven dispatch: coherence maintenance + the spec's
        Instruction sequence through the POC, timed end to end."""
        spec = op_registry.get_op(opcode)
        if spec is None or spec.device_insns is None:
            raise NotImplementedError(
                f"{opcode!r} has no model-face executor (supports()=False)")
        t0 = self.poc.mc.now_ns
        latency = self.coherence.flush_cost_ns(coherence_on, self.allocator,
                                               write_back=write_back)
        insns = spec.device_insns(self, src, dst)
        ok, handshakes = self._dispatch(insns, blocking, batch)
        latency += handshakes + self.poc.mc.now_ns - t0
        return OpReceipt(ok, spec.name, face=self.face, n_ops=dst.nrows,
                         latency_ns=latency)

    # -- PimLib protocol ------------------------------------------------- #

    def copy(self, src: Allocation, dst: Allocation,
             blocking: Blocking = Blocking.ACK, batch: bool = True) -> OpReceipt:
        """RowClone-Copy src -> dst (row lists must be same-subarray),
        one POC handshake per batch by default."""
        if src.group != dst.group or src.nrows != dst.nrows:
            raise ValueError("copy operands must be same-subarray, same size")
        self.stats["copies"] += src.nrows
        return self._run_op(Opcode.RC_COPY, src, dst, blocking, batch,
                            write_back=True, coherence_on=src)

    def init(self, dst: Allocation, value: float = 0.0,
             blocking: Blocking = Blocking.ACK, batch: bool = True) -> OpReceipt:
        """RowClone-Init: copy the reserved zero row over each dst row
        (one POC handshake per batch by default, as for :meth:`copy`).
        Nonzero fill has no RowClone sequence — it falls back to the CPU
        memset path (graceful capability fallback).  The device stores
        bytes, so only integer fills in [0, 255] reproduce the JAX
        face's element-wise fill; anything else raises rather than
        silently truncating."""
        if isinstance(value, Blocking):   # v1 signature: init(dst, blocking)
            raise TypeError("pimolib v2 moved `value` before `blocking`: "
                            "call init(dst, value=0.0, blocking=...)")
        if value != 0.0:
            if not (float(value).is_integer() and 0 <= value <= 255):
                raise ValueError(
                    f"model-face init fill must be a byte value, got {value!r}")
            rec = self.cpu_init(dst, value)
            self.stats["inits"] += dst.nrows
            return rec
        rec = self._run_op(Opcode.RC_INIT, None, dst, blocking, batch,
                           write_back=False, coherence_on=dst)
        self.stats["inits"] += dst.nrows
        return rec

    _BITWISE_OPC = {"and": Opcode.AMB_AND, "or": Opcode.AMB_OR,
                    "not": Opcode.AMB_NOT}

    def bitwise(self, op: str, src: Allocation, dst: Allocation,
                blocking: Blocking = Blocking.ACK,
                batch: bool = True) -> OpReceipt:
        """Ambit ``dst <- src OP dst`` (or ``~src`` for "not") through
        the POC: each row pair is priced as its TRA command sequence.
        Operands must be same-subarray (the B-group compute rows are
        per-subarray) — a cross-subarray pair makes the sequence report
        ``ok=False`` rather than silently staging through the CPU."""
        if op not in self._BITWISE_OPC:
            raise ValueError(f"unknown bitwise op {op!r}")
        if src.group != dst.group or src.nrows != dst.nrows:
            raise ValueError(
                "bitwise operands must be same-subarray, same size")
        self.stats["bitwises"] += src.nrows
        return self._run_op(self._BITWISE_OPC[op], src, dst, blocking, batch,
                            write_back=True, coherence_on=src)

    def cpu_bitwise(self, op: str, src: Allocation, dst: Allocation) -> OpReceipt:
        """CPU read-modify-write baseline for the same op (the fallback
        the serving-trace replay prices when operands span subarrays)."""
        mc = self.poc.mc
        nbytes = src.nrows * mc.proto.row_bytes
        for s, d in zip(src.rows, dst.rows):
            a = mc.device.read_row(s)
            if op == "not":
                out = np.bitwise_not(a)
            elif op == "and":
                out = a & mc.device.read_row(d)
            else:
                out = a | mc.device.read_row(d)
            mc.device.write_row(d, out)
        self.allocator.touch_cpu_write(dst)
        return OpReceipt(True, "cpu_bitwise", face=self.face, n_ops=src.nrows,
                         latency_ns=mc.bitwise_ns(nbytes))

    def rand(self, n_bits: int, seed=None) -> Tuple[np.ndarray, OpReceipt]:
        """Paper's rand_dram(): drain the POC random-number buffer.
        Requires an attached characterized TRNG (``supports(DR_GEN)``)."""
        if self.trng is None:
            raise NotImplementedError(
                "rand() needs a characterized DRangeTRNG: "
                "DeviceLib(..., trng=...) or attach_trng()")
        bits = self.trng.random_bits(n_bits)
        chunks = -(-n_bits // self.poc.mc.proto.drange_bits_per_read)
        latency = (self.poc.mc.proto.drange_latency_ns
                   + (chunks - 1) * self.poc.mc.proto.drange_sustained_ns)
        self.stats["rand_bits"] += n_bits
        return bits, OpReceipt(True, "drange_rand", face=self.face,
                               n_ops=n_bits, latency_ns=latency)

    def read(self, alloc: Allocation) -> np.ndarray:
        """Page contents as (nrows, row_bytes) uint8 (CPU read path)."""
        mc = self.poc.mc
        out = np.stack([mc.device.read_row(r) for r in alloc.rows])
        self.allocator.touch_cpu_read(alloc)
        self.stats["reads"] += alloc.nrows
        return out

    def write(self, alloc: Allocation, values) -> OpReceipt:
        """CPU write path: store ``values`` (castable to (nrows,
        row_bytes) uint8) into the allocation's rows.  There is no PiM
        sequence for host-data ingress, so this is accounted as a CPU
        memcpy — the same fallback the serving-trace replay uses for
        ``KV_WRITE`` (``supports(Opcode.KV_WRITE)`` is False here)."""
        mc = self.poc.mc
        geo = mc.device.geometry
        raw = np.asarray(values)
        vals = raw.astype(np.uint8).reshape(alloc.nrows, geo.row_bytes)
        if not np.array_equal(vals.astype(raw.dtype).reshape(raw.shape), raw):
            raise ValueError(
                "model-face write payload must be byte values in [0, 255] "
                "(the device stores bytes; silent truncation would diverge "
                "from the JAX face)")
        for r, row in zip(alloc.rows, vals):
            mc.device.write_row(r, row)
        self.allocator.touch_cpu_write(alloc)
        self.stats["writes"] += alloc.nrows
        nbytes = alloc.nrows * mc.proto.row_bytes
        return OpReceipt(True, "cpu_write", face=self.face,
                         n_ops=alloc.nrows, latency_ns=mc.memcpy_ns(nbytes))

    def flush(self, blocking: Blocking = Blocking.ACK) -> OpReceipt:
        """The model face executes synchronously: nothing is deferred."""
        return OpReceipt(True, "flush", face=self.face, n_ops=0)

    # -- CPU baselines (memcpy / calloc through the core) ----------------- #

    def cpu_copy(self, src: Allocation, dst: Allocation) -> OpReceipt:
        mc = self.poc.mc
        nbytes = src.nrows * mc.proto.row_bytes
        for s, d in zip(src.rows, dst.rows):
            mc.device.write_row(d, mc.device.read_row(s))
        self.allocator.touch_cpu_write(dst)
        return OpReceipt(True, "cpu_memcpy", face=self.face, n_ops=src.nrows,
                         latency_ns=mc.memcpy_ns(nbytes))

    def cpu_init(self, dst: Allocation, value: float = 0.0) -> OpReceipt:
        mc = self.poc.mc
        nbytes = dst.nrows * mc.proto.row_bytes
        geo = mc.device.geometry
        fill = np.full(geo.row_bytes, int(value), np.uint8)
        for d in dst.rows:
            mc.device.write_row(d, fill)
        self.allocator.touch_cpu_write(dst)
        return OpReceipt(True, "cpu_calloc", face=self.face, n_ops=dst.nrows,
                         latency_ns=mc.memset_ns(nbytes))

    # -- deprecated v1 spelling ------------------------------------------ #

    def rand_dram(self, n_bits: int, trng) -> Tuple[np.ndarray, OpReceipt]:
        warnings.warn("rand_dram(n, trng) is deprecated: attach_trng(trng) "
                      "then rand(n)", DeprecationWarning, stacklevel=2)
        self.attach_trng(trng)
        return self.rand(n_bits)


# ---------------------------------------------------------------------- #
# JAX face — the same ops over JAX HBM arena buffers
# ---------------------------------------------------------------------- #


@dataclass
class TpuArena:
    """A paged HBM arena: (num_pages, page_elems) + its allocator."""

    buffer: jax.Array
    allocator: SubarrayAllocator
    dtype: jnp.dtype = jnp.bfloat16

    @property
    def num_pages(self) -> int:
        return self.buffer.shape[0]

    @property
    def page_elems(self) -> int:
        return self.buffer.shape[1]


class TpuLib(PimLib):
    """pimolib over JAX arena buffers (serving/training integration point).

    Arena mutations route through the batched PiM op scheduler
    (:class:`repro.core.pim_queue.PimOpQueue`) — the same queue the
    serving-side paged KV cache shares — so every client gets op
    coalescing and unified launch accounting.  By default every call
    still flushes immediately (the historical synchronous semantics);
    construct with ``deferred=True`` (or toggle the attribute) to
    collect ops across calls and pay one coalesced launch per op kind at
    :meth:`flush`.  Hazard-aware admission lives in the queue
    (:meth:`PimOpQueue.admit`): deferred mode preserves program-order
    results by flushing the backlog when an op mixes kinds with pending
    work or touches a row a pending op already touched.  Reads flush
    implicitly, and ``Blocking.FIN`` is always a full synchronization
    point.

    The lib binds either one :class:`TpuArena` (training-side single
    buffer, pages on axis 0) or a list of layered ``(L, P, ...)``
    buffers (the serving KV cache's (k, v) pair, pages on axis 1) — the
    queue flushes all bound buffers together.

    **Sharded arenas.**  When the serving engine runs over a device
    mesh, the arenas it binds are single *global* jax.Arrays laid out
    with a :class:`jax.sharding.NamedSharding` that splits one axis
    (the KV-head axis) across the mesh's ``model`` dimension — each
    device holds its head slice of every page.  The lib stays ONE lib
    behind ONE queue: flushes run on the global arrays (XLA partitions
    the coalesced launch across shards), so ``launches_by_kind`` still
    counts each coalesced flush once for the whole mesh.  ``shard_axis``
    / ``mesh`` record the layout; :meth:`owner_tags` names the per-shard
    owners (``tag/shard0`` …) that the queue's per-owner breakdown
    attributes launches to, and :meth:`shard_views` exposes each
    device's addressable slice for parity tests.
    """

    face = op_registry.FACE_JAX

    def __init__(self, arena: Optional[TpuArena] = None, *,
                 buffers: Optional[Sequence[jax.Array]] = None,
                 layered: Optional[bool] = None,
                 allocator: Optional[SubarrayAllocator] = None,
                 use_pallas: bool = False, deferred: bool = False,
                 queue: Optional[PimOpQueue] = None,
                 tag: str = "lib", shard_axis: Optional[int] = None,
                 mesh=None, axis_name: str = "model") -> None:
        if arena is not None and buffers is not None:
            raise ValueError("pass either arena= or buffers=, not both")
        self.arena = arena
        self.use_pallas = use_pallas
        self.deferred = deferred
        self.tag = tag
        self.shard_axis = shard_axis
        self.mesh = mesh
        self.axis_name = axis_name
        self.queue = queue if queue is not None else PimOpQueue(
            use_pallas=use_pallas)
        if self.queue.owner is not None:
            raise ValueError(
                "PimOpQueue is already driven by another lib — pending ops "
                "carry no owner, so two libs flushing one queue would land "
                "each other's ops on the wrong arenas; share ONE lib across "
                "clients for joint accounting instead")
        self.queue.owner = self
        self.stats = {"copies": 0, "inits": 0, "bitwises": 0, "reads": 0,
                      "writes": 0, "rand_bits": 0}
        self._rand_ctr = 0   # advances the default rand() seed per call
        if arena is not None:
            self.buffers: List[jax.Array] = [arena.buffer]
            self.allocator = arena.allocator
            self.layered = False if layered is None else layered
        else:
            self.buffers = list(buffers) if buffers is not None else []
            self.allocator = allocator
            self.layered = True if layered is None else layered

    def adopt_buffers(self, buffers: Sequence[jax.Array], *,
                      layered: bool = True,
                      allocator: Optional[SubarrayAllocator] = None,
                      shard_axis: Optional[int] = None,
                      mesh=None, axis_name: str = "model") -> None:
        """Bind the arena buffers this face flushes against — how the
        paged KV cache plugs its (k, v) pair into a caller-supplied lib.
        A lib already bound to arenas refuses to rebind: the first
        owner's page ids would silently flush against the new buffers
        (share a queue across libs for joint accounting instead).
        ``shard_axis``/``mesh`` record that the buffers are global
        arrays split on that axis over ``mesh``'s ``axis_name``
        dimension (see the class docstring)."""
        if self.queue.pending_ops:
            raise RuntimeError("cannot adopt buffers with pending ops")
        if self.buffers or self.arena is not None:
            raise RuntimeError(
                "lib is already bound to arenas; construct one lib per "
                "arena owner (clients share the lib for joint accounting)")
        self._set_buffers(buffers)
        self.layered = layered
        if shard_axis is not None:
            self.shard_axis = shard_axis
            self.mesh = mesh
            self.axis_name = axis_name
        if allocator is not None:
            self.allocator = allocator

    @property
    def n_shards(self) -> int:
        """Mesh extent of the shard axis (1 for a host-local lib)."""
        if self.shard_axis is None or self.mesh is None:
            return 1
        return self.mesh.shape[self.axis_name]

    def owner_tags(self) -> Tuple[str, ...]:
        """Owner tags for the queue's per-owner launch attribution: one
        tag per shard for a sharded lib (every shard participates in
        each SPMD dispatch), else the lib's own tag."""
        n = self.n_shards
        if n == 1:
            return (self.tag,)
        return tuple(f"{self.tag}/shard{i}" for i in range(n))

    def shard_views(self, buffer: int = 0) -> List[np.ndarray]:
        """Each shard's slice of ``buffers[buffer]`` as numpy arrays,
        ordered by position along the shard axis (shard 0 = heads
        [0, H/N), …).  Host-local libs return the whole buffer as one
        view.  Flushes pending work first so views reflect committed
        state — the sharded-parity tests compare these against the
        host-local engine's head slices."""
        self.flush()
        buf = self.buffers[buffer]
        if self.shard_axis is None or self.n_shards == 1:
            return [np.asarray(buf)]
        shards = sorted(buf.addressable_shards,
                        key=lambda s: s.index[self.shard_axis].start or 0)
        return [np.asarray(s.data) for s in shards]

    def _set_buffers(self, buffers: Sequence[jax.Array]) -> None:
        """The ONE place buffer state changes: keeps a wrapping TpuArena
        (if any) in sync so external holders never read stale data."""
        self.buffers = list(buffers)
        if self.arena is not None:
            self.arena.buffer = self.buffers[0]

    # -- internals ------------------------------------------------------- #

    def _page_rows(self, alloc: Allocation) -> jax.Array:
        return jnp.asarray(alloc.rows, jnp.int32)

    def _receipt(self, op: str, n_ops: int, blocking: Blocking) -> OpReceipt:
        """Flush-or-defer and account launches for one mutation call."""
        if self.deferred and blocking is not Blocking.FIN:
            return OpReceipt(True, op, face=self.face, n_ops=n_ops,
                             deferred=True)
        before = self.queue.stats["launches"]
        self.flush(blocking)
        return OpReceipt(True, op, face=self.face, n_ops=n_ops,
                         launches=self.queue.stats["launches"] - before)

    # -- PimLib protocol ------------------------------------------------- #

    def copy(self, src: Allocation, dst: Allocation,
             blocking: Blocking = Blocking.ACK) -> OpReceipt:
        if src.group != dst.group or src.nrows != dst.nrows:
            raise ValueError("copy operands must be same-slab, same size")
        self.queue.admit("page_copy", dst.rows, self.flush, reads=src.rows)
        for s, d in zip(src.rows, dst.rows):
            self.queue.enqueue_copy(s, d)
        self.stats["copies"] += src.nrows
        return self._receipt("rowclone_copy", src.nrows, blocking)

    def init(self, dst: Allocation, value: float = 0.0,
             blocking: Blocking = Blocking.ACK) -> OpReceipt:
        self.queue.admit("page_init", dst.rows, self.flush)
        for d in dst.rows:
            self.queue.enqueue_init(d, value)
        self.stats["inits"] += dst.nrows
        return self._receipt("rowclone_init", dst.nrows, blocking)

    _BITWISE_KIND = {"and": "page_and", "or": "page_or", "not": "page_not"}

    def bitwise(self, op: str, src: Allocation, dst: Allocation,
                blocking: Blocking = Blocking.ACK) -> OpReceipt:
        """Ambit ``dst <- src OP dst`` (or ``~src`` for "not") on pages:
        one coalesced bitwise launch per bound arena at flush.  The ops
        both read and write dst, so ``admit`` registers src pages as
        reads — a pending op that wrote either operand flushes first."""
        kind = self._BITWISE_KIND.get(op)
        if kind is None:
            raise ValueError(f"unknown bitwise op {op!r}")
        if src.group != dst.group or src.nrows != dst.nrows:
            raise ValueError(
                "bitwise operands must be same-slab, same size")
        self.queue.admit(kind, dst.rows, self.flush, reads=src.rows)
        for s, d in zip(src.rows, dst.rows):
            self.queue.enqueue(kind, (s, d))
        self.stats["bitwises"] += src.nrows
        return self._receipt(f"ambit_{op}", src.nrows, blocking)

    def flush(self, blocking: Blocking = Blocking.ACK) -> OpReceipt:
        """Drain pending ops: one coalesced launch per op kind across
        all bound buffers (an unlayered arena flushes as a single-layer
        view)."""
        before = self.queue.stats["launches"]
        if self.queue.pending_ops:
            if not self.buffers:
                raise RuntimeError("flush with pending ops but no buffers "
                                   "bound (adopt_buffers first)")
            views = [b if self.layered else b[None] for b in self.buffers]
            out = self.queue.flush(*views)
            self._set_buffers([o if self.layered else o[0] for o in out])
        if blocking is Blocking.FIN:
            for b in self.buffers:
                b.block_until_ready()
        return OpReceipt(True, "flush", face=self.face, n_ops=0,
                         launches=self.queue.stats["launches"] - before)

    def rand(self, n_bits: int, seed=None) -> Tuple[np.ndarray, OpReceipt]:
        """True-random bits from the D-RaNGe kernel (one launch).  With
        no explicit seed the stream advances per call, matching the
        model face's fresh-bits-per-call semantics; pass ``seed`` for a
        reproducible draw."""
        if seed is None:
            self._rand_ctr += 1
            seed = jnp.asarray([0x9E3779B9 + self._rand_ctr,
                                0x85EBCA6B ^ self._rand_ctr], jnp.uint32)
        words = dr_ops.pim_random_u32(seed, 1, -(-n_bits // 32),
                                      use_pallas=self.use_pallas)
        self.stats["rand_bits"] += n_bits   # logical bits, like DeviceLib
        self.queue.count_external("drange_rand")
        bits = np.unpackbits(
            np.asarray(words).view(np.uint8), bitorder="little")[:n_bits]
        return bits, OpReceipt(True, "drange_rand", face=self.face,
                               n_ops=n_bits, launches=1)

    def read(self, alloc: Allocation, buffer: int = 0) -> jax.Array:
        """Page contents of ``buffers[buffer]`` (the index mirrors
        :meth:`write`); deferred mutations land before any read.
        Unlayered: (nrows, elems); layered: (layers, nrows, ...)."""
        self.flush()
        self.stats["reads"] += alloc.nrows
        buf = self.buffers[buffer]
        rows = self._page_rows(alloc)
        return buf[rows] if not self.layered else buf[:, rows]

    def write(self, alloc: Allocation, values, buffer: int = 0) -> OpReceipt:
        """Host-data ingress: direct XLA scatter into ``buffers[buffer]``
        (flushes first to preserve enqueue order vs direct writes)."""
        self.flush()
        buf = self.buffers[buffer]
        rows = self._page_rows(alloc)
        vals = jnp.asarray(values).astype(buf.dtype)
        idx = rows if not self.layered else (slice(None), rows)
        new = list(self.buffers)
        new[buffer] = buf.at[idx].set(vals)
        self._set_buffers(new)
        self.stats["writes"] += alloc.nrows
        self.queue.count_external("host_write")
        return OpReceipt(True, "host_write", face=self.face,
                         n_ops=alloc.nrows, launches=1)

    # -- extras shared with the drange kernel layer ----------------------- #

    def rand_u32(self, seed: jax.Array, n_rows: int, n_cols: int) -> jax.Array:
        """Raw u32 word generation (the training-side consumer API)."""
        self.stats["rand_bits"] += n_rows * n_cols * 32
        self.queue.count_external("drange_rand")
        return dr_ops.pim_random_u32(seed, n_rows, n_cols,
                                     use_pallas=self.use_pallas)


def make_tpu_arena(num_slabs: int, pages_per_slab: int, page_elems: int,
                   dtype=jnp.bfloat16) -> TpuArena:
    from .allocator import arena_groups
    buf = jnp.zeros((num_slabs * pages_per_slab, page_elems), dtype)
    alloc = SubarrayAllocator(arena_groups(num_slabs, pages_per_slab))
    return TpuArena(buffer=buf, allocator=alloc, dtype=dtype)

"""pimolib — PiDRAM's extensible PiM operations library (component ③).

Two faces, one API:

* **Model face** (`DeviceLib`): executes ops against the simulated DDR3
  device through the POC register protocol, with end-to-end latency
  accounting from the memory-controller timing model.  This is the
  faithful reproduction path (paper workflow Fig. 2, steps ①-⑩).

* **TPU face** (`TpuLib`): the same operations over a JAX HBM arena,
  dispatched through the Pallas kernel layer (or XLA reference paths).
  The POC handshake maps onto JAX's asynchronous dispatch: ``Ack`` = op
  dispatched, ``Fin`` = result buffer committed (``block_until_ready``).

Both are built for extension: registering a new PiM op is one entry in
``_OPS`` plus its executor — the software mirror of the paper's
"60 additional lines of Verilog" extensibility argument.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .allocator import Allocation, SubarrayAllocator
from .coherence import CoherenceModel, CoherencePolicy
from .isa import Instruction, Opcode
from .memctrl import MemoryController
from .poc import PimOpsController


class Blocking(enum.Enum):
    ACK = "ack"    # return once the POC acknowledged the op
    FIN = "fin"    # block until the command sequence finished


# ---------------------------------------------------------------------- #
# Model face — drives the simulated prototype
# ---------------------------------------------------------------------- #


@dataclass
class OpReceipt:
    """What a pimolib call returns: success + accounted latency."""

    ok: bool
    latency_ns: float
    op: str


class DeviceLib:
    """pimolib over the simulated DDR3 prototype."""

    def __init__(
        self,
        poc: PimOpsController,
        allocator: SubarrayAllocator,
        coherence: CoherencePolicy = CoherencePolicy.PRECISE,
    ) -> None:
        self.poc = poc
        self.allocator = allocator
        self.coherence = CoherenceModel(coherence, poc.mc)
        self.zero_rows: Dict[int, int] = {}  # group -> reserved all-zeros row

    # -- supervisor-software services ----------------------------------- #

    def reserve_zero_row(self, group: int) -> int:
        """RowClone-Init copies from a reserved all-zeros row per subarray."""
        if group not in self.zero_rows:
            alloc = self.allocator.alloc(1, group=group, tag="zero-row")
            row = alloc.rows[0]
            geo = self.poc.mc.device.geometry
            self.poc.mc.device.write_row(row, np.zeros(geo.row_bytes, np.uint8))
            self.zero_rows[group] = row
        return self.zero_rows[group]

    # -- the four-step pimolib protocol ---------------------------------- #

    def _start_and_poll(self, blocking: Blocking) -> None:
        self.poc.store_start()                      # (ii) set Start flag
        flags = self.poc.load_flags()               # (iii) poll Ack / Fin
        want = flags.ack if blocking is Blocking.ACK else flags.fin
        assert want, "POC handshake failed"

    def _issue(self, insn: Instruction, blocking: Blocking) -> None:
        self.poc.store_instruction(insn.encode())   # (i) write instruction reg
        self._start_and_poll(blocking)

    def _dispatch(self, insns: list, blocking: Blocking,
                  batch: bool) -> Tuple[bool, float]:
        """Issue an instruction sequence; returns (ok, handshake_ns).

        ``batch=True`` stages the whole sequence in the POC instruction
        buffer and pays ONE register handshake; ``batch=False`` is the
        legacy one-handshake-per-instruction dispatch (the looped
        baseline the benchmarks compare against)."""
        ok = True
        if batch:
            self.poc.store_instruction_buffer([i.encode() for i in insns])
            self._start_and_poll(blocking)
            return self.poc.last_ok, self.poc.mc.poc_handshake_ns()
        for insn in insns:
            self._issue(insn, blocking)
            ok &= self.poc.last_ok
        return ok, len(insns) * self.poc.mc.poc_handshake_ns()

    def copy(self, src: Allocation, dst: Allocation,
             blocking: Blocking = Blocking.FIN, batch: bool = True) -> OpReceipt:
        """RowClone-Copy src -> dst (row lists must be same-subarray),
        one POC handshake per batch by default."""
        if src.group != dst.group or src.nrows != dst.nrows:
            raise ValueError("copy operands must be same-subarray, same size")
        t0 = self.poc.mc.now_ns
        latency = self.coherence.flush_cost_ns(src, self.allocator, write_back=True)
        insns = [Instruction(Opcode.RC_COPY, s, d)
                 for s, d in zip(src.rows, dst.rows)]
        ok, handshakes = self._dispatch(insns, blocking, batch)
        latency += handshakes + self.poc.mc.now_ns - t0
        return OpReceipt(ok, latency, "rowclone_copy")

    def init(self, dst: Allocation, blocking: Blocking = Blocking.FIN,
             batch: bool = True) -> OpReceipt:
        """RowClone-Init: copy the reserved zero row over each dst row
        (one POC handshake per batch by default, as for :meth:`copy`)."""
        zero = self.reserve_zero_row(dst.group)
        t0 = self.poc.mc.now_ns
        latency = self.coherence.flush_cost_ns(dst, self.allocator, write_back=False)
        insns = [Instruction(Opcode.RC_INIT, zero, d) for d in dst.rows]
        ok, handshakes = self._dispatch(insns, blocking, batch)
        latency += handshakes + self.poc.mc.now_ns - t0
        return OpReceipt(ok, latency, "rowclone_init")

    def rand_dram(self, n_bits: int, trng) -> Tuple[np.ndarray, OpReceipt]:
        """Paper's rand_dram(): drain the POC random-number buffer."""
        bits = trng.random_bits(n_bits)
        chunks = -(-n_bits // self.poc.mc.proto.drange_bits_per_read)
        latency = (self.poc.mc.proto.drange_latency_ns
                   + (chunks - 1) * self.poc.mc.proto.drange_sustained_ns)
        return bits, OpReceipt(True, latency, "drange_rand")

    # -- CPU baselines (memcpy / calloc through the core) ----------------- #

    def cpu_copy(self, src: Allocation, dst: Allocation) -> OpReceipt:
        mc = self.poc.mc
        nbytes = src.nrows * mc.proto.row_bytes
        for s, d in zip(src.rows, dst.rows):
            mc.device.write_row(d, mc.device.read_row(s))
        self.allocator.touch_cpu_write(dst)
        return OpReceipt(True, mc.memcpy_ns(nbytes), "cpu_memcpy")

    def cpu_init(self, dst: Allocation) -> OpReceipt:
        mc = self.poc.mc
        nbytes = dst.nrows * mc.proto.row_bytes
        geo = mc.device.geometry
        for d in dst.rows:
            mc.device.write_row(d, np.zeros(geo.row_bytes, np.uint8))
        self.allocator.touch_cpu_write(dst)
        return OpReceipt(True, mc.memset_ns(nbytes), "cpu_calloc")


# ---------------------------------------------------------------------- #
# TPU face — the same ops over a JAX HBM arena
# ---------------------------------------------------------------------- #


@dataclass
class TpuArena:
    """A paged HBM arena: (num_pages, page_elems) + its allocator."""

    buffer: jax.Array
    allocator: SubarrayAllocator
    dtype: jnp.dtype = jnp.bfloat16

    @property
    def num_pages(self) -> int:
        return self.buffer.shape[0]

    @property
    def page_elems(self) -> int:
        return self.buffer.shape[1]


class TpuLib:
    """pimolib over a JAX arena (serving/training integration point).

    Arena mutations route through the batched PiM op scheduler
    (:class:`repro.serving.pim_queue.PimOpQueue`) — the same queue the
    serving-side paged KV cache uses — so training-side users get op
    coalescing and unified launch accounting for free.  By default every
    call still flushes immediately (the historical synchronous
    semantics); construct with ``deferred=True`` (or toggle the
    attribute) to collect ops across calls and pay one coalesced launch
    per op kind at :meth:`flush`.  Deferred mode preserves program-order
    results: an op that touches a row a pending op already touched, or
    that mixes kinds with pending work, flushes the backlog first (the
    common bulk case — many same-kind ops on disjoint rows — still
    coalesces to one launch).  Reads flush implicitly, and
    ``Blocking.FIN`` is always a full synchronization point.
    """

    def __init__(self, arena: TpuArena, *, use_pallas: bool = False,
                 deferred: bool = False) -> None:
        from repro.kernels.drange import ops as dr_ops
        from repro.serving.pim_queue import PimOpQueue
        self.arena = arena
        self.use_pallas = use_pallas
        self.deferred = deferred
        self.queue = PimOpQueue(use_pallas=use_pallas)
        self._dr = dr_ops
        self._pending_rows: set = set()
        self._pending_kind: Optional[str] = None
        self.stats = {"copies": 0, "inits": 0, "rand_words": 0}

    def _admit(self, kind: str, rows) -> None:
        """Flush the backlog when enqueueing would break program order:
        the queue replays by kind (copies before inits), so mixed kinds
        or row reuse must not coalesce across the hazard."""
        if self.queue.pending_ops and (
                self._pending_kind != kind
                or any(r in self._pending_rows for r in rows)):
            self.flush()
        self._pending_kind = kind
        self._pending_rows.update(rows)

    def copy_pages(self, src: Allocation, dst: Allocation,
                   blocking: Blocking = Blocking.ACK) -> None:
        if src.group != dst.group or src.nrows != dst.nrows:
            raise ValueError("copy operands must be same-slab, same size")
        self._admit("page_copy", list(src.rows) + list(dst.rows))
        for s, d in zip(src.rows, dst.rows):
            self.queue.enqueue_copy(s, d)
        self.stats["copies"] += src.nrows
        if not self.deferred or blocking is Blocking.FIN:
            self.flush(blocking)

    def init_pages(self, dst: Allocation, value=0.0,
                   blocking: Blocking = Blocking.ACK) -> None:
        self._admit("page_init", dst.rows)
        for d in dst.rows:
            self.queue.enqueue_init(d, value)
        self.stats["inits"] += dst.nrows
        if not self.deferred or blocking is Blocking.FIN:
            self.flush(blocking)

    def flush(self, blocking: Blocking = Blocking.ACK) -> None:
        """Drain pending ops: one coalesced launch per op kind.  The
        (pages, elems) buffer flushes as a single-layer arena view."""
        if self.queue.pending_ops:
            (buf,) = self.queue.flush(self.arena.buffer[None])
            self.arena.buffer = buf[0]
        self._pending_rows.clear()
        self._pending_kind = None
        if blocking is Blocking.FIN:
            self.arena.buffer.block_until_ready()

    def rand(self, seed: jax.Array, n_rows: int, n_cols: int) -> jax.Array:
        self.stats["rand_words"] += n_rows * n_cols
        return self._dr.pim_random_u32(seed, n_rows, n_cols, use_pallas=self.use_pallas)

    def read_pages(self, alloc: Allocation) -> jax.Array:
        self.flush()   # deferred mutations land before any read
        return self.arena.buffer[jnp.asarray(alloc.rows, jnp.int32)]

    def write_pages(self, alloc: Allocation, values: jax.Array) -> None:
        self.flush()   # preserve enqueue order vs direct writes
        self.arena.buffer = self.arena.buffer.at[
            jnp.asarray(alloc.rows, jnp.int32)].set(values.astype(self.arena.buffer.dtype))


def make_tpu_arena(num_slabs: int, pages_per_slab: int, page_elems: int,
                   dtype=jnp.bfloat16) -> TpuArena:
    from .allocator import arena_groups
    buf = jnp.zeros((num_slabs * pages_per_slab, page_elems), dtype)
    alloc = SubarrayAllocator(arena_groups(num_slabs, pages_per_slab))
    return TpuArena(buffer=buf, allocator=alloc, dtype=dtype)

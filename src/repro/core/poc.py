"""PiM Operations Controller (POC).

The POC is PiDRAM's hardware component ①: it exposes memory-mapped
*instruction*, *data* and *flag* registers to the CPU, decodes PiDRAM
instructions, and drives the memory controller.  The handshake protocol
(paper Fig. 2) is preserved exactly:

  1. CPU stores instruction word       -> instruction register
  2. CPU stores Start=1                -> flag register
  3. POC forwards op to memory ctrl, sets Start=0, Ack=1
  4. memory controller issues the (violated-timing) command sequence
  5. controller sets Fin=1 when the last command is issued
  6. CPU polls Ack (non-blocking start) or Fin (blocking completion)
  7. CPU loads result (if any)          <- data register

On the TPU target the same object front-ends the asynchronous kernel
dispatch queue (JAX dispatch is async; `wait_fin` maps to blocking on the
result buffer), so pimolib code is identical across both substrates.
"""

from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from . import op_registry
from .isa import Instruction, Opcode
from .memctrl import MemoryController, SequenceResult


@dataclass
class FlagRegister:
    start: bool = False
    ack: bool = False
    fin: bool = False


@dataclass
class PocStats:
    executed: Dict[str, int] = field(default_factory=lambda: collections.defaultdict(int))
    busy_ns: float = 0.0


class PimOpsController:
    """Decode/execute PiDRAM instructions against a memory controller."""

    def __init__(self, mc: MemoryController, data_buffer_words: int = 64) -> None:
        self.mc = mc
        self.instruction_reg: int = 0
        self.data_reg: int = 0
        self.flags = FlagRegister()
        self.stats = PocStats()
        # D-RaNGe random-number buffer (hardware component in the paper's
        # D-RaNGe extension): the scheduler deposits generated bits here.
        self.rng_buffer: Deque[int] = collections.deque(maxlen=data_buffer_words * 64)
        # Batched dispatch: pimolib can stage a whole instruction sequence
        # and trigger it with ONE Start (one handshake for the batch) —
        # the ComputeDRAM batched-command-sequence model.  None = no batch
        # staged (an EMPTY staged batch is a valid no-op, distinct from
        # falling back to the single instruction register).
        self.insn_buffer: Optional[List[int]] = None
        self._last_result: Optional[SequenceResult] = None

    # -------------------- CPU-visible register interface ---------------- #

    def store_instruction(self, word: int) -> None:
        self.instruction_reg = word

    def store_instruction_buffer(self, words: List[int]) -> None:
        """Stage a batch of instruction words; the next Start executes
        them all under a single Ack/Fin handshake."""
        self.insn_buffer = list(words)

    def store_start(self) -> None:
        """CPU sets Start; POC decodes + executes synchronously in the
        model (the timing model accounts latency; see memctrl).  If an
        instruction batch is staged, the whole batch runs before Fin."""
        self.flags.start = True
        if self.insn_buffer is not None:
            self._execute_batch()
        else:
            self._execute()

    def load_flags(self) -> FlagRegister:
        return self.flags

    def load_data(self) -> int:
        return self.data_reg

    # -------------------- execution ------------------------------------- #

    def _execute(self) -> None:
        insn = Instruction.decode(self.instruction_reg)
        self.flags.start = False
        self.flags.ack = True
        self.flags.fin = False

        t0 = self.mc.now_ns
        spec = op_registry.get_op(insn.opcode)
        if insn.opcode is Opcode.NOP:
            res = SequenceResult(0.0, [])
        elif insn.opcode is Opcode.READ_BUF:
            # Register-file op, not a command sequence: drain up to 64
            # bits into the data register.
            word = 0
            n = min(64, len(self.rng_buffer))
            for i in range(n):
                word |= self.rng_buffer.popleft() << i
            self.data_reg = word
            res = SequenceResult(0.0, [])
        elif spec is not None and spec.device_seq is not None:
            # Opcode-keyed registry dispatch: the spec names the memory
            # controller sequence; poc_post handles any result payload
            # (D-RaNGe deposits generated bits into the RNG buffer).
            res = self.mc.run_sequence(spec.device_seq, insn.operand0,
                                       insn.operand1)
            if spec.poc_post is not None:
                spec.poc_post(self, res)
        else:
            raise ValueError(
                f"opcode {insn.opcode!r} has no model-face executor "
                "(register_pim_op with device_seq to add one)")

        self._last_result = res
        self.stats.executed[insn.opcode.name] += 1
        self.stats.busy_ns += self.mc.now_ns - t0
        self.flags.fin = True

    def _execute_batch(self) -> None:
        """Run every staged instruction under one Ack/Fin pair.

        Batches whose opcodes all map (via the op registry) to the same
        memory-controller sequence, with no result-payload hook, route
        through the controller's batched dispatch (one scheduler entry);
        mixed batches fall back to per-instruction decode.  ``last_ok``
        is the conjunction over the batch."""
        words, self.insn_buffer = self.insn_buffer, None
        insns = [Instruction.decode(w) for w in words]
        self.flags.start = False
        self.flags.ack = True
        self.flags.fin = False

        t0 = self.mc.now_ns
        specs = [op_registry.get_op(i.opcode) for i in insns]
        seqs = {s.device_seq if s is not None and s.poc_post is None else None
                for s in specs}
        if not insns:
            # empty batch: acknowledged no-op (do NOT fall back to the
            # stale single-instruction register)
            self._last_result = SequenceResult(0.0, [])
        elif len(seqs) == 1 and None not in seqs:
            res = self.mc.run_sequence_batch(
                seqs.pop(), [(i.operand0, i.operand1) for i in insns])
            for i in insns:
                self.stats.executed[i.opcode.name] += 1
            self._last_result = res
            self.stats.busy_ns += self.mc.now_ns - t0
        else:
            ok = True
            for insn in insns:
                self.instruction_reg = insn.encode()
                self._execute()          # accounts its own busy_ns
                ok &= self.last_ok
            self._last_result = SequenceResult(self.mc.now_ns - t0, [], ok=ok)
        self.flags.fin = True

    # -------------------- convenience ------------------------------------ #

    @property
    def last_ok(self) -> bool:
        return bool(self._last_result and self._last_result.ok)

    def rng_bits_available(self) -> int:
        return len(self.rng_buffer)

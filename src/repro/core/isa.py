"""PiDRAM instruction encoding.

The POC consumes 64-bit instructions written to its memory-mapped
*instruction* register.  We mirror the prototype's encoding: an opcode
field plus two operand fields (row addresses or sizes).  The encoding is
exercised end-to-end: pimolib encodes, the POC decodes, tests round-trip.

    63      56 55        28 27         0
    [ opcode ] [ operand1 ] [ operand0 ]
"""

from __future__ import annotations

import enum
from dataclasses import dataclass


class Opcode(enum.IntEnum):
    NOP = 0x00
    RC_COPY = 0x01      # RowClone-Copy:  operand0=src row, operand1=dst row
    RC_INIT = 0x02      # RowClone-Init:  operand0=zero row, operand1=dst row
    DR_GEN = 0x03       # D-RaNGe: operand0=row, operand1=n_bits
    BULK_COPY = 0x04    # multi-row copy: operands are base rows (count via imm)
    READ_BUF = 0x05     # drain random-number buffer into data register
    KV_WRITE = 0x06     # slot-granular KV scatter: JAX-face only (no DDR3
                        # command sequence exists for it; the model face
                        # reports it unsupported and callers fall back to
                        # the CPU write path)
    AMB_AND = 0x07      # Ambit AND: operand0=src row, operand1=dst row,
                        # dst <- src & dst (TRA, same-subarray only)
    AMB_OR = 0x08       # Ambit OR:  dst <- src | dst (TRA with C1 control row)
    AMB_NOT = 0x09      # Ambit NOT: dst <- ~src (dual-contact-cell row)
    SSM_STATE_WRITE = 0x0A  # slot-granular SSM recurrent-state scatter:
                        # JAX-face only, like KV_WRITE (no DDR3 sequence;
                        # the model face reports it unsupported and replay
                        # prices it as CPU traffic).  State page copy/init
                        # ride the existing RC_COPY/RC_INIT RowClone ops.


_OP_BITS = 28
_OP_MASK = (1 << _OP_BITS) - 1


@dataclass(frozen=True)
class Instruction:
    opcode: Opcode
    operand0: int = 0
    operand1: int = 0

    def encode(self) -> int:
        if not (0 <= self.operand0 <= _OP_MASK and 0 <= self.operand1 <= _OP_MASK):
            raise ValueError("operand out of range")
        return (int(self.opcode) << (2 * _OP_BITS)) | (self.operand1 << _OP_BITS) | self.operand0

    @staticmethod
    def decode(word: int) -> "Instruction":
        return Instruction(
            opcode=Opcode((word >> (2 * _OP_BITS)) & 0xFF),
            operand1=(word >> _OP_BITS) & _OP_MASK,
            operand0=word & _OP_MASK,
        )

"""Supervisor-software memory management: subarray-aware allocation.

PiDRAM's custom supervisor software provides the OS primitives that make
RowClone usable: allocation at row granularity, aligned to DRAM rows, with
source/destination placed in the *same subarray*.  This module implements
that allocator over any "address space" organized as groups of rows:

* the simulated DDR3 device (groups = discovered subarrays), used by the
  faithful reproduction, and
* the TPU HBM arena (groups = arena *slabs*, the contiguity domains inside
  which aliased zero-copy `pim_copy` is legal), used by the serving KV-cache
  manager and the training-state initializer.

The allocator also tracks per-row **coherence state** (clean / dirty-in-
cache), which the end-to-end model uses to decide whether a RowClone needs
CLFLUSH-style maintenance first (paper's 118.5x vs 14.6x distinction).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


class CoherenceState(enum.Enum):
    INVALID = "invalid"      # not cached anywhere; DRAM copy is authoritative
    CLEAN = "clean"          # cached, matches DRAM
    DIRTY = "dirty"          # cached and modified; DRAM copy is stale


class PimAllocError(Exception):
    pass


@dataclass
class Allocation:
    """A row-granularity allocation handle."""

    rows: Tuple[int, ...]
    group: int
    tag: str = ""

    @property
    def nrows(self) -> int:
        return len(self.rows)


@dataclass
class _Group:
    gid: int
    free: List[int]
    total: int


class SubarrayAllocator:
    """Row-granularity allocator with same-subarray placement constraints.

    ``groups`` maps group-id -> list of row ids (from subarray discovery or
    from arena slab layout).  The allocator is deliberately simple —
    per-group free lists with first-fit — because that is what the paper's
    supervisor implements; the interesting property is the *constraint
    language* (``same_group_as=``), not the fitting policy.
    """

    def __init__(self, groups: Dict[int, Sequence[int]]) -> None:
        if not groups:
            raise PimAllocError("no row groups supplied")
        self._groups: Dict[int, _Group] = {
            gid: _Group(gid, list(rows), len(rows)) for gid, rows in groups.items()
        }
        self._owner: Dict[int, Allocation] = {}
        self.coherence: Dict[int, CoherenceState] = {
            r: CoherenceState.INVALID for rows in groups.values() for r in rows
        }
        self.stats = {"allocs": 0, "frees": 0, "failed": 0}

    # ------------------------------------------------------------------ #

    def _group_with_space(self, nrows: int, exclude: Iterable[int] = ()) -> Optional[int]:
        excl = set(exclude)
        best: Optional[int] = None
        best_free = -1
        for gid, g in self._groups.items():
            if gid in excl:
                continue
            if len(g.free) >= nrows and len(g.free) > best_free:
                best, best_free = gid, len(g.free)
        return best

    def alloc(
        self,
        nrows: int,
        *,
        same_group_as: Optional[Allocation] = None,
        group: Optional[int] = None,
        tag: str = "",
    ) -> Allocation:
        """Allocate ``nrows`` rows from a single group.

        ``same_group_as`` expresses the RowClone constraint: the new rows
        are guaranteed to be in-subarray with the given allocation, so
        ``pim_copy`` between them is legal.
        """
        if same_group_as is not None:
            gid = same_group_as.group
        elif group is not None:
            gid = group
        else:
            g = self._group_with_space(nrows)
            if g is None:
                self.stats["failed"] += 1
                raise PimAllocError(f"no group with {nrows} free rows")
            gid = g

        grp = self._groups.get(gid)
        if grp is None:
            raise PimAllocError(f"unknown group {gid}")
        if len(grp.free) < nrows:
            self.stats["failed"] += 1
            raise PimAllocError(
                f"group {gid} has {len(grp.free)} free rows, need {nrows}"
                + (" (same-subarray constraint)" if same_group_as else "")
            )
        rows = tuple(grp.free[:nrows])
        del grp.free[:nrows]
        alloc = Allocation(rows=rows, group=gid, tag=tag)
        for r in rows:
            self._owner[r] = alloc
            self.coherence[r] = CoherenceState.INVALID
        self.stats["allocs"] += 1
        return alloc

    def alloc_copy_pair(self, nrows: int, tag: str = "") -> Tuple[Allocation, Allocation]:
        """Allocate src+dst operands satisfying RowClone's constraint."""
        gid = self._group_with_space(2 * nrows)
        if gid is None:
            self.stats["failed"] += 1
            raise PimAllocError(f"no group with {2 * nrows} free rows for copy pair")
        src = self.alloc(nrows, group=gid, tag=tag + ":src")
        dst = self.alloc(nrows, group=gid, tag=tag + ":dst")
        return src, dst

    def free(self, alloc: Allocation) -> None:
        grp = self._groups[alloc.group]
        for r in alloc.rows:
            if self._owner.get(r) is not alloc:
                raise PimAllocError(f"row {r} not owned by this allocation")
            del self._owner[r]
            grp.free.append(r)
            self.coherence[r] = CoherenceState.INVALID
        self.stats["frees"] += 1

    # Coherence tracking ------------------------------------------------- #

    def touch_cpu_write(self, alloc: Allocation) -> None:
        for r in alloc.rows:
            self.coherence[r] = CoherenceState.DIRTY

    def touch_cpu_read(self, alloc: Allocation) -> None:
        for r in alloc.rows:
            if self.coherence[r] is CoherenceState.INVALID:
                self.coherence[r] = CoherenceState.CLEAN

    def needs_flush(self, alloc: Allocation) -> bool:
        return any(self.coherence[r] is CoherenceState.DIRTY for r in alloc.rows)

    def mark_flushed(self, alloc: Allocation) -> None:
        for r in alloc.rows:
            self.coherence[r] = CoherenceState.CLEAN

    # Introspection ------------------------------------------------------ #

    def free_rows(self, gid: Optional[int] = None) -> int:
        if gid is not None:
            return len(self._groups[gid].free)
        return sum(len(g.free) for g in self._groups.values())

    def utilization(self) -> float:
        total = sum(g.total for g in self._groups.values())
        return 1.0 - self.free_rows() / total if total else 0.0

    @property
    def num_groups(self) -> int:
        return len(self._groups)

    def group_ids(self) -> List[int]:
        return sorted(self._groups)


def allocator_from_subarray_map(smap) -> SubarrayAllocator:
    """Build an allocator from a discovered :class:`SubarrayMap`."""
    return SubarrayAllocator({gid: rows for gid, rows in smap.members.items()})


def arena_groups(num_slabs: int, pages_per_slab: int) -> Dict[int, List[int]]:
    """Row groups for a TPU HBM arena: slab s owns pages [s*P, (s+1)*P)."""
    return {
        s: list(range(s * pages_per_slab, (s + 1) * pages_per_slab))
        for s in range(num_slabs)
    }

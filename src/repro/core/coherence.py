"""Cache-coherence maintenance model (PiDRAM SS2 / SS5).

PiM source operands must be up to date in DRAM.  On the prototype this
means a CLFLUSH-style operation per cache block of the operand; the paper
shows this collapses RowClone's 118.5x copy speedup to 14.6x.  This module
gives the framework a first-class coherence policy object so end-to-end
paths (benchmarks, the serving engine's page manager) charge the right
cost and so policies can be compared (the paper points at Dirty-Block
Index-style trackers as the fix; we model that as `PRECISE`).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass

from .allocator import Allocation, CoherenceState, SubarrayAllocator
from .memctrl import MemoryController


class CoherencePolicy(enum.Enum):
    #: No tracking: every PiM op conservatively flushes all operand blocks
    #: (the paper's "coherence" rows: 14.6x / 12.6x).
    CONSERVATIVE = "conservative"
    #: Perfect dirty tracking (Dirty-Block-Index-like): flush only when the
    #: allocator observed a CPU write since the last flush (118.5x rows when
    #: operands are PiM-private).
    PRECISE = "precise"
    #: Never flush — only valid when the software contract guarantees
    #: operands are never CPU-cached (e.g. device-resident arenas on TPU).
    NONE = "none"


@dataclass
class CoherenceModel:
    policy: CoherencePolicy
    mc: MemoryController

    def flush_cost_ns(self, alloc: Allocation, allocator: SubarrayAllocator, *, write_back: bool = True) -> float:
        nbytes = alloc.nrows * self.mc.proto.row_bytes
        if self.policy is CoherencePolicy.NONE:
            return 0.0
        if self.policy is CoherencePolicy.CONSERVATIVE:
            return self.mc.clflush_ns(nbytes) if write_back else self.mc.clinval_ns(nbytes)
        # PRECISE: charge only if the allocator saw dirty state.
        if allocator.needs_flush(alloc):
            allocator.mark_flushed(alloc)
            return self.mc.clflush_ns(nbytes) if write_back else self.mc.clinval_ns(nbytes)
        return 0.0

"""Trial-based DRAM subarray discovery (PiDRAM SS4.2 methodology).

RowClone requires source and destination rows to live in the *same DRAM
subarray*, but the row->subarray map is proprietary and chip-specific.
PiDRAM's supervisor software discovers it empirically: write known
patterns, attempt RowClone between candidate row pairs, and check whether
the destination changed.  Rows are then clustered into subarray groups
that the allocator consumes.

This module implements that methodology against the opaque
:class:`MemoryController` / :class:`SimulatedDRAM` interface — it never
reads the device's hidden map.

Discovery cost is O(rows) RowClone trials, not O(rows^2): each unmatched
row is trial-copied against one *representative* row per known group, and
a union-find collapses groups discovered to be equal (transitivity of
same-subarray membership lets us stop at the first hit).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .memctrl import MemoryController


@dataclass
class SubarrayMap:
    """Discovered row -> subarray-group mapping."""

    group_of: Dict[int, int] = field(default_factory=dict)
    members: Dict[int, List[int]] = field(default_factory=dict)
    trials: int = 0

    def same_subarray(self, a: int, b: int) -> bool:
        return (
            a in self.group_of
            and b in self.group_of
            and self.group_of[a] == self.group_of[b]
        )

    @property
    def num_groups(self) -> int:
        return len(self.members)


def _trial_rowclone(mc: MemoryController, src: int, dst: int, seed: int) -> bool:
    """One trial: write distinct patterns, attempt copy, verify."""
    rb = mc.device.geometry.row_bytes
    rng = np.random.default_rng(seed)
    pattern = rng.integers(0, 256, rb, dtype=np.uint8)
    anti = ~pattern
    mc.device.write_row(src, pattern)
    mc.device.write_row(dst, anti)
    mc.run_sequence("rowclone_copy", src, dst)
    return bool((mc.device.read_row(dst) == pattern).all())


def discover_subarrays(
    mc: MemoryController,
    rows: Optional[List[int]] = None,
    max_rows: Optional[int] = None,
    seed: int = 7,
) -> SubarrayMap:
    """Cluster ``rows`` into same-subarray groups via RowClone trials.

    Destructive to row contents (characterization pass runs before the
    allocator hands out rows, exactly as on the prototype).
    """
    geo = mc.device.geometry
    if rows is None:
        rows = list(range(geo.num_rows if max_rows is None else max_rows))

    smap = SubarrayMap()
    representatives: List[int] = []  # one row per discovered group

    for row in rows:
        placed = False
        for gid, rep in enumerate(representatives):
            smap.trials += 1
            if _trial_rowclone(mc, rep, row, seed + smap.trials):
                smap.group_of[row] = gid
                smap.members[gid].append(row)
                placed = True
                break
        if not placed:
            gid = len(representatives)
            representatives.append(row)
            smap.group_of[row] = gid
            smap.members[gid] = [row]
    return smap

"""Batched PiM operation scheduler: the deferred op queue.

PiDRAM's end-to-end lesson is that in-DRAM ops only win when the dispatch
path is amortized: one POC handshake per *batch* of row operations, not
per row.  The serving analogue: every CoW fork, page free, and
decode-round KV write used to issue ``O(num_layers)`` separate kernel
launches from Python.  This queue collects those arena mutations as
lightweight op records and flushes them as ONE coalesced launch per op
kind per arena — a constant number of dispatches regardless of layer
count or active-batch size.

Op kinds come from the opcode-keyed registry
(:mod:`repro.core.op_registry`): every spec with a JAX face contributes
its ``(jax_kind, jax_flush)`` pair at queue construction, so a new PiM
op is one ``register_pim_op`` call — the software twin of the paper's
"60 additional lines of Verilog" extensibility argument.  Ad-hoc kinds
can still be registered per-queue with :meth:`PimOpQueue.register_kind`.

``flush`` takes a variable number of arenas: the paged KV cache flushes
its (k, v) pair, while :class:`repro.core.pimolib.TpuLib` flushes its
buffer list through the same queue — both get per-kind coalescing and
unified launch accounting.  Work dispatched *outside* the queue but
belonging to the same accounting (the engine's fused decode step, one
jit call covering forward + scatter) is recorded with
:meth:`PimOpQueue.count_external` so per-round dispatch counts have one
source of truth.

Flush ordering is fixed and documented: ``page_copy`` ops land first
(CoW source pages must be duplicated before anything overwrites them),
then ``page_init`` (zeroing freed pages), then the Ambit bitwise kinds
(``page_and`` / ``page_or`` / ``page_not``, which read their operand
pages in place), then ``kv_write`` (fresh token KV).  Within a kind, op
order follows enqueue order; duplicate destinations resolve to the last
enqueued op.

Deferred clients that coalesce across calls use :meth:`admit` for
hazard-aware admission: because the queue replays by *kind*, enqueueing
an op that mixes kinds with the backlog, or that touches a row a
pending op already touched, would break program order — ``admit``
flushes the backlog first in exactly those cases, so the common bulk
case (many same-kind ops on disjoint rows) still coalesces to one
launch per kind.
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp

from . import op_registry
from .op_registry import KVWriteBatch

# A flush executor: (queue, arenas, ops) -> arenas (same length tuple).
FlushFn = Callable[["PimOpQueue", Tuple[jax.Array, ...], list],
                   Tuple[jax.Array, ...]]


class PimOpQueue:
    """Deferred queue of arena mutations, flushed as coalesced launches."""

    KIND_ORDER = ("page_copy", "page_init",
                  "page_and", "page_or", "page_not", "kv_write")

    def __init__(self, *, use_pallas: bool = False) -> None:
        self.use_pallas = use_pallas
        self._kinds: Dict[str, FlushFn] = {}
        self._pending: Dict[str, list] = {}
        self.stats = {
            "launches": 0,            # kernel dispatches issued (total)
            "flushes": 0,             # flush() calls that launched anything
            "ops_enqueued": 0,        # logical ops collected
            "ops_coalesced": 0,       # logical ops folded into launches
            "hazard_flushes": 0,      # admit() flushes forced by hazards
            "overlap_flushes": 0,     # backlogs dispatched early to overlap
            "ops_saved": 0,           # logical ops sharing made unnecessary
        }
        self.launches_by_kind: Dict[str, int] = {}
        # logical ops that never had to run because pages were shared
        # instead of rewritten (prefix-cache hits, pairwise sharing):
        # kind -> count.  The complement of launches_by_kind — "work the
        # dispatch path was spared", reported next to "work it did".
        self.saved_by_kind: Dict[str, int] = {}
        # per-owner attribution: owner tag -> {kind: launches}.  A launch
        # that spans shards (one SPMD dispatch over N per-shard buffers)
        # counts ONCE in launches/launches_by_kind and once per
        # participating owner here — the global counters stay the
        # dispatch-regression source of truth, the breakdown answers
        # "which arena/shard did that dispatch serve?".
        self.launches_by_owner: Dict[str, Dict[str, int]] = {}
        self._pending_owner: Dict[str, Set[str]] = {}
        # optional PimTrace sink (duck-typed: record_from_queue(kind, ops))
        self.trace = None
        # at most one lib drives a queue: owner tags are accounting
        # metadata, not routing — two libs flushing one queue would
        # still land each other's ops on the wrong arenas (TpuLib
        # claims this at construction)
        self.owner = None
        # hazard tracking for deferred clients (see admit())
        self._hazard_rows: Set[int] = set()
        self._hazard_kind: Optional[str] = None
        for kind, fn in op_registry.queue_kinds():
            self.register_kind(kind, fn)

    # -- extension registry (fed by repro.core.op_registry) -------------- #

    def register_kind(self, kind: str, fn: FlushFn) -> None:
        self._kinds[kind] = fn
        self._pending.setdefault(kind, [])
        self._pending_owner.setdefault(kind, set())
        self.launches_by_kind.setdefault(kind, 0)

    def has_kind(self, kind: str) -> bool:
        return kind in self._kinds

    # -- enqueue -------------------------------------------------------- #

    def enqueue(self, kind: str, op, n_ops: int = 1,
                owner: Optional[str] = None) -> None:
        """Collect one op record.  ``owner`` optionally tags the op with
        the lib/arena it belongs to; flush attributes the kind's launch
        to every distinct owner seen (falling back to the owning lib's
        :meth:`owner_tags`/``tag`` when ops carry no tag)."""
        if kind not in self._kinds:
            raise KeyError(f"unknown PiM op kind {kind!r}")
        self._pending[kind].append(op)
        if owner is not None:
            self._pending_owner[kind].add(owner)
        self.stats["ops_enqueued"] += n_ops

    def enqueue_copy(self, src_page: int, dst_page: int) -> None:
        self.enqueue("page_copy", (src_page, dst_page))

    def enqueue_init(self, page: int, value: float = 0.0) -> None:
        self.enqueue("page_init", (page, float(value)))

    def enqueue_kv_write(self, page: int, slot: int,
                         k: jax.Array, v: jax.Array) -> None:
        """Single token: k/v (layers, ...)."""
        self.enqueue_kv_writes([page], [slot],
                               jnp.asarray(k)[:, None], jnp.asarray(v)[:, None])

    def enqueue_kv_writes(self, pages, slots, k: jax.Array,
                          v: jax.Array) -> None:
        """Bulk form: pages/slots length-B, k/v (layers, B, ...) — stored
        stacked; no per-token host work.  An empty batch (e.g. a prompt
        fully covered by a shared prefix) enqueues nothing, so the
        launch counters only ever count real dispatches."""
        if len(pages) == 0:
            return
        batch = KVWriteBatch([int(p) for p in pages], [int(s) for s in slots],
                             k, v)
        self.enqueue("kv_write", batch, n_ops=batch.n)

    # -- hazard-aware deferred admission --------------------------------- #

    def admit(self, kind: str, rows: Iterable[int],
              flush: Callable[[], None], *,
              reads: Iterable[int] = ()) -> bool:
        """Admit ops of ``kind`` writing ``rows`` (and reading ``reads``)
        for deferred enqueue.

        The queue replays by kind (copies before inits before writes),
        so coalescing across a kind change, or touching a row a pending
        op already *wrote*, would break program order.  Reading a row
        other pending ops also read is safe (batched copies read the
        pre-flush arena state), so fan-out copies from one source still
        coalesce.  ``admit`` calls ``flush`` (the owning face's flush,
        which drains this queue against its arenas) exactly when a
        hazard exists, records the admitted write rows, and returns
        whether it flushed.  Flushing the queue clears the record.
        """
        rows = list(rows)
        flushed = False
        if self.pending_ops and (
                self._hazard_kind != kind
                or not self._hazard_rows.isdisjoint(rows)
                or not self._hazard_rows.isdisjoint(reads)):
            flush()
            flushed = True
            self.stats["hazard_flushes"] += 1
        self._hazard_kind = kind
        self._hazard_rows.update(rows)
        return flushed

    # -- flush ---------------------------------------------------------- #

    @property
    def pending_ops(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def _default_owners(self) -> Tuple[str, ...]:
        """Owner tags to attribute a launch to when its ops carried
        none: the owning lib's per-shard tags, its plain tag, or
        nothing (ownerless queues keep only the global counters)."""
        tags = getattr(self.owner, "owner_tags", None)
        if callable(tags):
            return tuple(tags())
        tag = getattr(self.owner, "tag", None)
        return (str(tag),) if tag else ()

    def _count_launch(self, kind: str, n: int = 1,
                      owners: Optional[Iterable[str]] = None) -> None:
        self.stats["launches"] += n
        self.launches_by_kind[kind] += n
        if owners is None:
            owners = self._pending_owner.get(kind) or self._default_owners()
        for o in sorted(owners):
            per = self.launches_by_owner.setdefault(o, {})
            per[kind] = per.get(kind, 0) + n

    def record_saved(self, kind: str, n: int = 1) -> None:
        """Account ``n`` logical ops of ``kind`` that sharing made
        unnecessary — e.g. a prefix-cache hit attaching 4 committed
        pages saves their ``kv_write`` token scatters (and the forward
        compute behind them).  Saved work is a first-class serving
        metric: the RowClone-traffic story is precisely that these ops
        become refcount bumps instead of launches."""
        self.saved_by_kind[kind] = self.saved_by_kind.get(kind, 0) + n
        self.stats["ops_saved"] += n

    def count_external(self, kind: str, n: int = 1,
                       owner=None) -> None:
        """Account kernel dispatches issued outside the queue (e.g. the
        engine's fused decode step, or the fused prefill batch's in-jit
        KV scatter) so launch counters stay the single source of truth
        for per-round dispatch regressions.  ``owner`` (a tag or an
        iterable of tags) attributes the dispatch in the per-owner
        breakdown; by default it lands on the owning lib's tags — for a
        sharded lib that is every shard the SPMD dispatch spanned."""
        self.launches_by_kind.setdefault(kind, 0)
        if owner is None:
            owners = None
        elif isinstance(owner, str):
            owners = (owner,)
        else:
            owners = tuple(owner)
        self._count_launch(kind, n, owners=owners)

    def snapshot(self, by_owner: bool = False) -> Dict:
        """Point-in-time copy of ``launches_by_kind`` for delta-based
        dispatch accounting: take one before a window of engine rounds,
        diff with :meth:`delta` after, and you have exactly the
        dispatches that window cost — the dispatches-per-token
        regression tests and the K-sweep benchmark both measure this
        way instead of trusting engine-side mirrors.  With
        ``by_owner=True`` the copy is the nested per-owner breakdown
        (``{owner: {kind: n}}``) instead."""
        if by_owner:
            return {o: dict(k) for o, k in self.launches_by_owner.items()}
        return dict(self.launches_by_kind)

    def delta(self, before: Dict, by_owner: bool = False) -> Dict:
        """Per-kind launches since ``before`` (a :meth:`snapshot` taken
        with the same ``by_owner``), zero-count kinds/owners omitted."""
        if by_owner:
            out: Dict[str, Dict[str, int]] = {}
            for o, kinds in self.launches_by_owner.items():
                prev = before.get(o, {})
                d = {k: v - prev.get(k, 0) for k, v in kinds.items()
                     if v - prev.get(k, 0)}
                if d:
                    out[o] = d
            return out
        return {k: v - before.get(k, 0)
                for k, v in self.launches_by_kind.items()
                if v - before.get(k, 0)}

    def flush_overlapped(self, flush: Callable[[], None]) -> bool:
        """Dispatch the pending backlog NOW so its device-side work runs
        behind upcoming host-side work (JAX dispatch is asynchronous).
        The serving engine calls this with the coming round's CoW copy
        backlog before assembling and tracing the prefill batch, so
        forking workloads pay the coalesced copy flush during prefill
        host work instead of stalling the decode step.  Returns whether
        anything was dispatched (counted in ``stats["overlap_flushes"]``
        — the launches themselves are accounted by the flush as usual).
        """
        if self.pending_ops == 0:
            return False
        flush()
        self.stats["overlap_flushes"] += 1
        return True

    def flush(self, *arenas: jax.Array) -> Tuple[jax.Array, ...]:
        """Drain the queue: one coalesced launch per op kind per arena.

        Returns the updated arenas (a tuple matching the input arity).
        Launch count per flush is bounded by ``len(arenas) *
        len(KIND_ORDER)`` no matter how many layers or sequences the
        pending ops span.
        """
        self._hazard_rows.clear()
        self._hazard_kind = None
        if self.pending_ops == 0:
            return arenas
        any_launch = False
        order = [k for k in self.KIND_ORDER if k in self._kinds]
        order += [k for k in self._kinds if k not in order]
        for kind in order:
            ops = self._pending[kind]
            if not ops:
                continue
            self._pending[kind] = []
            if self.trace is not None:
                self.trace.record_from_queue(kind, ops)
            arenas = self._kinds[kind](self, arenas, ops)
            self._pending_owner[kind] = set()
            # logical ops, matching ops_enqueued (a KVWriteBatch record
            # carries .n token writes)
            self.stats["ops_coalesced"] += sum(getattr(o, "n", 1) for o in ops)
            any_launch = True
        if any_launch:
            self.stats["flushes"] += 1
        return arenas

"""PiDRAM core: the paper's contribution as a composable layer.

pimolib v2: one :class:`PimLib` protocol (copy/init/rand/read/write/
flush, unified :class:`OpReceipt`) over two faces, backed by the
opcode-keyed op registry (:mod:`repro.core.op_registry`) and the
batched PiM op scheduler (:mod:`repro.core.pim_queue`).

Faithful-reproduction substrate (simulated DDR3 prototype):
  timing, dram_model, memctrl, subarray, allocator, coherence, isa, poc,
  drange, pimolib.DeviceLib

TPU-native substrate (JAX/Pallas):
  pimolib.TpuLib / TpuArena over repro.kernels.*
"""

from .allocator import (Allocation, CoherenceState, PimAllocError,
                        SubarrayAllocator, allocator_from_subarray_map,
                        arena_groups)
from .coherence import CoherenceModel, CoherencePolicy
from .dram_model import CellPhysics, DRAMGeometry, SimulatedDRAM
from .drange import DRangeTRNG, characterize
from .isa import Instruction, Opcode
from .memctrl import EndToEndCosts, MemoryController
from .op_registry import (FACE_DEVICE, FACE_JAX, KVWriteBatch, PimOpSpec,
                          get_op, ops_for_face, register_pim_op,
                          unregister_pim_op)
from .pim_queue import PimOpQueue
from .pimolib import (Blocking, DeviceLib, OpReceipt, PimLib, TpuArena,
                      TpuLib, make_tpu_arena)
from .poc import PimOpsController
from .subarray import SubarrayMap, discover_subarrays
from .timing import (DDR3Timings, PrototypeParams, ViolatedTimings,
                     DEFAULT_PROTOTYPE, DEFAULT_TIMINGS, DEFAULT_VIOLATIONS)

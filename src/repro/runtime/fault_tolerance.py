"""Fault tolerance: supervised training loop with checkpoint/restart,
failure injection, heartbeat/straggler detection and elastic restart.

Single-process embodiment of the multi-pod control plane:

* **Supervisor** — runs the step loop, checkpoints every N steps
  (async), catches worker failures (``FailureInjector`` simulates chip /
  host loss) and restarts from the latest checkpoint; the data pipeline
  is counter-keyed so replayed steps are bit-identical.
* **HeartbeatMonitor** — per-step wall-time heartbeats; a step slower
  than ``straggler_factor`` x rolling median flags a straggler (at pod
  scale this triggers requeue-on-spare; here it is recorded and
  surfaced in the step log).
* **Elastic restart** — checkpoints are mesh-agnostic (see
  `repro.checkpoint`), so the supervisor can be re-launched with a
  different mesh and resume; tested in tests/test_fault_tolerance.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raise at given steps (once each) to simulate node loss."""

    fail_at: List[int] = field(default_factory=list)
    _fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")


@dataclass
class HeartbeatMonitor:
    straggler_factor: float = 3.0
    window: int = 32
    durations: List[float] = field(default_factory=list)
    stragglers: List[int] = field(default_factory=list)
    last_beat: float = field(default_factory=time.monotonic)

    def beat(self, step: int) -> bool:
        now = time.monotonic()
        dur = now - self.last_beat
        self.last_beat = now
        self.durations.append(dur)
        hist = self.durations[-self.window:]
        med = float(np.median(hist[:-1])) if len(hist) > 4 else None
        is_straggler = med is not None and dur > self.straggler_factor * med
        if is_straggler:
            self.stragglers.append(step)
        return is_straggler


@dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    resumed_from: List[int] = field(default_factory=list)
    stragglers: List[int] = field(default_factory=list)
    losses: Dict[int, float] = field(default_factory=dict)


class Supervisor:
    """Run `num_steps` of `step_fn` with checkpoint/restart supervision.

    step_fn(state, batch) -> (state, metrics); batch_fn(step) -> batch.
    """

    def __init__(self, checkpointer, *, ckpt_every: int = 10,
                 max_restarts: int = 5,
                 injector: Optional[FailureInjector] = None):
        self.ckpt = checkpointer
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.injector = injector or FailureInjector()
        self.monitor = HeartbeatMonitor()

    def run(self, state: Any, step_fn: Callable, batch_fn: Callable,
            num_steps: int, start_step: int = 0) -> (Any, SupervisorReport):
        report = SupervisorReport()
        step = start_step
        restarts = 0
        while step < num_steps:
            try:
                self.injector.check(step)
                batch = batch_fn(step)
                state, metrics = step_fn(state, batch)
                if self.monitor.beat(step):
                    report.stragglers.append(step)
                loss = metrics.get("loss")
                if loss is not None:
                    report.losses[step] = float(loss)
                report.steps_run += 1
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except InjectedFailure:
                restarts += 1
                report.restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                latest = self.ckpt.latest_step()
                if latest is None:
                    step = start_step  # cold restart
                    continue
                state, step = self.ckpt.load(state)
                report.resumed_from.append(step)
        self.ckpt.wait()
        return state, report

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory/cost/collective evidence.

The two lines above MUST precede any jax import (jax locks the device
count at first init); do not move them.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --arch granite-3-8b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all          # subprocess sweep driver

Artifacts: experiments/dryrun/<arch>__<shape>__<mesh>.json
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, ParallelConfig, OptimizerConfig, cells_for
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import resolve_spec, sharding_env
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.params import abstract_params, param_count, param_specs
from repro.roofline import analysis as ra
from repro.roofline import hw
from repro.training import train_step as ts

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")

# Per-arch parallel overrides for the production dry-run (big configs use
# bf16 masters + bf16 optimizer moments and more microbatches; see
# EXPERIMENTS.md §Dry-run notes).
PARALLEL_OVERRIDES: Dict[str, Dict[str, Any]] = {
    "jamba-1.5-large-398b": dict(param_dtype="bfloat16", opt_state_dtype="bfloat16",
                                 microbatches=8),
    "deepseek-v2-236b": dict(param_dtype="bfloat16", opt_state_dtype="bfloat16",
                             microbatches=8),
    "llava-next-34b": dict(param_dtype="bfloat16", opt_state_dtype="bfloat16",
                           microbatches=8),
    "llama4-scout-17b-a16e": dict(microbatches=8),
    "granite-3-8b": dict(microbatches=4),
    "minitron-8b": dict(microbatches=2),
}


def parallel_for(cfg: ModelConfig, multi_pod: bool, **overrides) -> ParallelConfig:
    kw: Dict[str, Any] = dict(multi_pod=multi_pod, remat="full",
                              attention_impl="chunked", moe_impl="shard_map")
    kw.update(PARALLEL_OVERRIDES.get(cfg.name, {}))
    kw.update(overrides)
    return ParallelConfig(**kw)


# --------------------------------------------------------------------- #
# input_specs — ShapeDtypeStruct stand-ins for every model input
# --------------------------------------------------------------------- #


def enc_dec_split(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[int, int]:
    """(enc_len, dec_len) per DESIGN.md SS6."""
    if shape.kind == "train":
        return shape.seq_len // 2, shape.seq_len // 2
    if shape.kind == "prefill":
        return 4096, shape.seq_len - 4096
    return 4096, shape.seq_len  # decode: dec KV budget = seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                pcfg: ParallelConfig) -> Dict[str, Any]:
    """Abstract batch for the step function (no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    fd = cfg.frontend_dim or cfg.d_model

    if shape.kind == "train":
        if cfg.family == "encdec":
            e, d = enc_dec_split(cfg, shape)
            return {"tokens": sds((B, d), jnp.int32),
                    "labels": sds((B, d), jnp.int32),
                    "frames": sds((B, e, fd), jnp.float32)}
        if cfg.family == "vlm":
            return {"tokens": sds((B, S - cfg.num_patch_tokens), jnp.int32),
                    "labels": sds((B, S), jnp.int32),
                    "patch_embeds": sds((B, cfg.num_patch_tokens, fd), jnp.float32)}
        return {"tokens": sds((B, S), jnp.int32),
                "labels": sds((B, S), jnp.int32)}

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            e, d = enc_dec_split(cfg, shape)
            return {"tokens": sds((B, d), jnp.int32),
                    "frames": sds((B, e, fd), jnp.float32)}
        if cfg.family == "vlm":
            return {"tokens": sds((B, S - cfg.num_patch_tokens), jnp.int32),
                    "patch_embeds": sds((B, cfg.num_patch_tokens, fd), jnp.float32)}
        return {"tokens": sds((B, S), jnp.int32)}

    # decode
    return {"tokens": sds((B, 1), jnp.int32)}


def batch_pspecs(cfg: ModelConfig, batch: Dict[str, Any]) -> Dict[str, P]:
    return {k: resolve_spec(v.shape, ("batch",) + (None,) * (len(v.shape) - 1))
            for k, v in batch.items()}


# --------------------------------------------------------------------- #
# Cell lowering
# --------------------------------------------------------------------- #


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, *, multi_pod: bool,
               parallel_overrides: Optional[Dict[str, Any]] = None):
    """Build mesh + abstract inputs, lower and compile the step. Returns
    (compiled, lowered, info_dict)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    pcfg = parallel_for(cfg, multi_pod, **(parallel_overrides or {}))
    pdtype = jnp.bfloat16 if pcfg.param_dtype == "bfloat16" else jnp.float32
    sdtype = jnp.bfloat16 if pcfg.opt_state_dtype == "bfloat16" else jnp.float32

    from repro.distributed.sharding import default_rules
    rules = default_rules(multi_pod)
    if pcfg.row_parallel_attn:
        rules["dmodel_rp"] = ("model",)
    with sharding_env(mesh, multi_pod=multi_pod, fsdp=pcfg.fsdp, rules=rules):
        defs = T.model_defs(cfg)
        pspecs = param_specs(defs)
        n_params = param_count(defs)
        batch = input_specs(cfg, shape, pcfg)
        bspecs = batch_pspecs(cfg, batch)

        if shape.kind == "train":
            params_abs = abstract_params(defs, pdtype)
            init_state, step = ts.make_train_step(
                cfg, pcfg, OptimizerConfig(), state_dtype=sdtype)
            opt_specs = {"m": pspecs, "v": pspecs, "step": P()}
            state_abs = {
                "params": params_abs,
                "opt": {"m": abstract_params(defs, sdtype),
                        "v": abstract_params(defs, sdtype),
                        "step": jax.ShapeDtypeStruct((), jnp.int32)},
            }
            state_specs = {"params": pspecs, "opt": opt_specs}
            jf = jax.jit(step,
                         in_shardings=(_ns(mesh, state_specs), _ns(mesh, bspecs)),
                         out_shardings=(_ns(mesh, state_specs), None),
                         donate_argnums=(0,))
            lowered = jf.lower(state_abs, batch)
        else:
            params_abs = abstract_params(defs, jnp.bfloat16)  # serving: bf16
            B = shape.global_batch
            enc_len = enc_dec_split(cfg, shape)[0] if cfg.family == "encdec" else 0
            max_len = shape.seq_len
            kvd = jnp.float8_e4m3fn if pcfg.kv_cache_dtype.startswith("float8") \
                else None
            cache_abs = T.cache_spec(cfg, B, max_len, enc_len, kv_dtype=kvd)
            cspecs = T.cache_pspecs(cfg, B, max_len, enc_len)
            lens = jax.ShapeDtypeStruct((B,), jnp.int32)
            lens_spec = resolve_spec((B,), ("batch",))
            if shape.kind == "prefill":
                step = ts.make_prefill_step(cfg, pcfg)
                jf = jax.jit(step,
                             in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs),
                                           _ns(mesh, cspecs),
                                           NamedSharding(mesh, lens_spec)),
                             out_shardings=(None, _ns(mesh, cspecs)),
                             donate_argnums=(2,))
                lowered = jf.lower(params_abs, batch, cache_abs, lens)
            else:
                step = ts.make_decode_step(cfg, pcfg)
                wpos = jax.ShapeDtypeStruct((), jnp.int32)
                jf = jax.jit(step,
                             in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs),
                                           _ns(mesh, cspecs),
                                           NamedSharding(mesh, P()),
                                           NamedSharding(mesh, lens_spec)),
                             out_shardings=(None, _ns(mesh, cspecs)),
                             donate_argnums=(2,))
                lowered = jf.lower(params_abs, batch, cache_abs, wpos, lens)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    info = {"arch": cfg.name, "shape": shape.name,
            "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
            "params": n_params, "compile_s": compile_s,
            "param_dtype": pcfg.param_dtype,
            "opt_state_dtype": pcfg.opt_state_dtype,
            "microbatches": pcfg.microbatches}
    return compiled, lowered, info


def analytic_memory(cfg: ModelConfig, shape: ShapeConfig, info: Dict[str, Any]) -> int:
    """First-principles per-device HBM estimate (TPU dtype semantics).

    train: params + grads + 2 opt moments (all sharded over every chip)
           + remat carry stacks + working set allowance.
    serve: bf16 params + KV cache (batch x seq sharded) + activations.
    """
    chips = info["chips"]
    n = info["params"]
    pbytes = 2 if info["param_dtype"] == "bfloat16" else 4
    sbytes = 2 if info["opt_state_dtype"] == "bfloat16" else 4
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    data_shards = chips // 16  # model axis = 16 on both meshes
    b_local = max(B // data_shards, 1)
    mb = max(info.get("microbatches", 1), 1)

    if shape.kind == "train":
        states = n * (pbytes + 4 + 2 * sbytes) / chips  # +grads fp32
        carry = cfg.num_layers * (b_local // mb) * S * d * 2  # bf16 stacks
        work = 4 * (b_local // mb) * S * d * 4  # a few fp32 working copies
        return int(states + carry + work)

    # serving: bf16 params + cache + small activations
    params_b = n * 2 / chips
    hd = cfg.resolved_head_dim
    if cfg.mla:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
        layers = cfg.num_layers
    elif cfg.family == "ssm":
        per_tok, layers = 0, 0
    elif cfg.family == "hybrid":
        per_tok = 2 * cfg.num_kv_heads * hd
        layers = cfg.num_layers // cfg.attn_every
    elif cfg.family == "encdec":
        per_tok = 2 * cfg.num_kv_heads * hd
        layers = cfg.dec_layers
    else:
        per_tok = 2 * cfg.num_kv_heads * hd
        layers = cfg.num_layers
    cache = layers * B * S * per_tok * 2 / chips  # sharded batch x seq
    if cfg.ssm:
        s_ = cfg.ssm
        d_in = s_.expand * d
        nh = d_in // s_.head_dim
        n_ssm = (cfg.num_layers - cfg.num_layers // cfg.attn_every
                 if cfg.family == "hybrid" else cfg.num_layers)
        cache += n_ssm * B * nh * s_.head_dim * s_.state_dim * 4 / max(data_shards, 1)
    toks = B if shape.kind == "decode" else B * S
    act = 6 * (toks // max(data_shards, 1)) * d * 2
    return int(params_b + cache + act)


def analyze(compiled, lowered, cfg, shape, info) -> Dict[str, Any]:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = ra.parse_collective_bytes(hlo)
    chips = info["chips"]
    terms = ra.RooflineTerms(
        flops=float(cost.get("flops", 0.0)),
        hbm_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(ra.collective_bytes_total(coll)),
        chips=chips,
        model_flops=ra.model_flops(cfg, shape, info["params"]),
    )
    arg_b = int(mem.argument_size_in_bytes)
    out_b = int(mem.output_size_in_bytes)
    tmp_b = int(mem.temp_size_in_bytes)
    alias_b = int(mem.alias_size_in_bytes)
    peak = arg_b + out_b + tmp_b - alias_b
    analytic = analytic_memory(cfg, shape, info)
    rec = dict(info)
    rec.update({
        "memory": {"argument_bytes": arg_b, "output_bytes": out_b,
                   "temp_bytes": tmp_b, "alias_bytes": alias_b,
                   "peak_bytes_per_device": peak,
                   # CPU backend emulates bf16 in f32 and upcasts whole
                   # saved-residual stacks; TPU keeps bf16. See
                   # EXPERIMENTS.md §Dry-run for the analytic model.
                   "fits_16GiB_hlo_cpu": bool(peak <= hw.HBM_BYTES),
                   "analytic_bytes_per_device": analytic,
                   "fits_16GiB_analytic": bool(analytic <= hw.HBM_BYTES)},
        "collectives": {k: int(v) for k, v in coll.items() if k != "_counts"},
        "collective_counts": coll.get("_counts", {}),
        "roofline": terms.as_dict(),
    })
    return rec


def _cost_scaled_cfgs(cfg: ModelConfig):
    """Two reduced-depth variants (n = uniform-group repeat count) plus
    the full repeat count, for affine cost extrapolation."""
    import dataclasses as dc
    if cfg.family == "hybrid":
        per = cfg.attn_every  # one superblock = `per` sublayers
        return ([(dc.replace(cfg, num_layers=per), 1),
                 (dc.replace(cfg, num_layers=2 * per), 2)],
                cfg.num_layers // per)
    if cfg.family == "encdec":
        return ([(dc.replace(cfg, enc_layers=2, dec_layers=2, num_layers=4), 1),
                 (dc.replace(cfg, enc_layers=4, dec_layers=4, num_layers=8), 2)],
                cfg.enc_layers // 2)
    if cfg.moe and cfg.moe.first_dense_layers:
        fd = cfg.moe.first_dense_layers
        return ([(dc.replace(cfg, num_layers=fd + 2), 2),
                 (dc.replace(cfg, num_layers=fd + 4), 4)],
                cfg.num_layers - fd)
    return ([(dc.replace(cfg, num_layers=2), 2),
             (dc.replace(cfg, num_layers=4), 4)],
            cfg.num_layers)


_COST_KEYS = ("flops_per_chip", "hbm_bytes_per_chip", "collective_bytes_per_chip")


def cost_metrics_extrapolated(cfg: ModelConfig, shape: ShapeConfig,
                              multi_pod: bool,
                              parallel_overrides: Optional[Dict[str, Any]] = None
                              ) -> Dict[str, Any]:
    """Exact affine extrapolation of per-chip cost metrics in layer count.

    Layers within a uniform group are identical, so every additive HLO
    metric (flops, bytes, per-kind collective bytes) is affine in the
    group repeat count n:  m(n) = a + b*n.  Two fully-unrolled reduced
    lowerings (n1 < n2 << n_full) pin (a, b); we report m(n_full).
    """
    (pairs, n_full) = _cost_scaled_cfgs(cfg)
    cost_over = dict(parallel_overrides or {})
    cost_over.update(scan_unroll=True, microbatches=1, attention_chunk=4096)

    # inner SSD chunk-scan: full unroll only when short; otherwise a
    # partial unroll k with a second affine extrapolation in k
    # (cost(L, k) = base + L*(layer_base + k*step) — a while body is
    # counted once, so the counted cost is affine in the unroll factor).
    nc_ssd = 0
    if cfg.ssm is not None and shape.kind in ("train", "prefill"):
        seq = shape.seq_len if shape.kind != "train" else shape.seq_len
        nc_ssd = -(-seq // cfg.ssm.chunk_size)
    use_k_extrap = nc_ssd > 32

    def lower_sample(sub_cfg, k):
        over = dict(cost_over)
        if use_k_extrap:
            over["ssd_unroll"] = k
        c, l, i = lower_cell(sub_cfg, shape, multi_pod=multi_pod,
                             parallel_overrides=over)
        rec = analyze(c, l, sub_cfg, shape, i)
        m = {key: rec["roofline"][key] for key in ("flops_per_chip",
                                                   "hbm_bytes_per_chip",
                                                   "collective_bytes_per_chip")}
        m["collectives"] = rec["collectives"]
        return m, i["compile_s"]

    (cfgA, nA), (cfgB, nB) = pairs
    k1, k2 = 2, 4

    def combine(f):
        """Apply scalar-extrapolation fn over all metrics."""
        keys = ("flops_per_chip", "hbm_bytes_per_chip",
                "collective_bytes_per_chip")
        out = {k: float(f(lambda m: m[k])) for k in keys}
        coll_keys = samples_m[0]["collectives"].keys()
        out["collectives"] = {
            k: int(max(f(lambda m, kk=k: m["collectives"][kk]), 0))
            for k in coll_keys}
        return out

    if not use_k_extrap:
        mA, tA = lower_sample(cfgA, 0)
        mB, tB = lower_sample(cfgB, 0)
        samples_m = [mA, mB]

        def extrap(g):
            a, b = g(mA), g(mB)
            return a + (b - a) / (nB - nA) * (n_full - nA)

        out = combine(extrap)
        out["cost_compile_s"] = tA + tB
        out["extrapolated_from"] = [nA, nB, n_full]
        return out

    # 3-sample scheme: (A, k1), (B, k1), (B, k2) -> extrapolate L and k
    mA1, tA1 = lower_sample(cfgA, k1)
    mB1, tB1 = lower_sample(cfgB, k1)
    mB2, tB2 = lower_sample(cfgB, k2)
    samples_m = [mA1, mB1, mB2]

    def extrap(g):
        step = (g(mB2) - g(mB1)) / (nB * (k2 - k1))      # per-(layer,chunk)
        b_k1 = (g(mB1) - g(mA1)) / (nB - nA)             # per-layer @ k1
        layer_base = b_k1 - k1 * step
        base = g(mA1) - nA * b_k1
        return base + n_full * (layer_base + nc_ssd * step)

    out = combine(extrap)
    out["cost_compile_s"] = tA1 + tB1 + tB2
    out["extrapolated_from"] = [nA, nB, n_full, k1, k2, nc_ssd]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = ARTIFACT_DIR,
             parallel_overrides: Optional[Dict[str, Any]] = None,
             tag: str = "", cost_pass: Optional[bool] = None) -> Dict[str, Any]:
    """Two measurement paths per cell:

    1. *proof* — full config, production settings (scanned layers,
       chunk 1024, microbatching): memory_analysis + compile evidence.
    2. *cost* — fully-unrolled reduced-depth lowerings, affinely
       extrapolated to full depth (HLO while bodies are otherwise
       counted once).  Single-pod only (the roofline table is 1-pod).
    """
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    compiled, lowered, info = lower_cell(cfg, shape, multi_pod=multi_pod,
                                         parallel_overrides=parallel_overrides)
    rec = analyze(compiled, lowered, cfg, shape, info)
    rec["roofline_scanbody"] = rec.pop("roofline")  # undercounted; kept for reference

    if cost_pass is None:
        cost_pass = not multi_pod
    if cost_pass:
        try:
            ext = cost_metrics_extrapolated(cfg, shape, multi_pod,
                                            parallel_overrides)
            terms = ra.RooflineTerms(
                flops=ext["flops_per_chip"],
                hbm_bytes=ext["hbm_bytes_per_chip"],
                coll_bytes=ext["collective_bytes_per_chip"],
                chips=info["chips"],
                model_flops=ra.model_flops(cfg, shape, info["params"]),
            )
            rec["roofline"] = terms.as_dict()
            rec["collectives"] = ext["collectives"]
            rec["cost_compile_s"] = ext["cost_compile_s"]
            rec["cost_extrapolated_from"] = ext["extrapolated_from"]
        except Exception as e:  # keep proof artifact; flag cost failure
            rec["cost_pass_error"] = repr(e)[:500]

    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape_name}__{rec['mesh']}{tag}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


# --------------------------------------------------------------------- #
# Sweep driver (subprocesses: fresh devices per cell, parallelism)
# --------------------------------------------------------------------- #


def all_cells(multi_pod_too: bool = True):
    cells = []
    for arch, cfg in ARCHS.items():
        for shape in cells_for(cfg):
            cells.append((arch, shape.name, False))
            if multi_pod_too:
                cells.append((arch, shape.name, True))
    return cells


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = all_cells()
        procs: Dict[Any, Tuple] = {}
        failures = []
        todo = list(cells)
        while todo or procs:
            while todo and len(procs) < args.jobs:
                arch, shape, mp = todo.pop(0)
                mesh_tag = "2x16x16" if mp else "16x16"
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh_tag}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"skip {arch} {shape} {mesh_tag}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", args.out]
                if mp:
                    cmd.append("--multi-pod")
                p = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                     stderr=subprocess.STDOUT)
                procs[p] = (arch, shape, mp)
            for p in list(procs):
                if p.poll() is not None:
                    arch, shape, mp = procs.pop(p)
                    out = p.stdout.read().decode()
                    status = "OK" if p.returncode == 0 else "FAIL"
                    print(f"[{status}] {arch} {shape} {'2pod' if mp else '1pod'}")
                    if p.returncode != 0:
                        failures.append((arch, shape, mp, out[-2000:]))
            time.sleep(1.0)
        for arch, shape, mp, out in failures:
            print(f"--- FAILURE {arch} {shape} mp={mp} ---\n{out}\n")
        return 1 if failures else 0

    rec = run_cell(args.arch, args.shape, args.multi_pod, args.out)
    print(json.dumps({k: rec.get(k) for k in
                      ("arch", "shape", "mesh", "compile_s",
                       "cost_compile_s", "cost_pass_error")}, indent=1))
    print(json.dumps(rec["memory"], indent=1))
    if "roofline" in rec:
        print(json.dumps(rec["roofline"], indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())

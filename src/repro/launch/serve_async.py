"""Open-system serving driver: Poisson arrivals against the async
front door, measured as goodput-under-SLO.

  PYTHONPATH=src python -m repro.launch.serve_async --arch granite-3-8b \
      --rates 2,8,32 --requests 24 --ttft-slo-ms 500

Closed-loop drivers (``repro.launch.serve``) understate tail latency:
the next request only arrives when the last one finished, so the system
is never overloaded.  This driver is open-loop — arrivals follow a
Poisson process at a fixed rate whatever the server is doing — and
reports what production cares about: how much work completed *within
its SLO* (goodput), how much was shed at admission, and what the
prefix cache turned into RowClone traffic along the way
(:func:`repro.serving.trace.replay_on_device` on the recorded trace).
Sweeping the rate traces the saturation curve benchmark table 7
records.
"""

from __future__ import annotations

import argparse
import asyncio
import json
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.server import AsyncServer, TokenStream


def _percentile(xs: List[float], q: float) -> Optional[float]:
    return float(np.percentile(xs, q)) if xs else None


async def poisson_open_loop(server: AsyncServer, prompts: Sequence,
                            rate_rps: float, *, max_new_tokens: int = 16,
                            temperature: float = 0.0,
                            deadline_ms: Optional[float] = None,
                            seed: int = 0) -> Dict[str, object]:
    """Drive ``server`` with one open-loop Poisson trace.

    One request per entry of ``prompts``, inter-arrival gaps drawn
    i.i.d. exponential at ``rate_rps``; every stream is consumed
    concurrently (tokens are awaited as they arrive, like a real
    client).  Returns the trace's SLO accounting:

    * ``goodput_rps`` / ``goodput_tok_s`` — requests (and their tokens)
      that were admitted, completed, AND met their deadline, per second
      of trace wall-time;
    * ``rejected`` — shed at admission (infeasible deadline);
    * ``ttft_ms`` / ``itl_p99_ms`` — latency percentiles over completed
      requests;
    * per-request detail in ``streams`` (the :class:`TokenStream`
      objects, timing marks included).
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=len(prompts))
    streams: List[TokenStream] = []
    consumers: List[asyncio.Task] = []
    t0 = asyncio.get_running_loop().time()
    for prompt, gap in zip(prompts, gaps):
        await asyncio.sleep(float(gap))
        s = await server.submit(prompt, max_new_tokens=max_new_tokens,
                                temperature=temperature,
                                deadline_ms=deadline_ms)
        streams.append(s)
        consumers.append(asyncio.ensure_future(s.drain()))
    await asyncio.gather(*consumers)
    wall_s = asyncio.get_running_loop().time() - t0

    good = [s for s in streams
            if not s.rejected and s.finished_ms is not None
            and (deadline_ms is None or s.e2e_ms <= deadline_ms)]
    ttfts = [s.ttft_ms for s in streams if s.ttft_ms is not None]
    itls = [g for s in streams for g in s.itl_ms()]
    return {
        "rate_rps": rate_rps,
        "requests": len(streams),
        "rejected": sum(s.rejected for s in streams),
        "completed": sum(s.finished_ms is not None and not s.rejected
                         for s in streams),
        "good": len(good),
        "goodput_rps": len(good) / wall_s,
        "goodput_tok_s": sum(len(s.tokens) for s in good) / wall_s,
        "wall_s": wall_s,
        "ttft_p50_ms": _percentile(ttfts, 50),
        "ttft_p99_ms": _percentile(ttfts, 99),
        "itl_p50_ms": _percentile(itls, 50),
        "itl_p99_ms": _percentile(itls, 99),
        "streams": streams,
    }


def shared_prefix_prompts(n: int, vocab: int, *, prefix_len: int,
                          tail_len: int, seed: int = 0) -> List[np.ndarray]:
    """A multi-tenant trace: every prompt opens with the same
    ``prefix_len``-token system prompt, followed by a per-request
    ``tail_len``-token suffix — the workload where the radix prefix
    cache turns (n-1) prefills into page attaches."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(0, vocab, prefix_len).astype(np.int32)
    return [np.concatenate([sys_prompt,
                            rng.integers(0, vocab, tail_len)
                            .astype(np.int32)])
            for _ in range(n)]


async def _amain(args) -> None:
    import jax
    from repro.configs import ARCHS, reduced
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.engine import PagedEngine, Request
    from repro.serving.trace import replay_on_device

    cfg = reduced(ARCHS[args.arch])
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    rates = [float(r) for r in args.rates.split(",")]
    out = []
    for rate in rates:
        engine = PagedEngine(cfg, params, page_size=args.page_size,
                             num_pages=args.num_pages,
                             max_prefill_chunk=args.chunk,
                             prefix_cache=True, record_trace=True)
        # warm the compile caches outside the timed trace
        engine.submit(Request(10**6, np.arange(args.prefix_len + args.tail_len)
                              % cfg.vocab_size, max_new_tokens=2))
        engine.run()
        prompts = shared_prefix_prompts(
            args.requests, cfg.vocab_size,
            prefix_len=args.prefix_len, tail_len=args.tail_len)
        server = AsyncServer(engine, ttft_slo_ms=args.ttft_slo_ms,
                             itl_p99_target_ms=args.itl_target_ms)
        async with server:
            res = await poisson_open_loop(
                server, prompts, rate, max_new_tokens=args.max_new,
                deadline_ms=args.deadline_ms)
        res.pop("streams")
        res["prefix"] = {k: engine.stats[k] for k in
                         ("prefix_hits", "prefix_hit_tokens",
                          "prefix_evictions")}
        res["ops_saved"] = dict(engine.cache.queue.saved_by_kind)
        rep = replay_on_device(engine.cache.trace)
        res["replay_speedup"] = rep["speedup"]
        out.append(res)
        print(json.dumps(res, indent=1))
    print(json.dumps({"sweep": [
        {k: r[k] for k in ("rate_rps", "goodput_rps", "rejected",
                           "ttft_p99_ms")} for r in out]}, indent=1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--rates", default="2,8,32",
                    help="comma-separated Poisson arrival rates (req/s)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prefix-len", type=int, default=32,
                    help="shared system-prompt length (tokens)")
    ap.add_argument("--tail-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=256)
    ap.add_argument("--chunk", type=int, default=64,
                    help="initial max_prefill_chunk (auto-tuned)")
    ap.add_argument("--ttft-slo-ms", type=float, default=1000.0)
    ap.add_argument("--deadline-ms", type=float, default=None)
    ap.add_argument("--itl-target-ms", type=float, default=None,
                    help="decode-p99 target for the chunk auto-tuner")
    asyncio.run(_amain(ap.parse_args()))


if __name__ == "__main__":
    main()

"""Production mesh builders.

Functions (not module constants) so importing never touches jax device
state — `dryrun.py` must set XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, *, pod: int = 0):
    """Small meshes for CPU tests (device count permitting)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))

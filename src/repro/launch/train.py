"""End-to-end training driver.

Example (CPU, reduced 100M-class model, few hundred steps):

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --reduced \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On a real cluster the same driver runs under the production mesh
(--mesh data,model) with per-host data sharding; here the mesh defaults
to all local devices on the `data` axis.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCHS, OptimizerConfig, ParallelConfig, ShapeConfig, reduced
from repro.data.pipeline import PipelineConfig, Prefetcher, SyntheticLM
from repro.distributed.sharding import sharding_env
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.models.params import init_params, param_count
from repro.runtime.fault_tolerance import FailureInjector, Supervisor
from repro.training.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--width", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject node failures at these steps (FT demo)")
    ap.add_argument("--data-axis", type=int, default=0,
                    help="mesh data size (0 = all local devices)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg, d_model=args.width, num_layers=args.layers,
                      d_ff=args.width * 4, vocab_size=4096)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    pcfg = ParallelConfig(remat="full", attention_impl="chunked",
                          attention_chunk=min(512, args.seq),
                          moe_impl="dense")
    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                           total_steps=args.steps)

    ndev = args.data_axis or len(jax.devices())
    mesh = make_local_mesh(data=ndev, model=1)

    with sharding_env(mesh, fsdp=True):
        defs = T.model_defs(cfg)
        print(f"arch={cfg.name} params={param_count(defs):,}")
        params = init_params(defs, jax.random.PRNGKey(0))
        init_state, step_fn = make_train_step(cfg, pcfg, ocfg)
        state = init_state(params)
        jstep = jax.jit(step_fn, donate_argnums=(0,))

        data = SyntheticLM(cfg, shape, PipelineConfig(seed=1))
        import os as _os
        ckpt_dir = args.ckpt_dir
        if not args.resume and _os.path.isdir(ckpt_dir) and _os.listdir(ckpt_dir):
            # fresh run: never resume from a stale (possibly different-
            # config) checkpoint tree
            n = 1
            while _os.path.isdir(f"{ckpt_dir}.run{n}"):
                n += 1
            ckpt_dir = f"{ckpt_dir}.run{n}"
            print(f"checkpoint dir in use; starting fresh at {ckpt_dir}")
        ckpt = Checkpointer(ckpt_dir)
        start = 0
        if args.resume and ckpt.latest_step() is not None:
            state, start = ckpt.load(state)
            print(f"resumed from step {start}")

        sup = Supervisor(ckpt, ckpt_every=args.ckpt_every,
                         injector=FailureInjector(fail_at=args.fail_at))
        t0 = time.time()
        losses = []

        def wrapped_step(st, batch):
            st, metrics = jstep(st, {k: jnp.asarray(v) for k, v in batch.items()})
            metrics = {k: float(v) for k, v in metrics.items()}
            losses.append(metrics["loss"])
            n = len(losses)
            if n % args.log_every == 0:
                dt = time.time() - t0
                tps = n * shape.tokens / dt
                print(f"step {n:5d} loss {metrics['loss']:.4f} "
                      f"gnorm {metrics['grad_norm']:.2f} tok/s {tps:,.0f}")
            return st, metrics

        state, report = sup.run(state, wrapped_step, data.batch, args.steps,
                                start_step=start)
        print(json.dumps({
            "steps_run": report.steps_run, "restarts": report.restarts,
            "resumed_from": report.resumed_from,
            "first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
        }, indent=1))


if __name__ == "__main__":
    main()

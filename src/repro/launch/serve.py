"""Serving driver: batched requests through the paged PiM engine.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b \
      --requests 8 --prompt-len 24 --max-new 16

This is the closed-loop batch driver; the open-system async front door
(streaming, Poisson arrivals, SLOs) lives in
``repro.launch.serve_async``.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCHS, ParallelConfig, reduced
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--share-prefix", action="store_true",
                    help="second half of requests reuse the first prompt "
                         "(minus a fresh 4-token tail); the radix prefix "
                         "cache dedupes the shared pages automatically")
    ap.add_argument("--share-pairwise", action="store_true",
                    help="DEPRECATED: same workload through the legacy "
                         "pairwise share_with/shared_len arithmetic the "
                         "prefix cache replaced — kept as the sharing "
                         "parity oracle")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    engine = PagedEngine(cfg, params, page_size=args.page_size,
                         prefix_cache=args.share_prefix)

    rng = np.random.default_rng(0)
    base_prompt = rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
    results = {}
    t0 = time.time()
    if args.share_prefix:
        # radix path: commit the base prompt once, then submit the
        # sharers with no sharing arguments at all — create(...,
        # tokens=) longest-prefix-matches their full pages against the
        # committed tree
        engine.submit(Request(0, base_prompt, max_new_tokens=args.max_new))
        results.update(engine.run())
    for i in range(1 if args.share_prefix else 0, args.requests):
        if (args.share_prefix or args.share_pairwise) \
                and i >= args.requests // 2:
            p = base_prompt.copy()
            p[-4:] = rng.integers(0, cfg.vocab_size, 4)
            if args.share_pairwise:
                engine.submit(Request(i, p, max_new_tokens=args.max_new,
                                      share_with=0,
                                      shared_len=(args.prompt_len - 4)
                                      // args.page_size * args.page_size))
            else:
                engine.submit(Request(i, p, max_new_tokens=args.max_new))
        else:
            engine.submit(Request(i, base_prompt if i == 0 else
                                  rng.integers(0, cfg.vocab_size,
                                               args.prompt_len).astype(np.int32),
                                  max_new_tokens=args.max_new))
    results.update(engine.run())
    dt = time.time() - t0
    toks = sum(len(v) for v in results.values())
    print(json.dumps({
        "requests": len(results), "tokens": toks,
        "tok_per_s": round(toks / dt, 1),
        "engine_stats": engine.stats,
        "cache_stats": engine.cache.stats,
        "ops_saved_by_sharing": engine.cache.queue.saved_by_kind,
        "pages_in_use_at_end": engine.cache.pages_in_use,
    }, indent=1))
    for rid in sorted(results)[:4]:
        print(rid, results[rid][:10])


if __name__ == "__main__":
    main()

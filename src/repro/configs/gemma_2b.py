"""gemma-2b — 18L d2048 8H (MQA kv=1) d_ff 16384 GeGLU head_dim 256
[arXiv:2403.08295]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=256_000,
    activation="geglu", tie_embeddings=True, rope_theta=10_000.0,
)

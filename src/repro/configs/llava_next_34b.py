"""llava-next-34b — VLM: 60L d7168 56H (GQA kv=8) d_ff 20480 backbone;
anyres patch frontend is a stub (patch embeddings) [hf:llava-hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
    head_dim=128, d_ff=20480, vocab_size=64_000,
    activation="swiglu", rope_theta=5_000_000.0,
    num_patch_tokens=256, frontend_dim=1024,
)

"""mamba2-1.3b — attention-free SSD: 48L d2048, state 128, headdim 64
[arXiv:2405.21060]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=0, vocab_size=50_280,
    activation="swiglu", tie_embeddings=True,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    supports_long_context=True,
)

"""granite-3-8b — 40L d4096 32H (GQA kv=8) d_ff 12800 vocab 49155
[hf:ibm-granite]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=12800, vocab_size=49_155,
    activation="swiglu", tie_embeddings=True, rope_theta=10_000.0,
)

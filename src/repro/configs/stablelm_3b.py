"""stablelm-3b — 32L d2560 32H (MHA kv=32) d_ff 6912 vocab 50304
[hf:stabilityai]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
    head_dim=80, d_ff=6912, vocab_size=50_304,
    activation="swiglu", rope_theta=10_000.0,
)

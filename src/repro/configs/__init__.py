from .base import (ModelConfig, MoEConfig, MLAConfig, SSMConfig, ShapeConfig,
                   ParallelConfig, OptimizerConfig, RunConfig, SHAPES,
                   cells_for, reduced)
from .registry import ARCHS, get

"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

from typing import Dict

from .base import ModelConfig
from .gemma_2b import CONFIG as gemma_2b
from .minitron_8b import CONFIG as minitron_8b
from .granite_3_8b import CONFIG as granite_3_8b
from .stablelm_3b import CONFIG as stablelm_3b
from .jamba_1_5_large_398b import CONFIG as jamba_1_5_large_398b
from .seamless_m4t_medium import CONFIG as seamless_m4t_medium
from .llava_next_34b import CONFIG as llava_next_34b
from .llama4_scout_17b_a16e import CONFIG as llama4_scout_17b_a16e
from .deepseek_v2_236b import CONFIG as deepseek_v2_236b
from .mamba2_1_3b import CONFIG as mamba2_1_3b

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in [
        gemma_2b, minitron_8b, granite_3_8b, stablelm_3b,
        jamba_1_5_large_398b, seamless_m4t_medium, llava_next_34b,
        llama4_scout_17b_a16e, deepseek_v2_236b, mamba2_1_3b,
    ]
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]

"""Config system: model architecture, input shapes, parallelism, run.

Plain frozen dataclasses (serializable, hashable where needed).  Every
assigned architecture is a `ModelConfig` in its own module under
`repro.configs`; shapes are global (`SHAPES`) with per-arch applicability
resolved by `cells_for(arch)`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


# --------------------------------------------------------------------- #
# Model architecture
# --------------------------------------------------------------------- #


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    num_shared_experts: int = 0
    expert_d_ff: int = 0             # per-expert hidden size
    first_dense_layers: int = 0      # leading layers use dense FFN
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0             # 0 = no q compression
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128             # N (SSD state size)
    head_dim: int = 64               # P
    expand: int = 2                  # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256            # SSD chunked-scan block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    activation: str = "swiglu"       # swiglu | geglu | gelu
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0              # hybrid: 1 attention layer per N layers
    enc_layers: int = 0              # encdec
    dec_layers: int = 0
    num_patch_tokens: int = 0        # vlm/audio stub frontend tokens
    frontend_dim: int = 0            # stub embedding dim (0 -> d_model)
    # long-context capability (sub-quadratic decode memory/time)
    supports_long_context: bool = False
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def param_count(self) -> int:
        """Total parameters (analytic; validated against init in tests)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.mla:
                m = self.mla
                q = d * self.num_heads * (m.nope_head_dim + m.rope_head_dim)
                if m.q_lora_rank:
                    q = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * (
                        m.nope_head_dim + m.rope_head_dim)
                kv_a = d * (m.kv_lora_rank + m.rope_head_dim)
                kv_b = m.kv_lora_rank * self.num_heads * (m.nope_head_dim + m.v_head_dim)
                o = self.num_heads * m.v_head_dim * d
                return q + kv_a + kv_b + o
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o

        def ffn_params(ff: int) -> int:
            mult = 3 if self.activation in ("swiglu", "geglu") else 2
            return mult * d * ff

        def ssm_params() -> int:
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            in_proj = d * (2 * d_in + 2 * s.state_dim + nheads)
            conv = (d_in + 2 * s.state_dim) * s.conv_width
            out = d_in * d
            return in_proj + conv + out + 2 * nheads  # + A, D

        total = emb
        if self.family == "ssm":
            total += L * (ssm_params() + d)  # + norm
        elif self.family == "hybrid":
            n_attn = L // self.attn_every
            n_ssm = L - n_attn
            moe_ffn = self.moe.num_experts * ffn_params(self.moe.expert_d_ff) if self.moe else 0
            # jamba: alternate MoE / dense MLP every other layer
            n_moe = L // 2
            n_dense = L - n_moe
            total += n_attn * attn_params() + n_ssm * ssm_params()
            total += n_moe * (self.moe.num_experts * ffn_params(self.moe.expert_d_ff)
                              + self.d_model * self.moe.num_experts) if self.moe else 0
            total += n_dense * ffn_params(self.d_ff)
            total += L * 2 * d
        elif self.family == "moe":
            n_dense = self.moe.first_dense_layers
            n_moe = L - n_dense
            router = d * self.moe.num_experts
            experts = self.moe.num_experts * ffn_params(self.moe.expert_d_ff)
            shared = self.moe.num_shared_experts * ffn_params(self.moe.expert_d_ff)
            total += L * attn_params() + L * 2 * d
            total += n_dense * ffn_params(self.d_ff) + n_moe * (experts + shared + router)
        elif self.family == "encdec":
            enc = self.enc_layers * (attn_params() + ffn_params(self.d_ff) + 2 * d)
            dec = self.dec_layers * (2 * attn_params() + ffn_params(self.d_ff) + 3 * d)
            total += enc + dec
        else:  # dense / vlm
            total += L * (attn_params() + ffn_params(self.d_ff) + 2 * d)
        return total


# --------------------------------------------------------------------- #
# Input shapes (assigned set)
# --------------------------------------------------------------------- #


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cells_for(model: ModelConfig) -> List[ShapeConfig]:
    """Applicable (arch x shape) cells; long_500k only for sub-quadratic
    archs (DESIGN.md SS6)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not model.supports_long_context:
            continue
        out.append(s)
    return out


# --------------------------------------------------------------------- #
# Parallelism / run
# --------------------------------------------------------------------- #


@dataclass(frozen=True)
class ParallelConfig:
    multi_pod: bool = False
    fsdp: bool = True                # ZeRO-3 param/optimizer sharding on data
    remat: str = "full"              # full | dots | none
    attention_impl: str = "chunked"  # chunked | pallas | naive
    attention_chunk: int = 1024
    seq_shard_attention: bool = False  # shard q-seq instead of heads (hillclimb)
    moe_impl: str = "shard_map"      # shard_map | dense
    grad_compression: bool = False   # int8 chunked reduce-scatter
    opt_state_dtype: str = "float32"
    param_dtype: str = "float32"     # master params (bf16 for 200B+ configs)
    microbatches: int = 1
    # cost-analysis lowering: fully unroll layer/tile scans so
    # compiled.cost_analysis() counts every iteration (HLO while bodies
    # are otherwise counted once). Never used for the memory-proof
    # lowering or real runs.
    scan_unroll: bool = False
    # SSD chunk-scan unroll for the cost lowering: 0 = follow scan_unroll
    # (full unroll); k > 0 = partial unroll (cost then extrapolated
    # affinely in k — see dryrun.cost_metrics_extrapolated).
    ssd_unroll: int = 0
    # hillclimb knobs
    logits_fp32: bool = True
    embed_2d_sharding: bool = False
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | float8_e4m3fn (decode)
    moe_psum_dtype: str = "float32"    # bfloat16 halves the EP combine bytes
    row_parallel_attn: bool = False    # shard attn d_model dim over model
    moe_capacity_factor: float = 0.0   # 0 = use the model's own


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = ParallelConfig()
    optimizer: OptimizerConfig = OptimizerConfig()
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def reduced(model: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized model of the same family (tiny dims, few layers,
    few experts, small vocab) preserving every structural feature."""
    kw: dict = dict(
        num_layers=min(model.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(model.num_kv_heads, 4) if model.num_kv_heads > 1 else 1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
    if model.moe:
        kw["moe"] = dataclasses.replace(
            model.moe, num_experts=min(model.moe.num_experts, 8),
            expert_d_ff=128,
            first_dense_layers=min(model.moe.first_dense_layers, 1))
    if model.mla:
        kw["mla"] = MLAConfig(kv_lora_rank=64, q_lora_rank=0, rope_head_dim=16,
                              nope_head_dim=32, v_head_dim=32)
    if model.ssm:
        kw["ssm"] = dataclasses.replace(model.ssm, state_dim=32, head_dim=16,
                                        chunk_size=32)
    if model.family == "hybrid":
        kw["num_layers"] = 8
        kw["attn_every"] = model.attn_every
    if model.is_encdec:
        kw["enc_layers"] = 2
        kw["dec_layers"] = 2
        kw["num_layers"] = 4
    if model.num_patch_tokens:
        kw["num_patch_tokens"] = 16
    kw.update(overrides)
    return dataclasses.replace(model, **kw)

"""llama4-scout-17b-a16e — MoE 16e top-1, 48L d5120 40H (GQA kv=8)
expert d_ff 8192; early-fusion frontend stubbed [hf:meta-llama]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202_048,
    activation="swiglu", rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=1, num_shared_experts=1,
                  expert_d_ff=8192),
)

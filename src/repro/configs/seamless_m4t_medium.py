"""seamless-m4t-medium — enc-dec 12L+12L d1024 16H d_ff 4096 vocab 256206;
audio frontend is a stub (frame embeddings) [arXiv:2308.11596]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=24, enc_layers=12, dec_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16,
    head_dim=64, d_ff=4096, vocab_size=256_206,
    activation="gelu", num_patch_tokens=0, frontend_dim=160,
)

"""jamba-1.5-large-398b — hybrid Mamba+attn 1:7, 72L d8192 64H (GQA kv=8),
MoE 16e top-2 every other layer [arXiv:2403.19887]."""
from .base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=24576, vocab_size=65_536,
    activation="swiglu", attn_every=8,
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=24576),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    supports_long_context=True,   # 1:7 attention; Mamba layers O(1) state
)

"""minitron-8b — pruned nemotron: 32L d4096 32H (GQA kv=8) d_ff 16384
[arXiv:2407.14679]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=16384, vocab_size=256_000,
    activation="swiglu", rope_theta=500_000.0,
)

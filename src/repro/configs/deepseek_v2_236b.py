"""deepseek-v2-236b — 60L d5120 128H MLA kv_lora 512, MoE 160e top-6 +
2 shared, expert d_ff 1536, first layer dense [arXiv:2405.04434]."""
from .base import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=12288, vocab_size=102_400,
    activation="swiglu", rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, num_shared_experts=2,
                  expert_d_ff=1536, first_dense_layers=1),
)

"""Loss + train step factory (microbatching, remat, clipping, optimizer).

The returned ``train_step(state, batch)`` is pure and jit-able; sharding
comes from in/out shardings supplied by the launcher (params by
`param_specs`, batch by the batch spec, state follows params).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig, ParallelConfig
from repro.models import transformer as T
from .optimizer import clip_by_global_norm, make_optimizer

IGNORE = -100
AUX_WEIGHT = 0.01


def cross_entropy(logits: jax.Array, labels: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Mean xent over non-ignored labels; returns (loss, token_count)."""
    mask = (labels != IGNORE)
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), safe[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold) * mask
    count = jnp.maximum(mask.sum(), 1)
    return nll.sum() / count, count


def make_loss_fn(cfg: ModelConfig, pcfg: ParallelConfig):
    from repro.models.lm_head import fused_xent

    def loss_fn(params, batch: Dict[str, jax.Array]):
        # memory-efficient path: features + chunked fused softmax-xent
        # (fp32 logits never materialized for the full sequence).
        feats, aux = T.forward(cfg, pcfg, params, batch, mode="features")
        labels = batch["labels"]
        table = params["embed"].get("out", params["embed"]["tok"])
        nll, count = fused_xent(feats, table, labels)
        loss = nll / jnp.maximum(count, 1)
        total = loss + AUX_WEIGHT * aux
        return total, {"loss": loss, "aux": aux,
                       "tokens": count.astype(jnp.float32)}

    return loss_fn


def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig, ocfg: OptimizerConfig,
                    state_dtype=jnp.float32):
    loss_fn = make_loss_fn(cfg, pcfg)
    opt_init, opt_update = make_optimizer(ocfg, state_dtype)

    def init_state(params):
        return {"params": params, "opt": opt_init(params)}

    def grads_of(params, batch):
        if pcfg.microbatches > 1:
            mb = pcfg.microbatches
            b = batch["tokens"].shape[0]
            assert b % mb == 0, (b, mb)
            split = lambda x: x.reshape(mb, b // mb, *x.shape[1:])
            mbatch = {k: split(v) for k, v in batch.items()}

            def acc_fn(carry, mb_batch):
                g_acc, m_acc = carry
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb_batch)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / mb, g_acc, g)
                m_acc = jax.tree.map(lambda a, x: a + x / mb, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = {"loss": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32),
                  "tokens": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(acc_fn, (g0, m0), mbatch)
            return grads, metrics
        (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def train_step(state, batch):
        grads, metrics = grads_of(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, ocfg.grad_clip)
        new_params, new_opt = opt_update(state["params"], grads, state["opt"])
        metrics = dict(metrics, grad_norm=gnorm)
        return {"params": new_params, "opt": new_opt}, metrics

    return init_state, train_step


def make_eval_step(cfg: ModelConfig, pcfg: ParallelConfig):
    loss_fn = make_loss_fn(cfg, pcfg)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step


# ----------------------- serve steps (dry-run units) -------------------- #


def make_prefill_step(cfg: ModelConfig, pcfg: ParallelConfig):
    def prefill_step(params, batch, cache, lengths):
        logits, new_cache, _ = T.forward(cfg, pcfg, params, batch,
                                         mode="prefill", cache=cache,
                                         lengths=lengths)
        return logits, new_cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, pcfg: ParallelConfig):
    def decode_step(params, batch, cache, write_pos, lengths):
        logits, new_cache = T.forward(cfg, pcfg, params, batch, mode="decode",
                                      cache=cache, write_pos=write_pos,
                                      lengths=lengths)
        return logits, new_cache

    return decode_step

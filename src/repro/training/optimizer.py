"""Optimizers (AdamW, Adafactor) implemented natively on pytrees.

State dtype is configurable (`opt_state_dtype`): fp32 by default; bf16
halves optimizer memory for the 236B/398B dry-run configs (quality note
recorded in DESIGN.md — bf16 moments with fp32 master params is the
standard large-scale compromise).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def lr_schedule(ocfg: OptimizerConfig) -> Callable[[jax.Array], jax.Array]:
    """Linear warmup + cosine decay to 10%."""

    def fn(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = ocfg.lr * step / max(ocfg.warmup_steps, 1)
        frac = jnp.clip((step - ocfg.warmup_steps)
                        / max(ocfg.total_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
        cos = ocfg.lr * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < ocfg.warmup_steps, warm, cos)

    return fn


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


# ------------------------------ AdamW ---------------------------------- #


def adamw_init(params: Any, state_dtype=jnp.float32) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}

def adamw_update(params: Any, grads: Any, opt: Dict[str, Any],
                 ocfg: OptimizerConfig, state_dtype=jnp.float32):
    step = opt["step"] + 1
    lr = lr_schedule(ocfg)(step)
    b1, b2, eps, wd = ocfg.b1, ocfg.b2, ocfg.eps, ocfg.weight_decay
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps) + wd * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * update).astype(p.dtype),
                m32.astype(state_dtype), v32.astype(state_dtype))

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------- Adafactor -------------------------------- #


def adafactor_init(params: Any) -> Dict[str, Any]:
    """Factored second moments for >=2D params; full for 1D."""

    def mk(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"fac": jax.tree.map(mk, params), "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params: Any, grads: Any, opt: Dict[str, Any],
                     ocfg: OptimizerConfig):
    step = opt["step"] + 1
    lr = lr_schedule(ocfg)(step)
    beta2 = 1.0 - step.astype(jnp.float32) ** -0.8
    eps = 1e-30

    def upd(p, g, s):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if p.ndim >= 2:
            vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            rms = (vr[..., None] * vc[..., None, :]) / (
                jnp.mean(vr, axis=-1, keepdims=True)[..., None] + eps)
            update = g32 / (jnp.sqrt(rms) + 1e-8)
            new_s = {"vr": vr, "vc": vc}
        else:
            v = beta2 * s["v"] + (1 - beta2) * g2
            update = g32 / (jnp.sqrt(v) + 1e-8)
            new_s = {"v": v}
        # update clipping (Adafactor d=1.0)
        denom = jnp.maximum(1.0, jnp.sqrt(jnp.mean(update * update)))
        newp = (p.astype(jnp.float32) - lr * update / denom
                - lr * ocfg.weight_decay * p.astype(jnp.float32)).astype(p.dtype)
        return (newp, new_s)

    is_state = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    out = jax.tree.map(upd, params, grads, opt["fac"],
                       is_leaf=lambda x: isinstance(x, jax.Array))
    # out is a tree of (param, state) tuples at param positions
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_fac = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"fac": new_fac, "step": step}


def make_optimizer(ocfg: OptimizerConfig, state_dtype=jnp.float32):
    if ocfg.name == "adamw":
        return (lambda p: adamw_init(p, state_dtype),
                lambda p, g, o: adamw_update(p, g, o, ocfg, state_dtype))
    if ocfg.name == "adafactor":
        return adafactor_init, lambda p, g, o: adafactor_update(p, g, o, ocfg)
    raise ValueError(ocfg.name)

"""int8 chunked gradient compression (distributed-optimization trick).

For pure data-parallel replicated-gradient sync, an fp32 all-reduce moves
4 bytes/element twice across the wire.  This module implements the
classic compressed alternative inside `shard_map`:

  1. each replica splits the gradient into `world` equal segments,
  2. quantizes to int8 with one fp32 scale per (segment, block),
  3. `all_to_all` so replica r receives segment r from everyone,
  4. dequantize + fp32 tree-sum of its segment (exact accumulation),
  5. re-quantize the reduced segment and `all_gather`.

Wire bytes: ~1/4 of fp32 ring all-reduce (int8 payload + scales), at the
cost of one quantization error on the way in and one on the way out.
`psum_compressed` is a drop-in for `jax.lax.psum` over the given axis.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256  # elements per quantization block


def _axis_size(axis_name) -> int:
    """Static mesh-axis size inside shard_map, across jax versions:
    ``jax.lax.axis_size`` only exists in newer releases; on 0.4.x the
    axis env frame holds the size directly."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)
    return frame if isinstance(frame, int) else frame.size


def _quantize(x: jax.Array):
    """x: (..., n) fp32 -> (int8 codes, fp32 scales per block)."""
    n = x.shape[-1]
    pad = (-n) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(*x.shape[:-1], -1, BLOCK)
    # all-zero blocks (e.g. the padding psum_compressed appends to reach
    # world*seg elements) must dequantize to EXACT zeros: a tiny additive
    # scale floor would keep codes at 0 here, but any future change that
    # divides by absmax directly would turn pads into NaN/garbage that the
    # all_to_all round trip then sums into real elements.  Guard with a
    # where(): zero blocks get scale 1.0 -> codes 0 -> dequantized 0.0.
    absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _dequantize(codes: jax.Array, scale: jax.Array, n: int) -> jax.Array:
    xb = codes.astype(jnp.float32) * scale
    return xb.reshape(*codes.shape[:-2], -1)[..., :n]


def psum_compressed(x: jax.Array, axis_name: str) -> jax.Array:
    """Compressed mean-preserving sum over ``axis_name`` (callable inside
    shard_map).  x: any shape; flattened internally."""
    world = _axis_size(axis_name)
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    seg = -(-n // world)
    flat = jnp.pad(flat, (0, seg * world - n)).reshape(world, seg)

    codes, scale = _quantize(flat)                       # (world, seg/B, B)
    # all_to_all: split dim 0, concat on a fresh leading axis
    codes_t = jax.lax.all_to_all(codes[None], axis_name, split_axis=1,
                                 concat_axis=0, tiled=False)[:, 0]
    scale_t = jax.lax.all_to_all(scale[None], axis_name, split_axis=1,
                                 concat_axis=0, tiled=False)[:, 0]
    # codes_t: (world, seg/B, B) — peer p's copy of MY segment
    mine = jnp.sum(_dequantize(codes_t, scale_t, seg), axis=0)  # fp32 exact sum

    codes_r, scale_r = _quantize(mine[None])
    codes_all = jax.lax.all_gather(codes_r[0], axis_name)       # (world, ...)
    scale_all = jax.lax.all_gather(scale_r[0], axis_name)
    full = _dequantize(codes_all, scale_all, seg).reshape(-1)[:n]
    return full.reshape(shape).astype(x.dtype)


def compressed_grad_sync(grads: Any, mesh, axis_name: str = "data") -> Any:
    """Tree-wise compressed all-reduce (replicated-gradient DP mode)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def sync(g):
        fn = functools.partial(psum_compressed, axis_name=axis_name)
        return shard_map(fn, mesh=mesh, in_specs=P(), out_specs=P(),
                         check_rep=False)(g)

    return jax.tree.map(sync, grads)

"""Logical-axis sharding rules and parameter-spec inference.

Models annotate tensors with *logical* axis names; this module resolves
them to mesh `PartitionSpec`s with divisibility-checked fallback (a
logical axis whose dim does not divide the mesh axis product is simply
replicated — this is what lets one rule set drive all 10 assigned
architectures on a fixed 16x16 / 2x16x16 mesh).

FSDP (ZeRO-3): after TP resolution, parameters get one additional dim
sharded over the batch axes — XLA then all-gathers weights per use and
reduce-scatters gradients, which with scan-over-layers reproduces the
classic ZeRO-3 schedule.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axis name -> tuple of mesh axis names (tried in order).
def default_rules(multi_pod: bool) -> Dict[str, Tuple[str, ...]]:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": batch_axes,
        "expert_batch": batch_axes,     # MoE shard_map token axis
        "seq": ("model",),              # sequence parallelism (activations/KV)
        "heads": ("model",),            # TP: attention heads
        "kv_heads": ("model",),         # TP: kv heads (GQA may fall back)
        "ff": ("model",),               # TP: MLP hidden
        "experts": ("model",),          # EP: expert dim
        "vocab": ("model",),            # TP: embedding/logits vocab
        "embed": (),                    # d_model: replicated (TP-wise)
        "dmodel_rp": (),                # row-parallel attn (off by default)
        "layers": (),                   # scan dim: never sharded
        "kv_lora": (),                  # MLA latent: replicated
        "state": (),                    # SSM state dim
    }


@dataclass
class ShardingEnv:
    mesh: Optional[Mesh] = None
    rules: Dict[str, Tuple[str, ...]] = field(default_factory=lambda: default_rules(False))
    fsdp: bool = True
    batch_axes: Tuple[str, ...] = ("data",)


_tls = threading.local()


def env() -> ShardingEnv:
    return getattr(_tls, "env", None) or ShardingEnv(mesh=None)


@contextlib.contextmanager
def sharding_env(mesh: Optional[Mesh], *, multi_pod: bool = False,
                 fsdp: bool = True, rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    prev = getattr(_tls, "env", None)
    _tls.env = ShardingEnv(
        mesh=mesh,
        rules=rules or default_rules(multi_pod),
        fsdp=fsdp,
        batch_axes=("pod", "data") if multi_pod else ("data",),
    )
    try:
        if mesh is not None:
            with mesh:
                yield _tls.env
        else:
            yield _tls.env
    finally:
        _tls.env = prev


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) if axes else 1


def resolve_spec(shape: Sequence[int], laxes: Sequence[Optional[str]],
                 *, fsdp_hint: bool = False) -> P:
    """Logical axes -> PartitionSpec with divisibility fallback.

    ``fsdp_hint``: additionally shard the largest yet-unsharded dim over
    the batch axes (parameters only).
    """
    e = env()
    if e.mesh is None:
        return P()
    assert len(shape) == len(laxes), (shape, laxes)
    spec: list = [None] * len(shape)
    used_mesh_axes: set = set()
    for i, name in enumerate(laxes):
        if name is None:
            continue
        axes = e.rules.get(name, ())
        if not axes:
            continue
        if any(a in used_mesh_axes for a in axes):
            continue
        size = _axes_size(e.mesh, axes)
        if size > 1 and shape[i] % size == 0:
            spec[i] = axes if len(axes) > 1 else axes[0]
            used_mesh_axes.update(axes)
    if fsdp_hint and e.fsdp and not any(a in used_mesh_axes for a in e.batch_axes):
        fs = _axes_size(e.mesh, e.batch_axes)
        # largest unsharded, divisible dim (skip dim 0 = scan/layers dim
        # when it is annotated 'layers')
        cands = [
            (shape[i], i) for i in range(len(shape))
            if spec[i] is None and laxes[i] != "layers" and shape[i] % fs == 0 and shape[i] >= fs
        ]
        if cands:
            _, i = max(cands)
            spec[i] = e.batch_axes if len(e.batch_axes) > 1 else e.batch_axes[0]
    return P(*spec)


def shard(x: jax.Array, *laxes: Optional[str]) -> jax.Array:
    """Apply a with_sharding_constraint from logical axes (no-op without
    a mesh)."""
    e = env()
    if e.mesh is None:
        return x
    spec = resolve_spec(x.shape, laxes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(e.mesh, spec))


def named_sharding(spec: P) -> Optional[NamedSharding]:
    e = env()
    if e.mesh is None:
        return None
    return NamedSharding(e.mesh, spec)

"""Deterministic sharded synthetic-token pipeline with background prefetch.

Production framing without a dataset dependency: batches are generated
from a counter-based RNG keyed by (seed, step), so every restart/replay
reproduces the exact same stream — which is what makes the fault-
tolerance tests meaningful (loss curves continue bit-exactly after a
checkpoint restart).  The generator can also draw its seed material from
the D-RaNGe TRNG (pim entropy) for data-order randomization.

The LM task is synthetic-structured (not pure noise): token t+1 depends
on token t through a fixed random permutation plus noise, so models can
actually reduce loss — giving the end-to-end train example a learnable
signal.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.training.train_step import IGNORE


@dataclass
class PipelineConfig:
    seed: int = 0
    noise: float = 0.1          # fraction of random next-tokens
    prefetch: int = 2


class SyntheticLM:
    """Markov-ish synthetic LM stream."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 pipe: PipelineConfig = PipelineConfig()):
        self.cfg = cfg
        self.shape = shape
        self.pipe = pipe
        rng = np.random.default_rng(pipe.seed ^ 0xC0FFEE)
        self.vocab = min(cfg.vocab_size, 65536)
        self.perm = rng.permutation(self.vocab)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.pipe.seed, step))
        b, s = self.shape.global_batch, self.shape.seq_len
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, b)
        noise_mask = rng.random((b, s)) < self.pipe.noise
        noise_tok = rng.integers(0, self.vocab, (b, s))
        for t in range(1, s):
            nxt = self.perm[toks[:, t - 1]]
            toks[:, t] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        labels = np.concatenate([toks[:, 1:], np.full((b, 1), IGNORE, np.int32)],
                                axis=1)
        batch = {"tokens": toks, "labels": labels}
        extra = modality_inputs(self.cfg, b, s, rng)
        batch.update(extra)
        if "patch_embeds" in extra:
            # patch positions are prepended by the model: shift labels
            npatch = extra["patch_embeds"].shape[1]
            batch["tokens"] = toks[:, : s - npatch]
            full_labels = np.full((b, s), IGNORE, np.int32)
            full_labels[:, npatch:] = labels[:, : s - npatch]
            batch["labels"] = full_labels
        if "frames" in extra:
            # encdec: seq budget split enc/dec (DESIGN.md SS6)
            batch["tokens"] = toks[:, : s // 2]
            batch["labels"] = labels[:, : s // 2]
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def modality_inputs(cfg: ModelConfig, b: int, s: int,
                    rng: Optional[np.random.Generator] = None) -> Dict[str, np.ndarray]:
    """Stub frontend inputs (precomputed patch/frame embeddings)."""
    rng = rng or np.random.default_rng(0)
    out: Dict[str, np.ndarray] = {}
    if cfg.family == "vlm" and cfg.num_patch_tokens:
        fd = cfg.frontend_dim or cfg.d_model
        out["patch_embeds"] = rng.standard_normal(
            (b, cfg.num_patch_tokens, fd)).astype(np.float32)
    if cfg.family == "encdec":
        fd = cfg.frontend_dim or cfg.d_model
        out["frames"] = rng.standard_normal((b, s // 2, fd)).astype(np.float32)
    return out


class Prefetcher:
    """Background-thread prefetch queue over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item

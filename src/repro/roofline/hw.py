"""Target hardware constants (TPU v5e) for the roofline model."""

PEAK_FLOPS_BF16 = 197e12     # per chip, FLOP/s
HBM_BW = 819e9               # per chip, B/s
ICI_LINK_BW = 50e9           # per link, B/s (roofline formula uses 1 link/chip)

# per-device HBM capacity (fit check)
HBM_BYTES = 16 * 1024 ** 3

"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (EXPERIMENTS.md
§Roofline):

  compute    = HLO_FLOPs / (chips x peak)          [cost_analysis]
  memory     = HLO_bytes / (chips x HBM bw)        [cost_analysis]
  collective = collective_bytes / (chips x link bw)  [parsed from HLO]

cost_analysis on the SPMD-partitioned module reports *per-device* FLOPs
and bytes, so `chips` is already folded in — we verify that convention
against analytic MODEL_FLOPS and record the ratio (useful-compute
fraction: catches remat recompute and dispatch waste).

collective_bytes is parsed from the compiled HLO text: the sum over
all-gather / all-reduce / reduce-scatter / all-to-all / collective-
permute of the op's output tensor bytes (all-reduce counted twice —
ring reduce+broadcast moves ~2x payload per chip).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from . import hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of 'f32[16,128]' or tuple '(f32[4], bf16[8,2])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind from HLO text."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        # e.g. %ag = f32[8,128]{1,0} all-gather(...), or tuple outputs
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) ([\w\-]+)\(", line)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        base = opname.rstrip(".0123456789")
        # normalize e.g. 'all-gather-start', 'all-reduce-done'
        for kind in _COLLECTIVES:
            if base == kind or base == kind + "-start":
                out[kind] += _shape_bytes(shape_str)
                counts[kind] += 1
                break
    out["_counts"] = counts  # type: ignore
    return out


def collective_bytes_total(parsed: Dict[str, int]) -> int:
    total = 0
    for k in _COLLECTIVES:
        mult = 2 if k == "all-reduce" else 1
        total += mult * parsed.get(k, 0)
    return total


@dataclass
class RooflineTerms:
    flops: float                 # per-chip HLO flops
    hbm_bytes: float             # per-chip HLO bytes accessed
    coll_bytes: float            # per-chip collective bytes
    chips: int
    model_flops: float = 0.0     # analytic useful flops (global)

    @property
    def t_compute(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / hw.ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / total HLO flops (global)."""
        if not self.model_flops:
            return 0.0
        return self.model_flops / (self.flops * self.chips)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound time — the score we hillclimb."""
        if not self.model_flops:
            return 0.0
        t_useful = self.model_flops / (self.chips * hw.PEAK_FLOPS_BF16)
        return t_useful / self.t_bound if self.t_bound else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_fraction": self.useful_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape, param_count: int) -> float:
    """Analytic useful FLOPs for the step (6ND for train; 2ND x tokens
    for inference; + attention terms)."""
    n_active = active_params(cfg, param_count)
    hd = cfg.resolved_head_dim

    def attn_flops(tokens: int, kv_len_avg: float) -> float:
        # 2 * (QK^T + PV) = 4 * tokens * kv_len * h * hd  (causal halves it)
        n_attn_layers = num_attn_layers(cfg)
        return 4.0 * tokens * kv_len_avg * cfg.num_heads * hd * n_attn_layers

    if shape.kind == "train":
        base = 6.0 * n_active * shape.tokens
        base += 3.0 * attn_flops(shape.tokens, shape.seq_len / 2)
        return base
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens + attn_flops(shape.tokens, shape.seq_len / 2)
    # decode: one token per sequence
    toks = shape.global_batch
    return 2.0 * n_active * toks + attn_flops(toks, shape.seq_len)


def num_attn_layers(cfg) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    if cfg.family == "encdec":
        return cfg.enc_layers + 2 * cfg.dec_layers
    return cfg.num_layers


def active_params(cfg, total: int) -> float:
    """Active parameters per token (MoE: only routed top-k + shared)."""
    if not cfg.moe:
        return float(total)
    m = cfg.moe
    mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
    expert_p = mult * cfg.d_model * m.expert_d_ff
    if cfg.family == "moe":
        n_moe = cfg.num_layers - m.first_dense_layers
    else:  # hybrid: MoE on odd sublayers = half the layers
        n_moe = cfg.num_layers // 2
    inactive = n_moe * (m.num_experts - m.top_k) * expert_p
    return float(total - inactive)

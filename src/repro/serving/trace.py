"""Serving-trace capture + model-face replay: paper-style end-to-end
latency accounting for real engine workloads.

The paged KV cache records its arena mutations as a :class:`PimTrace`
— one event per op kind per queue flush, so the trace preserves the
batching the serving path actually achieved (a CoW fork's N page copies
are ONE event, exactly as they were one coalesced launch).  The engine's
fused decode round, whose KV scatter bypasses the queue, records its
writes explicitly.

:func:`replay_on_device` then drives the same trace through the
:class:`repro.core.pimolib.DeviceLib` face of the ``PimLib`` protocol:
each KV page maps to a DRAM row of the simulated DDR3 prototype
(same slab → same discovered subarray, so CoW copies are legal
RowClones), each event becomes one batched pimolib call (one POC
handshake, mirroring the serving coalescing), and the returned
:class:`OpReceipt` latencies accumulate into RowClone-vs-CPU totals —
the paper's copy/init tables, measured on a *serving* workload instead
of a microbenchmark.  Capability flags drive graceful fallback:
``KV_WRITE`` has no DDR3 sequence (``lib.supports`` is False), so token
writes are accounted as CPU writes; a copy whose operands land in
different subarrays falls back to ``cpu_copy`` the same way.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.allocator import Allocation, allocator_from_subarray_map
from repro.core.coherence import CoherencePolicy
from repro.core.dram_model import DRAMGeometry, SimulatedDRAM
from repro.core.memctrl import EndToEndCosts, MemoryController
from repro.core.op_registry import group_inits_by_value
from repro.core.pimolib import Blocking, DeviceLib, OpReceipt
from repro.core.poc import PimOpsController
from repro.core.subarray import discover_subarrays


@dataclass(frozen=True)
class TraceEvent:
    """One coalesced batch of same-kind ops (one flush-side launch)."""

    kind: str                        # "page_copy" | "page_init" |
                                     # "page_and" | "page_or" | "page_not" |
                                     # "page_zero_scan" | "kv_write" |
                                     # "prefix_hit" | "ssm_state_write" |
                                     # "state_copy" | "state_init"
                                     # (state_* dst/src are state-arena
                                     # rows, a namespace disjoint from
                                     # KV page ids)
    src: Tuple[int, ...] = ()        # source pages (page_copy, bitwise)
    dst: Tuple[int, ...] = ()        # destination pages (all kinds)
    slots: Tuple[int, ...] = ()      # in-page slots (kv_write)
    value: float = 0.0               # fill value (page_init)
    nbytes: int = 0                  # payload bytes (kv_write)
    rounds: int = 1                  # engine rounds this event spans
                                     # (>1: a K-blocked decode loop's
                                     # writes landed as one host commit)

    @property
    def n(self) -> int:
        return len(self.dst)


class PimTrace:
    """Recorded arena-mutation schedule of a serving run."""

    def __init__(self, *, num_pages: int, num_slabs: int,
                 page_size: int, kv_itemsize: Optional[int] = None) -> None:
        self.num_pages = num_pages
        self.num_slabs = num_slabs
        self.page_size = page_size
        # bytes per stored KV element (the ARENA dtype — enqueued source
        # arrays may be wider and only cast at flush)
        self.kv_itemsize = kv_itemsize
        # state-arena slot count (set by the owning cache when an SSM
        # state arena exists) — sizes the replay twin so state rows get
        # their own DRAM rows next to the KV pages
        self.num_state_rows = 0
        self.events: List[TraceEvent] = []

    def __len__(self) -> int:
        return len(self.events)

    def counts(self) -> Dict[str, int]:
        """Logical op counts per kind (not event counts)."""
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + e.n
        return out

    # -- recording hooks ------------------------------------------------- #

    def record_from_queue(self, kind: str, ops: list) -> None:
        """PimOpQueue flush hook: summarize one kind's pending ops into
        one event (mirrors the one-coalesced-launch-per-kind contract).
        Unknown kinds are ignored (ad-hoc per-queue registrations)."""
        if kind in ("page_copy", "page_and", "page_or", "page_not"):
            # pairwise (src, dst) kinds: RowClone copies and the Ambit
            # bitwise family share the op-record shape
            self.events.append(TraceEvent(
                kind, src=tuple(s for s, _ in ops),
                dst=tuple(d for _, d in ops)))
        elif kind == "page_init":
            # same value-grouping as the flush executor: one event per
            # actual launch group
            for value, pages in group_inits_by_value(ops).items():
                self.events.append(TraceEvent(kind, dst=tuple(pages),
                                              value=value))
        elif kind == "kv_write":
            pages = tuple(p for o in ops for p in o.pages)
            slots = tuple(s for o in ops for s in o.slots)
            nbytes = sum(
                (o.k.size + o.v.size)
                * (self.kv_itemsize or int(np.dtype(o.k.dtype).itemsize))
                for o in ops)
            self.events.append(TraceEvent(kind, dst=pages, slots=slots,
                                          nbytes=nbytes))
        elif kind == "state_copy":
            # copy-on-fork of whole state rows — RowClone on replay
            self.events.append(TraceEvent(
                kind, src=tuple(s for s, _ in ops),
                dst=tuple(d for _, d in ops)))
        elif kind == "state_init":
            for value, rows in group_inits_by_value(ops).items():
                self.events.append(TraceEvent(kind, dst=tuple(rows),
                                              value=value))
        elif kind == "ssm_state_write":
            # StateWriteBatch records (already cast to the arena dtypes)
            rows = tuple(r for o in ops for r in o.rows)
            nbytes = sum(
                o.conv.size * int(np.dtype(o.conv.dtype).itemsize)
                + o.ssm.size * int(np.dtype(o.ssm.dtype).itemsize)
                for o in ops)
            self.events.append(TraceEvent(kind, dst=rows, nbytes=nbytes))

    def record_kv_write(self, pages, slots, nbytes: int, *,
                        rounds: int = 1) -> None:
        """Explicit hook for writes that bypass the queue (the fused
        decode round's in-jit scatter).  ``rounds > 1`` stamps a
        K-blocked decode loop's whole block — replay still sees one
        ``kv_write`` batch (the coalescing the engine actually
        achieved), and analyses can recover rounds-per-host-commit."""
        self.events.append(TraceEvent("kv_write", dst=tuple(pages),
                                      slots=tuple(slots), nbytes=int(nbytes),
                                      rounds=int(rounds)))

    def record_state_write(self, rows, nbytes: int, *,
                           rounds: int = 1) -> None:
        """Explicit hook for state writes that bypass the queue (the
        fused steps scatter recurrent state in-jit on donated arenas,
        mirroring the KV path's :meth:`record_kv_write`)."""
        self.events.append(TraceEvent("ssm_state_write",
                                      dst=tuple(rows), nbytes=int(nbytes),
                                      rounds=int(rounds)))

    def record_zero_scan(self, pages) -> None:
        """The KV cache's zero-compare page scan (eviction candidates /
        clear_prefix audit) bypasses the queue — it is a read-only
        kernel, counted via ``count_external`` — so it records its page
        batch explicitly.  Replay prices it as the Ambit OR-reduce-and-
        test sequence vs a CPU word scan."""
        if len(pages):
            self.events.append(TraceEvent("page_zero_scan",
                                          dst=tuple(int(p) for p in pages)))

    def record_prefix_hit(self, pages, nbytes: int = 0) -> None:
        """A radix prefix-cache hit attached ``pages`` to a new sequence
        instead of recomputing + rewriting them.  On the JAX face the
        hit is free (refcount++); what it *stands in for* is the bulk
        page materialization a CoW-less server would pay per request —
        RowClone on the model face (one batched in-DRAM copy), memcpy on
        the CPU baseline.  Replay accounts it exactly that way, which is
        how shared-system-prompt traffic turns into the paper's
        copy-table savings."""
        if pages:
            self.events.append(TraceEvent("prefix_hit", dst=tuple(pages),
                                          nbytes=int(nbytes)))


# ---------------------------------------------------------------------- #
# Model-face replay
# ---------------------------------------------------------------------- #


def replay_on_device(trace: PimTrace, *, lib: Optional[DeviceLib] = None,
                     row_bytes: int = 64,
                     coherence: CoherencePolicy = CoherencePolicy.PRECISE,
                     ) -> Dict[str, object]:
    """Replay a serving trace on the simulated-prototype face.

    Builds (unless ``lib`` is supplied) a DDR3 twin sized so each arena
    slab maps onto one subarray, then replays every event as one batched
    ``PimLib`` call, collecting :class:`OpReceipt` objects.  Returns the
    receipts plus latency totals: the PiM account (RowClone copies/inits
    + CPU-fallback paths) against the all-CPU baseline (memcpy/calloc),
    per kind and end-to-end.
    """
    pages_per_slab = trace.num_pages // trace.num_slabs
    if lib is None:
        # +2 rows of slack per subarray: the reserved zero row, plus the
        # discovery probe's scratch tolerance.  State-arena rows (SSM
        # serving) all map into the first subarray — copy-on-fork src
        # and dst share it, so forks replay as legal RowClones — which
        # therefore needs room for every state slot on top of its pages.
        geo = DRAMGeometry(num_subarrays=trace.num_slabs,
                           rows_per_subarray=(pages_per_slab + 2
                                              + trace.num_state_rows),
                           row_bytes=row_bytes)
        mc = MemoryController(SimulatedDRAM(geo))
        smap = discover_subarrays(mc, max_rows=geo.num_rows)
        lib = DeviceLib(PimOpsController(mc), allocator_from_subarray_map(smap),
                        coherence=coherence)
    mc = lib.poc.mc
    costs = EndToEndCosts(mc)

    # arena page -> device row, same slab -> same discovered group
    groups = lib.allocator.group_ids()
    page_row: Dict[int, Allocation] = {}

    def row_of(page: int) -> Allocation:
        if page not in page_row:
            gid = groups[(page // pages_per_slab) % len(groups)]
            page_row[page] = lib.allocator.alloc(1, group=gid,
                                                 tag=f"page{page}")
        return page_row[page]

    # state-arena rows live in their own id namespace (slot ids overlap
    # page ids); one subarray holds them all so fork copies are
    # same-group RowClones
    state_row: Dict[int, Allocation] = {}

    def srow_of(slot: int) -> Allocation:
        if slot not in state_row:
            state_row[slot] = lib.allocator.alloc(1, group=groups[0],
                                                  tag=f"srow{slot}")
        return state_row[slot]

    def grouped(pages) -> Dict[int, Allocation]:
        """Batch same-group rows into one Allocation (one pimolib call
        -> one POC handshake, mirroring the serving-side coalescing)."""
        rows_by_group: Dict[int, List[int]] = {}
        for p in pages:
            a = row_of(p)
            rows_by_group.setdefault(a.group, []).append(a.rows[0])
        return {g: Allocation(rows=tuple(rows), group=g)
                for g, rows in rows_by_group.items()}

    receipts: List[OpReceipt] = []
    pim = {"rowclone_copy": 0.0, "rowclone_init": 0.0,
           "ambit_bitwise": 0.0, "zero_scan_ambit": 0.0,
           "cpu_fallback_copy": 0.0, "cpu_fallback_init": 0.0,
           "cpu_fallback_bitwise": 0.0,
           "kv_write_cpu": 0.0, "prefix_hit_rowclone": 0.0,
           "state_rowclone_copy": 0.0, "state_rowclone_init": 0.0,
           "state_write_cpu": 0.0}
    cpu = {"memcpy": 0.0, "calloc": 0.0, "bitwise": 0.0, "zero_scan": 0.0,
           "kv_write_cpu": 0.0, "prefix_hit_memcpy": 0.0,
           "state_memcpy": 0.0, "state_calloc": 0.0, "state_write_cpu": 0.0}
    _BITWISE_OP = {"page_and": "and", "page_or": "or", "page_not": "not"}

    for ev in trace.events:
        if ev.kind == "page_copy":
            cpu["memcpy"] += ev.n * costs.cpu_copy_ns()
            # pair up; RowClone where src/dst share a subarray, CPU else
            pim_pairs: Dict[int, List[Tuple[int, int]]] = {}
            for s, d in zip(ev.src, ev.dst):
                sa, da = row_of(s), row_of(d)
                if sa.group == da.group:
                    pim_pairs.setdefault(sa.group, []).append(
                        (sa.rows[0], da.rows[0]))
                else:   # graceful fallback: cross-subarray copy
                    rec = lib.cpu_copy(sa, da)
                    receipts.append(rec)
                    pim["cpu_fallback_copy"] += rec.latency_ns
            for g, pairs in pim_pairs.items():
                src = Allocation(rows=tuple(p[0] for p in pairs), group=g)
                dst = Allocation(rows=tuple(p[1] for p in pairs), group=g)
                rec = lib.copy(src, dst, blocking=Blocking.FIN)
                receipts.append(rec)
                pim["rowclone_copy"] += rec.latency_ns
        elif ev.kind in _BITWISE_OP:
            # Ambit bitwise: TRA sequences where operands share a
            # subarray, CPU read-modify-write fallback across subarrays
            # — same shape as the page_copy pairing above.
            op = _BITWISE_OP[ev.kind]
            cpu["bitwise"] += ev.n * costs.cpu_bitwise_ns()
            bw_pairs: Dict[int, List[Tuple[int, int]]] = {}
            for s, d in zip(ev.src, ev.dst):
                sa, da = row_of(s), row_of(d)
                if sa.group == da.group:
                    bw_pairs.setdefault(sa.group, []).append(
                        (sa.rows[0], da.rows[0]))
                else:
                    rec = lib.cpu_bitwise(op, sa, da)
                    receipts.append(rec)
                    pim["cpu_fallback_bitwise"] += rec.latency_ns
            for g, pairs in bw_pairs.items():
                src = Allocation(rows=tuple(p[0] for p in pairs), group=g)
                dst = Allocation(rows=tuple(p[1] for p in pairs), group=g)
                rec = lib.bitwise(op, src, dst, blocking=Blocking.FIN)
                receipts.append(rec)
                pim["ambit_bitwise"] += rec.latency_ns
        elif ev.kind == "page_zero_scan":
            # Read-only scan: CPU pays a word-compare pass per page; the
            # Ambit account OR-reduces the candidate rows into B-group
            # scratch (one TRA sequence per page) and word-scans only the
            # one result row.  Accounted analytically — the scan never
            # mutates the arena, so there is no device state to replay.
            cpu["zero_scan"] += ev.n * costs.cpu_scan_ns()
            ns = costs.zero_scan_batched_ns(ev.n)
            receipts.append(OpReceipt(True, "ambit_zero_scan", face=lib.face,
                                      n_ops=ev.n, latency_ns=ns))
            pim["zero_scan_ambit"] += ns
        elif ev.kind == "page_init":
            cpu["calloc"] += ev.n * costs.cpu_init_ns()
            byte_fill = (float(ev.value).is_integer()
                         and 0 <= ev.value <= 255)
            for g, alloc in grouped(ev.dst).items():
                # non-byte fills (legal on the JAX face) have no device
                # representation: account them as CPU memsets instead of
                # aborting the replay
                rec = (lib.init(alloc, ev.value, blocking=Blocking.FIN)
                       if byte_fill else lib.cpu_init(alloc))
                receipts.append(rec)
                key = ("rowclone_init" if rec.op == "rowclone_init"
                       else "cpu_fallback_init")
                pim[key] += rec.latency_ns
        elif ev.kind == "kv_write":
            # Slot-granular KV writes replay as CPU writes on both
            # accounts (speedup 1x): the PimLib protocol has no
            # slot-granular op, so even a future model-face KV_WRITE
            # sequence (lib.supports(Opcode.KV_WRITE)) would need a
            # protocol extension before replay could dispatch it.
            ns = mc.memcpy_ns(max(ev.nbytes, 1))
            rec = OpReceipt(True, "cpu_write", face=lib.face, n_ops=ev.n,
                            latency_ns=ns)
            receipts.append(rec)
            pim["kv_write_cpu"] += ns
            cpu["kv_write_cpu"] += ns
        elif ev.kind == "state_copy":
            # copy-on-fork of whole state rows: all state rows share one
            # subarray by construction, so these are always legal
            # same-group RowClones; the CPU baseline memcpys each row
            cpu["state_memcpy"] += ev.n * costs.cpu_copy_ns()
            src = Allocation(rows=tuple(srow_of(s).rows[0] for s in ev.src),
                             group=groups[0])
            dst = Allocation(rows=tuple(srow_of(d).rows[0] for d in ev.dst),
                             group=groups[0])
            rec = lib.copy(src, dst, blocking=Blocking.FIN)
            receipts.append(rec)
            pim["state_rowclone_copy"] += rec.latency_ns
        elif ev.kind == "state_init":
            cpu["state_calloc"] += ev.n * costs.cpu_init_ns()
            alloc = Allocation(rows=tuple(srow_of(d).rows[0] for d in ev.dst),
                               group=groups[0])
            byte_fill = (float(ev.value).is_integer()
                         and 0 <= ev.value <= 255)
            rec = (lib.init(alloc, ev.value, blocking=Blocking.FIN)
                   if byte_fill else lib.cpu_init(alloc))
            receipts.append(rec)
            pim["state_rowclone_init"] += rec.latency_ns
        elif ev.kind == "ssm_state_write":
            # slot-granular recurrent-state scatter: like KV_WRITE, the
            # SSM_STATE_WRITE opcode has no DDR3 sequence — the model
            # face reports it unsupported, so replay prices it as CPU
            # traffic on both accounts (graceful capability fallback)
            ns = mc.memcpy_ns(max(ev.nbytes, 1))
            rec = OpReceipt(True, "cpu_write", face=lib.face, n_ops=ev.n,
                            latency_ns=ns)
            receipts.append(rec)
            pim["state_write_cpu"] += ns
            cpu["state_write_cpu"] += ns
        elif ev.kind == "prefix_hit":
            # A radix prefix-cache hit: on the JAX face the attach was
            # free (refcount++), but it displaced the per-request bulk
            # materialization of n prefix pages that a cache-less server
            # would pay.  Account that displaced work analytically —
            # one batched RowClone (one POC handshake + n sequences) vs
            # n CPU row memcpys — without consuming device scratch rows
            # (the twin's subarrays have pages_per_slab + 2 rows; a
            # popular prefix is re-hit far more often than that).
            cpu["prefix_hit_memcpy"] += ev.n * costs.cpu_copy_ns()
            ns = costs.rowclone_copy_batched_ns(ev.n)
            receipts.append(OpReceipt(True, "rowclone_copy", face=lib.face,
                                      n_ops=ev.n, latency_ns=ns))
            pim["prefix_hit_rowclone"] += ns
        else:
            raise ValueError(f"unknown trace event kind {ev.kind!r}")

    pim_total = sum(pim.values())
    cpu_total = sum(cpu.values())
    # fallback latencies stay in the denominators: the per-kind speedup
    # reflects what the workload actually achieved, fallbacks included
    copy_pim = pim["rowclone_copy"] + pim["cpu_fallback_copy"]
    init_pim = pim["rowclone_init"] + pim["cpu_fallback_init"]
    bitwise_pim = pim["ambit_bitwise"] + pim["cpu_fallback_bitwise"]
    return {
        "counts": trace.counts(),
        "events": len(trace),
        # the twin controller's own account: PiM sequences dispatched,
        # refreshes the bank-state clock folded in (tREFI/tRFC), and the
        # device-time the replay consumed — evidence the PiM totals ride
        # the cycle-accurate face, not an analytic shortcut
        "device_stats": dict(mc.stats, now_ns=mc.now_ns),
        "pim_ns": dict(pim, total=pim_total),
        "cpu_ns": dict(cpu, total=cpu_total),
        "speedup": {
            "copy": (cpu["memcpy"] / copy_pim) if copy_pim else None,
            "init": (cpu["calloc"] / init_pim) if init_pim else None,
            "state_copy": ((cpu["state_memcpy"]
                            / pim["state_rowclone_copy"])
                           if pim["state_rowclone_copy"] else None),
            "state_init": ((cpu["state_calloc"]
                            / pim["state_rowclone_init"])
                           if pim["state_rowclone_init"] else None),
            "bitwise": (cpu["bitwise"] / bitwise_pim) if bitwise_pim else None,
            "zero_scan": ((cpu["zero_scan"] / pim["zero_scan_ambit"])
                          if pim["zero_scan_ambit"] else None),
            "prefix": ((cpu["prefix_hit_memcpy"] / pim["prefix_hit_rowclone"])
                       if pim["prefix_hit_rowclone"] else None),
            "end_to_end": (cpu_total / pim_total) if pim_total else None,
        },
        "receipts": receipts,
    }

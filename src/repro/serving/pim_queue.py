"""Deprecated shim — the batched PiM op scheduler moved to
``repro.core.pim_queue`` (pimolib v2).

The queue is shared core infrastructure (the JAX-face executors of the
opcode-keyed op registry flush through it), so it no longer lives under
``serving/``.  Import from :mod:`repro.core.pim_queue` instead; this
module will be removed in a future PR.
"""

import warnings

from repro.core.pim_queue import FlushFn, KVWriteBatch, PimOpQueue  # noqa: F401

warnings.warn(
    "repro.serving.pim_queue has moved to repro.core.pim_queue; "
    "update imports (this shim will be removed)",
    DeprecationWarning, stacklevel=2)

__all__ = ["FlushFn", "KVWriteBatch", "PimOpQueue"]

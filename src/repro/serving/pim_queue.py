"""Batched PiM operation scheduler: the deferred op queue.

PiDRAM's end-to-end lesson is that in-DRAM ops only win when the dispatch
path is amortized: one POC handshake per *batch* of row operations, not
per row.  The serving analogue: every CoW fork, page free, and
decode-round KV write used to issue ``O(num_layers)`` separate kernel
launches from Python.  This queue collects those arena mutations as
lightweight op records and flushes them as ONE coalesced launch per op
kind per arena — a constant number of dispatches regardless of layer
count or active-batch size.

Design mirrors :class:`repro.core.memctrl.MemoryController`'s PiM
sequence registry: each op *kind* registers a flush executor, so new
batched ops are one ``register_kind`` call plus their executor (the
software twin of the paper's "60 additional lines of Verilog"
extensibility argument).

``flush`` takes a variable number of arenas: the paged KV cache flushes
its (k, v) pair, while :class:`repro.core.pimolib.TpuLib` flushes its
single training-side buffer through the same queue — both get per-kind
coalescing and unified launch accounting.  Work dispatched *outside* the
queue but belonging to the same accounting (the engine's fused decode
step, one jit call covering forward + scatter) is recorded with
:meth:`PimOpQueue.count_external` so per-round dispatch counts have one
source of truth.

Flush ordering is fixed and documented: ``page_copy`` ops land first
(CoW source pages must be duplicated before anything overwrites them),
then ``page_init`` (zeroing freed pages), then ``kv_write`` (fresh
token KV).  Within a kind, op order follows enqueue order; duplicate
destinations resolve to the last enqueued op.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rowclone import ops as rc_ops

# A flush executor: (queue, arenas, ops) -> arenas (same length tuple).
FlushFn = Callable[["PimOpQueue", Tuple[jax.Array, ...], list],
                   Tuple[jax.Array, ...]]


@dataclass
class KVWriteBatch:
    """Pending slot writes: full-depth K/V for a batch of tokens,
    kept stacked as (layers, batch, ...) so enqueue/flush do O(1) host
    work in the batch size (no per-token slicing or re-stacking)."""

    pages: List[int]
    slots: List[int]
    k: jax.Array      # (layers, batch, kvh, hd)
    v: jax.Array

    @property
    def n(self) -> int:
        return len(self.pages)


class PimOpQueue:
    """Deferred queue of arena mutations, flushed as coalesced launches."""

    KIND_ORDER = ("page_copy", "page_init", "kv_write")

    def __init__(self, *, use_pallas: bool = False) -> None:
        self.use_pallas = use_pallas
        self._kinds: Dict[str, FlushFn] = {}
        self._pending: Dict[str, list] = {}
        self.stats = {
            "launches": 0,            # kernel dispatches issued (total)
            "flushes": 0,             # flush() calls that launched anything
            "ops_enqueued": 0,        # logical ops collected
            "ops_coalesced": 0,       # logical ops folded into launches
        }
        self.launches_by_kind: Dict[str, int] = {}
        for kind, fn in (("page_copy", _flush_page_copy),
                         ("page_init", _flush_page_init),
                         ("kv_write", _flush_kv_write)):
            self.register_kind(kind, fn)

    # -- extension registry (mirrors MemoryController.register_sequence) -- #

    def register_kind(self, kind: str, fn: FlushFn) -> None:
        self._kinds[kind] = fn
        self._pending.setdefault(kind, [])
        self.launches_by_kind.setdefault(kind, 0)

    def has_kind(self, kind: str) -> bool:
        return kind in self._kinds

    # -- enqueue -------------------------------------------------------- #

    def enqueue(self, kind: str, op, n_ops: int = 1) -> None:
        if kind not in self._kinds:
            raise KeyError(f"unknown PiM op kind {kind!r}")
        self._pending[kind].append(op)
        self.stats["ops_enqueued"] += n_ops

    def enqueue_copy(self, src_page: int, dst_page: int) -> None:
        self.enqueue("page_copy", (src_page, dst_page))

    def enqueue_init(self, page: int, value: float = 0.0) -> None:
        self.enqueue("page_init", (page, float(value)))

    def enqueue_kv_write(self, page: int, slot: int,
                         k: jax.Array, v: jax.Array) -> None:
        """Single token: k/v (layers, ...)."""
        self.enqueue_kv_writes([page], [slot],
                               jnp.asarray(k)[:, None], jnp.asarray(v)[:, None])

    def enqueue_kv_writes(self, pages, slots, k: jax.Array,
                          v: jax.Array) -> None:
        """Bulk form: pages/slots length-B, k/v (layers, B, ...) — stored
        stacked; no per-token host work.  An empty batch (e.g. a prompt
        fully covered by a shared prefix) enqueues nothing, so the
        launch counters only ever count real dispatches."""
        if len(pages) == 0:
            return
        batch = KVWriteBatch([int(p) for p in pages], [int(s) for s in slots],
                             k, v)
        self.enqueue("kv_write", batch, n_ops=batch.n)

    # -- flush ---------------------------------------------------------- #

    @property
    def pending_ops(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def _count_launch(self, kind: str, n: int = 1) -> None:
        self.stats["launches"] += n
        self.launches_by_kind[kind] += n

    def count_external(self, kind: str, n: int = 1) -> None:
        """Account kernel dispatches issued outside the queue (e.g. the
        engine's fused decode step) so launch counters stay the single
        source of truth for per-round dispatch regressions."""
        self.launches_by_kind.setdefault(kind, 0)
        self._count_launch(kind, n)

    def flush(self, *arenas: jax.Array) -> Tuple[jax.Array, ...]:
        """Drain the queue: one coalesced launch per op kind per arena.

        Returns the updated arenas (a tuple matching the input arity).
        Launch count per flush is bounded by ``len(arenas) *
        len(KIND_ORDER)`` no matter how many layers or sequences the
        pending ops span.
        """
        if self.pending_ops == 0:
            return arenas
        any_launch = False
        order = [k for k in self.KIND_ORDER if k in self._kinds]
        order += [k for k in self._kinds if k not in order]
        for kind in order:
            ops = self._pending[kind]
            if not ops:
                continue
            self._pending[kind] = []
            arenas = self._kinds[kind](self, arenas, ops)
            # logical ops, matching ops_enqueued (a KVWriteBatch record
            # carries .n token writes)
            self.stats["ops_coalesced"] += sum(getattr(o, "n", 1) for o in ops)
            any_launch = True
        if any_launch:
            self.stats["flushes"] += 1
        return arenas


# ---------------------------------------------------------------------- #
# Built-in flush executors
# ---------------------------------------------------------------------- #


def _flush_page_copy(q: PimOpQueue, arenas, ops):
    src = jnp.asarray([s for s, _ in ops], jnp.int32)
    dst = jnp.asarray([d for _, d in ops], jnp.int32)
    arenas = tuple(rc_ops.pim_page_copy_batched(a, src, dst,
                                                use_pallas=q.use_pallas)
                   for a in arenas)
    q._count_launch("page_copy", len(arenas))
    return arenas


def _flush_page_init(q: PimOpQueue, arenas, ops):
    # ops: (page, value) records; one launch per arena per distinct value
    # (in practice a single 0.0 group — the calloc analogue)
    by_value: Dict[float, List[int]] = {}
    for page, value in ops:
        by_value.setdefault(value, []).append(page)
    for value, pages in by_value.items():
        dst = jnp.asarray(pages, jnp.int32)
        arenas = tuple(rc_ops.pim_page_init_batched(a, dst, value,
                                                    use_pallas=q.use_pallas)
                       for a in arenas)
        q._count_launch("page_init", len(arenas))
    return arenas


def _flush_kv_write(q: PimOpQueue, arenas, ops: List[KVWriteBatch]):
    assert len(arenas) == 2, "kv_write flushes a (k, v) arena pair"
    k_arena, v_arena = arenas
    pages = jnp.asarray([p for o in ops for p in o.pages], jnp.int32)
    slots = jnp.asarray([s for o in ops for s in o.slots], jnp.int32)
    if len(ops) == 1:              # the common case: already stacked
        k_new, v_new = ops[0].k, ops[0].v
    else:
        k_new = jnp.concatenate([o.k for o in ops], axis=1)  # (L, B, ...)
        v_new = jnp.concatenate([o.v for o in ops], axis=1)
    k_arena = rc_ops.pim_kv_scatter(k_arena, pages, slots,
                                    k_new.astype(k_arena.dtype),
                                    use_pallas=q.use_pallas)
    v_arena = rc_ops.pim_kv_scatter(v_arena, pages, slots,
                                    v_new.astype(v_arena.dtype),
                                    use_pallas=q.use_pallas)
    q._count_launch("kv_write", 2)
    return (k_arena, v_arena)

"""Paged KV cache on the PiM arena — where PiDRAM's memory management
meets serving.

Pages (the DRAM-row analogue) are allocated from a
:class:`SubarrayAllocator` over the KV arena; the PiDRAM-inherited
properties:

* **allocation constraints** — a sequence's pages prefer one slab
  (subarray); copy-on-write forks allocate destination pages
  `same_group_as` the source so the copy is a RowClone (`pim_page_copy`,
  zero compute-unit traffic) rather than a gather through the core;
* **init-on-free** — freed pages are zeroed with `pim_page_init`
  (calloc analogue) so cross-request data leakage is structurally
  impossible (the security-primitive angle of the paper);
* **prefix sharing** — refcounted pages let concurrent requests share a
  common prompt prefix; CoW forking copies only on divergence.

The arena tensors are (layers, pages, page_size, kvh, hd); the decode
step attends through `repro.kernels.paged_attention`.

All arena mutations route through a JAX-face :class:`PimLib`
(pimolib v2): the cache binds its (k, v) arena pair to the lib and ops
are enqueued on the lib's batched PiM op scheduler
(:class:`repro.core.pim_queue.PimOpQueue`) as lightweight records,
flushed as one coalesced launch per op kind — so a CoW fork, a sequence
free, or a bulk prompt write costs a constant number of kernel
dispatches regardless of ``num_layers`` or batch size.  A caller may
supply the lib (``PagedKVCache(..., lib=my_lib)``) to share dispatch
accounting with other arena clients; by default the cache constructs
its own :class:`repro.core.pimolib.TpuLib`.  Batched copies read all
sources from the pre-flush arena state (each RowClone in a batch is
independent); destination pages are always freshly allocated, so no
chaining can occur within a flush.

With ``record_trace=True`` the cache keeps a
:class:`repro.serving.trace.PimTrace` of every coalesced mutation batch
— replayable on the ``DeviceLib`` model face for paper-style RowClone
vs memcpy/calloc latency accounting of the actual serving workload
(:func:`repro.serving.trace.replay_on_device`).

The engine's fused decode round and fused prefill batch are the two
exceptions to queue routing: their KV scatters run *inside* the jitted
step on donated arenas (the prefill scatter against the host-side
:meth:`PagedKVCache.prefill_scatter_plan`), and the cache adopts the
results via :meth:`PagedKVCache.commit_fused_round` /
:meth:`PagedKVCache.commit_fused_prefill` (which still record the
dispatches in the queue's launch counters — ``fused_decode`` /
``fused_prefill`` kinds — and the writes in the trace).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence as Seq, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.allocator import PimAllocError, SubarrayAllocator, arena_groups
from repro.core.op_registry import StateWriteBatch, group_inits_by_value
from repro.core.pimolib import PimLib, TpuLib
from repro.kernels.ambit import ops as amb_ops
from repro.kernels.ssm_scan import ops as ssm_ops
from repro.models import transformer as T
from repro.serving.prefix_cache import RadixPrefixCache
from repro.serving.trace import PimTrace


@dataclass
class Sequence:
    seq_id: int
    pages: List[int] = field(default_factory=list)
    length: int = 0
    shared_prefix_pages: int = 0


class PagedStateArena:
    """Paged recurrent state for SSM/hybrid layouts — the KV arena's
    constant-size sibling.

    A sequence's Mamba state never grows: one arena *row* (slot) holds
    its full-depth conv window + SSD state for the whole lifetime.  The
    paging economics therefore differ from KV pages in every direction
    the docstring above cares about:

    * no growth — allocation is one slot at ``create``, period;
    * no prefix sharing — recurrent state is position-dependent, so a
      shared prompt prefix cannot attach (the owning cache declines
      radix/pairwise prefix hits entirely when a state arena exists);
    * copy-on-fork — a beam fork duplicates the *whole* row immediately
      (there is no page-granular divergence to defer), a RowClone copy
      on the model-face replay.

    Mutations route through the owning cache's :class:`PimOpQueue`
    under three kinds, all flushed as ONE coalesced launch per arena
    regardless of depth or batch:

    * ``ssm_state_write`` — the per-round state scatter (the
      ``SSM_STATE_WRITE`` opcode's JAX face; the registry default flush
      demands this arena-bound rebind via ``queue.register_kind``);
    * ``state_copy`` — copy-on-fork (RowClone-priced on replay);
    * ``state_init`` — init-on-free zeroing (RowClone-Init-priced), so
      a fresh slot is zero by construction and cross-request state
      leakage is structurally impossible.

    Hazard rows are namespaced as ``("state", slot)`` tuples so they
    never collide with KV page ids in the queue's hazard set — a fork's
    ``state_copy`` admission reading a slot with a deferred
    ``ssm_state_write`` pending forces the flush (program order), which
    is exactly the regression the hybrid tests pin.

    Arenas are ``(groups, mamba_sublayers, slots, ...)`` — the leading
    ``groups`` dim matches the engine's ``lax.scan`` length so the
    fused steps scan (params, k, v, conv, ssm) together.
    """

    def __init__(self, cfg: ModelConfig, *, num_slots: int, queue, lib,
                 trace: Optional[PimTrace], use_pallas: bool = False,
                 dtype=jnp.bfloat16) -> None:
        G, M = _mamba_layout(cfg)
        assert M > 0, "state arena needs at least one mamba sublayer"
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nheads = d_in // s.head_dim
        ch = d_in + 2 * s.state_dim
        self.cfg = cfg
        self.num_slots = num_slots
        self.use_pallas = use_pallas
        # conv window in the cache dtype (matches the model cache spec);
        # the SSD state stays float32 — the recurrence accumulates.
        self.conv = jnp.zeros((G, M, num_slots, s.conv_width - 1, ch), dtype)
        self.ssm = jnp.zeros((G, M, num_slots, nheads, s.head_dim,
                              s.state_dim), jnp.float32)
        self.queue = queue
        self.lib = lib
        self.trace = trace
        self.rows: Dict[int, int] = {}         # seq_id -> slot
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        if trace is not None:
            trace.num_state_rows = num_slots
        queue.register_kind("ssm_state_write", self._flush_write)
        queue.register_kind("state_copy", self._flush_copy)
        queue.register_kind("state_init", self._flush_init)

    # -- queue flush executors (arena-bound closures) ------------------- #
    # Each returns the (k, v) arenas untouched: state buffers live here,
    # not on the lib (the kv_write flush asserts a (k, v) pair).

    def _flush_write(self, q, arenas, ops):
        rows = jnp.asarray([r for o in ops for r in o.rows], jnp.int32)
        if len(ops) == 1:
            conv, ssm = ops[0].conv, ops[0].ssm
        else:
            conv = jnp.concatenate([o.conv for o in ops], axis=2)
            ssm = jnp.concatenate([o.ssm for o in ops], axis=2)
        self.conv = ssm_ops.pim_state_scatter(self.conv, rows, conv,
                                              use_pallas=q.use_pallas)
        self.ssm = ssm_ops.pim_state_scatter(self.ssm, rows, ssm,
                                             use_pallas=q.use_pallas)
        q._count_launch("ssm_state_write", 2)
        return arenas

    def _flush_copy(self, q, arenas, ops):
        src = jnp.asarray([s for s, _ in ops], jnp.int32)
        dst = jnp.asarray([d for _, d in ops], jnp.int32)
        self.conv = ssm_ops.pim_state_copy(self.conv, src, dst,
                                           use_pallas=q.use_pallas)
        self.ssm = ssm_ops.pim_state_copy(self.ssm, src, dst,
                                          use_pallas=q.use_pallas)
        q._count_launch("state_copy", 2)
        return arenas

    def _flush_init(self, q, arenas, ops):
        for value, rows in group_inits_by_value(ops).items():
            dst = jnp.asarray(rows, jnp.int32)
            self.conv = ssm_ops.pim_state_init(self.conv, dst, value,
                                               use_pallas=q.use_pallas)
            self.ssm = ssm_ops.pim_state_init(self.ssm, dst, value,
                                              use_pallas=q.use_pallas)
            q._count_launch("state_init", 2)
        return arenas

    # -- slot ledger ---------------------------------------------------- #

    def alloc(self, seq_id: int) -> int:
        """One slot per sequence; the slot is already zero (init-on-free
        ran when its previous owner died), so allocation launches
        nothing."""
        if not self._free:
            raise PimAllocError("state arena out of slots")
        slot = self._free.pop()
        self.rows[seq_id] = slot
        return slot

    def fork(self, src_id: int, dst_id: int) -> int:
        """Copy-on-fork: duplicate the parent's whole state row NOW.
        ``admit`` flushes any deferred ``ssm_state_write`` still pending
        against the source slot first — otherwise the queue's
        replay-by-kind would copy stale state.  The copy itself is only
        enqueued; the owning cache's ``fork`` flush coalesces it with
        the KV tail copies."""
        src = self.rows[src_id]
        dst = self.alloc(dst_id)
        self.queue.admit("state_copy", (("state", dst),), self.lib.flush,
                         reads=(("state", src),))
        self.queue.enqueue("state_copy", (src, dst))
        return dst

    def free(self, seq_id: int) -> None:
        """Release a slot; zero it through the queue (one coalesced
        RowClone-Init launch per arena at the caller's flush)."""
        slot = self.rows.pop(seq_id)
        self.queue.admit("state_init", (("state", slot),), self.lib.flush)
        self.queue.enqueue("state_init", (slot, 0.0))
        self._free.append(slot)

    def row(self, seq_id: int) -> int:
        return self.rows[seq_id]

    def rows_for(self, seq_ids: Seq[int]) -> List[int]:
        return [self.rows[sid] for sid in seq_ids]

    @property
    def rows_in_use(self) -> int:
        return len(self.rows)

    def _row_bytes(self) -> int:
        G, M = self.conv.shape[:2]
        conv_elems = int(np.prod(self.conv.shape[3:]))
        ssm_elems = int(np.prod(self.ssm.shape[3:]))
        return G * M * (conv_elems * np.dtype(self.conv.dtype).itemsize
                        + ssm_elems * 4)

    # -- mutation entry points ------------------------------------------ #

    def write(self, seq_ids: Seq[int], conv: jax.Array, ssm: jax.Array,
              *, flush: bool = True) -> None:
        """Eager-path round write: conv/ssm are (groups, sublayers,
        batch, ...) fresh states, one batch entry per sequence.  Admits
        with hazard tracking, enqueues ONE stacked record (O(1) host
        work in batch), and flushes unless the caller defers — the
        deferred form is what the fork-hazard regression races."""
        rows = self.rows_for(seq_ids)
        self.queue.admit("ssm_state_write",
                         [("state", r) for r in rows], self.lib.flush)
        batch = StateWriteBatch(rows, conv.astype(self.conv.dtype),
                                ssm.astype(self.ssm.dtype))
        self.queue.enqueue("ssm_state_write", batch, n_ops=batch.n)
        if flush:
            self.lib.flush()

    def adopt(self, conv: jax.Array, ssm: jax.Array) -> None:
        """Fused-path commit: the engine's step scattered new rows
        in-jit on donated state arenas; adopt the results.  The fused
        dispatch is already counted (``fused_*``); only the trace needs
        the write event — callers record it via
        :meth:`record_fused_write`."""
        self.conv = conv
        self.ssm = ssm

    def record_fused_write(self, seq_ids: Seq[int], *,
                           rounds: int = 1) -> None:
        if self.trace is not None and seq_ids:
            rows = self.rows_for(seq_ids)
            self.trace.record_state_write(
                rows, nbytes=len(rows) * self._row_bytes(), rounds=rounds)

    def gather(self, seq_ids: Seq[int]) -> Tuple[jax.Array, jax.Array]:
        """Host-side state read for tests/oracles: (conv, ssm) stacked
        (groups, sublayers, batch, ...).  Flush first so the read sees
        committed state."""
        self.lib.flush()
        rows = jnp.asarray(self.rows_for(seq_ids), jnp.int32)
        return (ssm_ops.state_gather_inline(self.conv, rows),
                ssm_ops.state_gather_inline(self.ssm, rows))


class PagedKVCache:
    def __init__(self, cfg: ModelConfig, *, num_pages: int = 128,
                 page_size: int = 16, num_slabs: int = 4,
                 dtype=jnp.bfloat16, use_pallas: bool = False,
                 lib: Optional[PimLib] = None, record_trace: bool = False,
                 mesh=None, prefix_cache: bool = False,
                 zero_scan: bool = False,
                 state_slots: Optional[int] = None):
        assert num_pages % num_slabs == 0
        hd = cfg.resolved_head_dim
        self.cfg = cfg
        self.page_size = page_size
        self.dtype = dtype
        self.use_pallas = use_pallas
        self.n_layers = _num_attn_layers(cfg)
        kvh = cfg.num_kv_heads
        k0 = jnp.zeros((self.n_layers, num_pages, page_size, kvh, hd), dtype)
        v0 = jnp.zeros((self.n_layers, num_pages, page_size, kvh, hd), dtype)
        # sharded serving: the arenas stay single GLOBAL arrays, laid out
        # with the KV-head axis split over the mesh's `model` dimension —
        # every device holds its head slice of every page, so page ids,
        # block tables, and the op queue are mesh-wide concepts
        self.mesh = mesh
        n_shard = mesh.shape["model"] if mesh is not None else 1
        if n_shard > 1:
            if kvh % n_shard != 0:
                raise ValueError(
                    f"num_kv_heads={kvh} not divisible by mesh model "
                    f"axis {n_shard}")
            from jax.sharding import NamedSharding, PartitionSpec as P
            ns = NamedSharding(mesh, P(None, None, None, "model", None))
            k0 = jax.device_put(k0, ns)
            v0 = jax.device_put(v0, ns)
        self.allocator = SubarrayAllocator(
            arena_groups(num_slabs, num_pages // num_slabs))
        # arena mutations route through a JAX-face PimLib; callers may
        # supply one to unify dispatch accounting across clients
        shard_kw = dict(shard_axis=3, mesh=mesh) if n_shard > 1 else {}
        if lib is None:
            lib = TpuLib(buffers=[k0, v0], layered=True,
                         allocator=self.allocator, use_pallas=use_pallas,
                         deferred=True, tag="kv", **shard_kw)
        else:
            if lib.face != "jax":
                raise ValueError(
                    f"PagedKVCache needs a JAX-face PimLib, got {lib.face!r}"
                    " (replay a recorded trace for model-face accounting)")
            lib.adopt_buffers([k0, v0], layered=True,
                              allocator=self.allocator, **shard_kw)
        self.lib = lib
        self.queue = lib.queue
        self.refcount: Dict[int, int] = {}
        self.page_alloc: Dict[int, object] = {}
        self.seqs: Dict[int, Sequence] = {}
        self.stats = {"cow_copies": 0, "pages_zeroed": 0, "prefix_hits": 0,
                      "prefix_hit_tokens": 0, "prefix_evictions": 0,
                      "init_skips_zero": 0, "zero_audit_pages": 0,
                      "zero_audit_failures": 0,
                      "state_pages": 0, "state_forks": 0,
                      "prefix_declined_ssm": 0}
        # Ambit zero-compare paths (opt-in: the scans add read-only
        # launches that per-round dispatch-count pins do not expect).
        # _known_zero holds pages a scan verified all-zero, so their
        # init-on-free can be skipped (zeros over zeros).
        self.zero_scan = zero_scan
        self._known_zero: set = set()
        # global radix prefix cache: committed full prompt pages index
        # into a trie (one node per token page), new prompts attach
        # their longest committed prefix automatically at create(...,
        # tokens=).  The tree holds its own refcount on every indexed
        # page; eviction releases it through the normal init-on-free
        # path.
        self.prefix: Optional[RadixPrefixCache] = None
        if prefix_cache:
            self.prefix = RadixPrefixCache(
                page_size,
                retain=self._retain_page,
                release=self._release_evicted_prefix_page)
        self.trace: Optional[PimTrace] = None
        if record_trace:
            self.trace = PimTrace(num_pages=num_pages, num_slabs=num_slabs,
                                  page_size=page_size,
                                  kv_itemsize=np.dtype(dtype).itemsize)
        # always (re)bind, so a lib reused from a previous cache does not
        # keep recording into that cache's trace
        self.queue.trace = self.trace
        # SSM/hybrid layouts: one paged state arena next to the KV pair.
        # Its buffers do NOT join lib.buffers (the kv_write flush is a
        # (k, v) contract); instead the arena rebinds the queue's
        # ssm_state_write kind (+ its state_copy/state_init siblings) to
        # arena-bound closures, so one lib.flush drains both worlds with
        # unified launch accounting.
        self.state: Optional[PagedStateArena] = None
        if _mamba_layout(cfg)[1] > 0:
            self.state = PagedStateArena(
                cfg, num_slots=state_slots or num_pages, queue=self.queue,
                lib=self.lib, trace=self.trace, use_pallas=use_pallas,
                dtype=dtype)

    # the arenas live on the lib (so a shared lib sees every mutation);
    # these properties keep the public names stable
    @property
    def k_arena(self) -> jax.Array:
        return self.lib.buffers[0]

    @k_arena.setter
    def k_arena(self, value: jax.Array) -> None:
        self.lib.buffers[0] = value

    @property
    def v_arena(self) -> jax.Array:
        return self.lib.buffers[1]

    @v_arena.setter
    def v_arena(self, value: jax.Array) -> None:
        self.lib.buffers[1] = value

    # ------------------------- page management ------------------------ #

    def _try_alloc(self, near: Optional[int] = None):
        if near is not None and near in self.page_alloc:
            try:
                return self.allocator.alloc(1,
                                            group=self.page_alloc[near].group)
            except PimAllocError:
                pass
        return self.allocator.alloc(1)

    def _alloc_page(self, near: Optional[int] = None) -> int:
        try:
            a = self._try_alloc(near)
        except PimAllocError:
            # arena full: evict cold prefix-cache subtrees (LRU, leaves
            # first) until a page frees up.  Only tree-exclusive pages
            # (refcount 1) actually return to the allocator — evicting a
            # node whose page live sequences still share just drops the
            # tree's reference — so keep evicting until the allocator
            # yields or the tree runs dry.
            if self.prefix is None:
                raise
            while True:
                if self.prefix.evict_lru(1) == 0:
                    raise
                try:
                    a = self._try_alloc(near)
                    break
                except PimAllocError:
                    continue
        page = a.rows[0]
        self.page_alloc[page] = a
        self.refcount[page] = 1
        return page

    def _retain_page(self, page: int) -> None:
        """Prefix-tree retain hook: the tree takes its own reference."""
        self.refcount[page] += 1

    def _release_evicted_prefix_page(self, page: int) -> None:
        """Prefix-tree release hook (node evicted): drop the tree's
        reference; an unshared page zeroes + frees through the usual
        batched init-on-free path.  The init is only *enqueued* — the
        next flush point (create/reserve callers flush before any
        dispatch that reads the arenas) coalesces a whole eviction
        sweep into one launch."""
        self.stats["prefix_evictions"] += 1
        self._release_page(page)

    def _release_page(self, page: int) -> None:
        """Drop a reference; on the last one, enqueue a batched
        RowClone-Init (zero without reading) and return the page to the
        allocator.  The caller flushes — `free()` zeroes a whole
        sequence's pages in one launch.  A page the zero-compare scan
        just verified all-zero (reserved-but-never-written tails, fully
        masked block rows) skips its init: the page already satisfies
        the init-on-free invariant, so the skipped op is accounted as
        saved work instead of launched work."""
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            if page in self._known_zero:
                self._known_zero.discard(page)
                self.queue.record_saved("page_init", 1)
                self.stats["init_skips_zero"] += 1
            else:
                self.queue.admit("page_init", (page,), self.lib.flush)
                self.queue.enqueue_init(page)
            self.stats["pages_zeroed"] += 1
            self.allocator.free(self.page_alloc.pop(page))
            del self.refcount[page]

    def flush_pending(self) -> None:
        """Drain the op queue: one coalesced launch per pending op kind."""
        self.lib.flush()

    # --------------------- Ambit zero-compare scan --------------------- #

    def enable_zero_scan(self) -> None:
        """Turn on the Ambit zero-compare paths: ``free()`` scans a
        dying sequence's exclusive pages (already-zero pages skip their
        init-on-free) and ``clear_prefix()`` audits that every page it
        freed really zeroed.  Off by default — the scans add read-only
        launches that the per-round dispatch-count regressions pin."""
        self.zero_scan = True

    def scan_zero_pages(self, pages) -> np.ndarray:
        """Batched in-arena zero-compare over ``pages``: ONE read-only
        kernel launch per arena (k, v) regardless of batch size — the
        TPU analogue of OR-reducing candidate rows into a B-group
        scratch row and testing the result.  Flushes pending mutations
        first so the scan sees committed state.  Returns bool (n,),
        True where the page holds all-zero bits in BOTH arenas."""
        idx = np.asarray(list(pages), np.int32)
        if idx.size == 0:
            return np.zeros((0,), bool)
        self.flush_pending()
        rows = jnp.asarray(idx)
        flags = None
        for buf in self.lib.buffers:
            z = amb_ops.pim_page_zero_scan(buf, rows,
                                           use_pallas=self.use_pallas)
            flags = z if flags is None else (flags & z)
        self.queue.count_external("page_zero_scan", len(self.lib.buffers))
        if self.trace is not None:
            self.trace.record_zero_scan(idx)
        return np.asarray(flags)

    # ------------------------- sequence API ---------------------------- #

    def create(self, seq_id: int, prompt_len: int,
               share_with: Optional[int] = None,
               shared_len: int = 0,
               tokens: Optional[Seq[int]] = None) -> Sequence:
        """Create a sequence, attaching any shareable prompt prefix.

        ``tokens`` (the prompt's token ids) enables the automatic path:
        the radix prefix cache longest-prefix-matches the prompt's full
        pages against every previously committed prompt and attaches
        the hit (refcount++ per page, no compute, no writes).

        ``share_with=``/``shared_len=`` is the legacy *pairwise* path —
        the caller names a live source sequence and pre-computes the
        page-aligned shared length itself.  It keeps working (and is
        still the parity oracle in tests) but new callers should pass
        ``tokens=`` and let the tree do the matching; the pairwise form
        warns ``DeprecationWarning`` when a prefix cache is enabled,
        since mixing both on one cache splits the hit accounting.
        """
        seq = Sequence(seq_id)
        shared_pages: List[int] = []
        if self.state is not None and (tokens is not None
                                       or (share_with is not None
                                           and shared_len)):
            # Recurrent state is position-dependent: a radix/pairwise
            # prefix hit could share the attention KV pages but NOT the
            # SSM state the prefix built up, and a sequence attached at
            # a nonzero offset would never compute it.  Decline the
            # match entirely — the engine recomputes the full prompt
            # (dense-only hit behavior is unchanged).
            self.stats["prefix_declined_ssm"] += 1
            share_with, shared_len, tokens = None, 0, None
        if share_with is not None and shared_len:
            if self.prefix is not None:
                warnings.warn(
                    "share_with=/shared_len= is the legacy pairwise "
                    "prefix API; pass tokens= and let the radix prefix "
                    "cache match automatically", DeprecationWarning,
                    stacklevel=2)
            src = self.seqs[share_with]
            n_shared = shared_len // self.page_size
            shared_pages = list(src.pages[:n_shared])
        elif tokens is not None and self.prefix is not None:
            shared_pages = self.prefix.match(list(tokens)[:prompt_len])
        if shared_pages:
            for p in shared_pages:
                self.refcount[p] += 1
                seq.pages.append(p)
            seq.length = len(shared_pages) * self.page_size
            seq.shared_prefix_pages = len(shared_pages)
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += seq.length
            # the hit displaced this many token writes (and the forward
            # compute behind them) — account the spared work, and give
            # the trace the bulk-copy event the hit stands in for
            self.queue.record_saved("kv_write", seq.length)
            if self.trace is not None:
                self.trace.record_prefix_hit(
                    shared_pages,
                    nbytes=seq.length * self._kv_tok_bytes())
        while seq.length < prompt_len:
            seq.pages.append(self._alloc_page(
                near=seq.pages[-1] if seq.pages else None))
            seq.length = min(seq.length + self.page_size, prompt_len)
        seq.length = prompt_len
        self.seqs[seq_id] = seq
        if self.state is not None:
            self.state.alloc(seq_id)
            self.stats["state_pages"] = self.state.rows_in_use
        return seq

    def commit_prefix(self, seq_id: int, tokens: Seq[int]) -> int:
        """Index a sequence's now-committed prompt KV in the radix
        prefix cache (no-op without one).  Call once the prompt's full
        pages hold real KV — after the fused/eager prefill commit, or
        when the last chunk of a chunked prefill lands.  Only full
        pages index (the partial tail stays private — decode appends
        into it); the tree retains each newly indexed page, so the
        prefix outlives this sequence.  Returns the number of pages
        newly indexed."""
        if self.prefix is None or self.state is not None:
            return 0   # SSM state is not prefix-shareable: never index
        seq = self.seqs[seq_id]
        n_full = min(len(seq.pages), len(tokens) // self.page_size)
        if n_full == 0:
            return 0
        return self.prefix.insert(
            [int(t) for t in list(tokens)[:n_full * self.page_size]],
            seq.pages[:n_full])

    def fork(self, src_id: int, dst_id: int) -> Sequence:
        """Beam/CoW fork: share full pages, RowClone-copy the partial tail."""
        src = self.seqs[src_id]
        dst = Sequence(dst_id)
        full = src.length // self.page_size
        for p in src.pages[:full]:
            self.refcount[p] += 1
            dst.pages.append(p)
        if full < len(src.pages):  # partial tail page -> CoW copy now
            tail = src.pages[full]
            new = self._alloc_page(near=tail)
            self._copy_page(tail, new)
            dst.pages.append(new)
            self.stats["cow_copies"] += 1
        dst.length = src.length
        dst.shared_prefix_pages = full
        self.seqs[dst_id] = dst
        if self.state is not None:
            # copy-on-fork for the recurrent state: the whole row, now
            # (no page-granular divergence to defer); coalesces into
            # this fork's single copy flush
            self.state.fork(src_id, dst_id)
            self.stats["state_forks"] += 1
            self.stats["state_pages"] = self.state.rows_in_use
        self.flush_pending()   # one batched copy launch per arena
        return dst

    def _copy_page(self, src: int, dst: int) -> None:
        """Enqueue a full-depth (all layers) page copy; callers flush.
        ``admit`` flushes any hazardous backlog first (e.g. a shared
        deferred lib's pending init on the source page — KIND_ORDER
        would otherwise replay the copy before it)."""
        self.queue.admit("page_copy", (dst,), self.lib.flush, reads=(src,))
        self.queue.enqueue_copy(src, dst)

    def ensure_writable_tail(self, seq: Sequence) -> None:
        """Before appending one token: CoW if the tail page is shared;
        allocate a fresh page on page-boundary crossings.

        CoW copies are only *enqueued* here — the engine reserves every
        active sequence's tail and then flushes once, so a decode round
        pays one batched copy launch however many sequences CoW."""
        self.reserve_tokens(seq, 1)

    def reserve_tokens(self, seq: Sequence, n: int) -> None:
        """Reserve arena capacity for the sequence's next ``n`` tokens:
        CoW the partial tail page if it is shared, then allocate enough
        fresh pages to cover positions ``[length, length + n)``.

        The engine's multi-round decode loop reserves a whole K-token
        block up front so every in-loop scatter has a host-planned
        (page, slot) destination with no mid-block host round-trip.
        Reservation is idempotent (it tops up to the needed page count)
        and never dispatches by itself — CoW copies are enqueued for the
        caller's coalesced flush, fresh pages are zero until written.
        A sequence that stops mid-block simply keeps its reserved tail
        pages in ``seq.pages`` (still zero — dead rows write back the
        value already in their slot), so the normal ``free`` path zeroes
        and returns them with everything else: no leak, no extra
        launch."""
        if n <= 0:
            return
        if seq.length % self.page_size != 0:
            tail = seq.pages[-1]
            if self.refcount[tail] > 1:
                new = self._alloc_page(near=tail)
                self._copy_page(tail, new)
                self.refcount[tail] -= 1
                seq.pages[-1] = new
                self.refcount[new] = 1
                self.stats["cow_copies"] += 1
        need = -(-(seq.length + n) // self.page_size)   # ceil div
        while len(seq.pages) < need:
            seq.pages.append(self._alloc_page(
                near=seq.pages[-1] if seq.pages else None))

    def append_token_kv(self, seq: Sequence, k: jax.Array, v: jax.Array) -> None:
        """k, v: (layers, kvh, hd) for the token at seq.length."""
        self.ensure_writable_tail(seq)
        page = seq.pages[-1]
        slot = seq.length % self.page_size
        self.queue.admit("kv_write", (page,), self.lib.flush)
        self.queue.enqueue_kv_write(page, slot, k, v)
        self.flush_pending()   # CoW copy (if any) lands before the write
        seq.length += 1

    def write_token_kv_batch(self, seq_ids: List[int], k: jax.Array,
                             v: jax.Array) -> None:
        """Decode-round bulk append: k, v (layers, batch, kvh, hd), one
        vector per sequence in ``seq_ids``, written at each sequence's
        current length.  Tails must already be reserved
        (``ensure_writable_tail``); one scatter launch per arena covers
        the whole batch."""
        pages, slots = [], []
        for sid in seq_ids:
            seq = self.seqs[sid]
            pages.append(seq.pages[-1])
            slots.append(seq.length % self.page_size)
        self.queue.admit("kv_write", pages, self.lib.flush)
        self.queue.enqueue_kv_writes(pages, slots, k, v)
        self.flush_pending()
        for sid in seq_ids:
            self.seqs[sid].length += 1

    def write_prompt_kv(self, seq: Sequence, k: jax.Array, v: jax.Array,
                        start: int = 0) -> None:
        """k, v: (layers, n, kvh, hd) — bulk write prefilled KV in one
        coalesced scatter launch per arena (was: n separate updates).
        This is the eager-prefill path; the fused prefill step scatters
        in-jit against :meth:`prefill_scatter_plan` instead."""
        n = k.shape[1]
        pages = [seq.pages[(start + i) // self.page_size] for i in range(n)]
        slots = [(start + i) % self.page_size for i in range(n)]
        self.queue.admit("kv_write", pages, self.lib.flush)
        self.queue.enqueue_kv_writes(pages, slots, k, v)
        self.flush_pending()

    def prefill_scatter_plan(self, seq: Sequence, start: int = 0,
                             stop: Optional[int] = None,
                             ) -> Tuple[List[int], List[int]]:
        """Host-side arena-destination plan for a prefilled prompt: the
        (page, slot) pair per position in ``[start, stop)`` (``stop``
        defaults to ``seq.length``).  The engine's fused prefill step
        scatters the forward's fresh KV against this plan *inside* the
        jit (no ``write_prompt_kv`` host round-trip); ``start`` skips a
        shared prefix.  The chunked-prefill scheduler calls this once
        per chunk — ``start``/``stop`` are the chunk's absolute position
        offsets, so successive chunks tile ``[prefix, seq.length)``."""
        if stop is None:
            stop = seq.length
        pages = [seq.pages[s // self.page_size] for s in range(start, stop)]
        slots = [s % self.page_size for s in range(start, stop)]
        return pages, slots

    def free(self, seq_id: int) -> None:
        """Release a sequence; all its dead pages zero in one batched
        RowClone-Init launch per arena.  With zero-scan enabled, the
        sequence's exclusive pages are zero-compared first: pages that
        are already all-zero (reserved-but-unwritten block tails) skip
        their init — the scan is one launch per arena however many
        pages die, and each skipped init is recorded as saved work."""
        seq = self.seqs.pop(seq_id)
        if self.zero_scan:
            excl = [p for p in seq.pages if self.refcount[p] == 1]
            if excl:
                flags = self.scan_zero_pages(excl)
                self._known_zero.update(
                    p for p, z in zip(excl, flags) if z)
        for p in seq.pages:
            self._release_page(p)
        if self.state is not None and seq_id in self.state.rows:
            self.state.free(seq_id)   # init-on-free rides the same flush
            self.stats["state_pages"] = self.state.rows_in_use
        self.flush_pending()

    def clear_prefix(self) -> int:
        """Drop the whole radix prefix cache (shutdown / leak audit):
        every tree-held page reference releases, unshared pages zero in
        one coalesced init launch.  With no live sequences left,
        ``pages_in_use`` must return to 0 afterwards — the
        zero-leaked-pages invariant the tests pin.  Returns the number
        of nodes evicted."""
        if self.prefix is None:
            return 0
        before = set(self.refcount)
        n = self.prefix.evict_all()
        self.flush_pending()
        if self.zero_scan:
            # zero-leak audit: every page the teardown freed must now be
            # all-zero bits in both arenas (the init-on-free invariant,
            # verified in-arena instead of trusted).  Failures count —
            # a nonzero audit means freed KV survived in HBM.
            freed = sorted(before - set(self.refcount))
            if freed:
                flags = self.scan_zero_pages(freed)
                self.stats["zero_audit_pages"] += len(freed)
                self.stats["zero_audit_failures"] += int(
                    len(freed) - int(np.count_nonzero(flags)))
        return n

    def _kv_tok_bytes(self) -> int:
        return (2 * self.n_layers * self.cfg.num_kv_heads
                * self.cfg.resolved_head_dim * np.dtype(self.dtype).itemsize)

    def commit_fused_round(self, seq_ids: List[int], k_arena: jax.Array,
                           v_arena: jax.Array, *,
                           kind: Optional[str] = "fused_decode",
                           wrote_kv: bool = True) -> None:
        """Adopt arenas mutated *inside* the engine's fused decode step
        (the round's KV scatter runs in-jit on donated buffers, so there
        is no separate ``kv_write`` flush) and advance each sequence by
        the token just written.  Tails must have been reserved with
        ``ensure_writable_tail`` before the step ran.  The single fused
        dispatch is recorded in the queue's launch counters so per-round
        dispatch accounting keeps one source of truth (and, when
        tracing, the round's writes land in the trace).  ``kind=None``
        skips the launch count — for the mixed chunk+decode round, whose
        ONE dispatch covers several commits and is accounted once by the
        engine as ``fused_mixed``.  ``wrote_kv=False`` (the pure-SSM
        engine: no attention sublayer touched the arenas) still advances
        lengths/accounting but prices no phantom KV traffic in the
        trace."""
        self.k_arena = k_arena
        self.v_arena = v_arena
        if self.trace is not None and wrote_kv:
            pages = [self.seqs[sid].pages[-1] for sid in seq_ids]
            slots = [self.seqs[sid].length % self.page_size
                     for sid in seq_ids]
            self.trace.record_kv_write(pages, slots,
                                       len(seq_ids) * self._kv_tok_bytes())
        for sid in seq_ids:
            self.seqs[sid].length += 1
        if kind is not None:
            self.queue.count_external(kind)

    def commit_fused_block(self, seq_ids: List[int], counts: List[int],
                           k_arena: jax.Array, v_arena: jax.Array, *,
                           rounds: int = 1,
                           kind: Optional[str] = "fused_decode_block",
                           wrote_kv: bool = True) -> None:
        """Adopt arenas mutated inside the engine's multi-round decode
        block (``decode_block_rounds=K``: up to K decode rounds in ONE
        ``lax.while_loop`` dispatch) and advance each sequence by the
        ``counts[i]`` tokens it actually emitted before its in-loop stop
        (EOS/budget).  Capacity for the whole block must have been
        reserved with :meth:`reserve_tokens`; positions beyond a row's
        count hold their pre-block value (the loop's masked write-back),
        so only the real writes land in the trace — one ``kv_write``
        event for the whole block, stamped with the executed in-loop
        ``rounds`` so replay can see the K-blocking the host path
        achieved.  ``wrote_kv=False``: see :meth:`commit_fused_round`."""
        self.k_arena = k_arena
        self.v_arena = v_arena
        if self.trace is not None and wrote_kv:
            pages: List[int] = []
            slots: List[int] = []
            for sid, n in zip(seq_ids, counts):
                seq = self.seqs[sid]
                for pos in range(seq.length, seq.length + n):
                    pages.append(seq.pages[pos // self.page_size])
                    slots.append(pos % self.page_size)
            self.trace.record_kv_write(pages, slots,
                                       len(pages) * self._kv_tok_bytes(),
                                       rounds=rounds)
        for sid, n in zip(seq_ids, counts):
            self.seqs[sid].length += n
        if kind is not None:
            self.queue.count_external(kind)

    def commit_fused_prefill(self, k_arena: jax.Array, v_arena: jax.Array,
                             pages: List[int], slots: List[int], *,
                             kind: Optional[str] = "fused_prefill") -> None:
        """Adopt arenas mutated inside the engine's fused prefill step
        (the batch's prompt-KV scatter runs in-jit on donated buffers,
        so there is no separate ``kv_write`` flush).  ``pages``/``slots``
        name the positions actually written (the batch's scatter plan,
        shared-prefix positions excluded); sequence lengths were already
        set at ``create`` time, so unlike ``commit_fused_round`` nothing
        advances here.  The single fused dispatch is recorded in the
        queue's launch counters under the ``fused_prefill`` kind —
        prefill KV writes show up in ``launches_by_kind`` exactly like
        decode writes — and, when tracing, the writes land in the
        trace.  ``kind=None`` skips the launch count (the mixed round's
        chunk half; the engine accounts the one ``fused_mixed``
        dispatch)."""
        self.k_arena = k_arena
        self.v_arena = v_arena
        if self.trace is not None and pages:
            self.trace.record_kv_write(pages, slots,
                                       len(pages) * self._kv_tok_bytes())
        if kind is not None:
            self.queue.count_external(kind)

    def block_table(self, seq_ids: List[int],
                    max_pages: Optional[int] = None,
                    lengths: Optional[List[int]] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
        """Block tables + lengths for ``seq_ids``.

        Bucketing contract: the table width is ``max_pages`` rounded up
        to the next power of two (computed from the widest sequence when
        not given), so growing sequences hit a new jit trace only at
        power-of-two page-count boundaries instead of every round.
        Padding columns point at page 0 and are never attended — the
        kernels mask all positions at or beyond ``lengths[b]``.

        ``lengths`` overrides the per-sequence valid length (defaults to
        ``seq.length``): the chunked prefill uses it to expose only the
        already-*committed* prefix of a mid-prefill sequence, while the
        table still spans the sequence's full page list — so every chunk
        of one prompt shares one table-width bucket (no retrace per
        chunk)."""
        if max_pages is None:
            max_pages = max(len(self.seqs[sid].pages) for sid in seq_ids)
        max_pages = _bucket_pow2(max_pages)
        bt = np.zeros((len(seq_ids), max_pages), np.int32)
        lens = np.zeros((len(seq_ids),), np.int32)
        for i, sid in enumerate(seq_ids):
            seq = self.seqs[sid]
            bt[i, :len(seq.pages)] = seq.pages
            lens[i] = seq.length if lengths is None else lengths[i]
        return jnp.asarray(bt), jnp.asarray(lens)

    @property
    def pages_in_use(self) -> int:
        return len(self.refcount)


def _bucket_pow2(n: int) -> int:
    """Round up to the next power of two (min 1) — the block-table width
    bucket that keeps jitted decode retraces logarithmic in growth."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _num_attn_layers(cfg: ModelConfig) -> int:
    """Leading (layers) dim of the KV arenas.

    This is the engine's ``lax.scan`` length, NOT the count of
    attention sublayers: hybrid superblocks carry exactly one attn per
    scanned step (num_layers // attn_every steps), while the pure-ssm
    family scans num_layers steps and keeps a phantom full-depth KV
    arena — the scan xs' leading dims must match, and the tiny-config
    waste buys a single uniform step signature across the zoo."""
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    if cfg.family == "encdec":
        return cfg.dec_layers
    return cfg.num_layers


def _mamba_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(scan groups, mamba sublayers per group) — the state arenas'
    leading (G, M) dims.  (0, 0) for layouts the paged engine serves
    without recurrent state (no mamba kinds, or a multi-group family
    the engine rejects anyway)."""
    if cfg.family not in ("ssm", "hybrid"):
        return (0, 0)
    groups = T.layer_groups(cfg)
    if len(groups) != 1:
        return (0, 0)
    count, kinds = groups[0]
    m = sum(1 for k in kinds if k == "mamba")
    return (count, m) if m else (0, 0)

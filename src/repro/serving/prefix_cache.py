"""Radix-tree prefix cache over KV arena pages.

PiDRAM's system argument is that in-DRAM bulk ops only matter when real
software traffic produces them.  The serving-side traffic generator is
prompt-prefix reuse: thousands of requests sharing a system prompt.
PR 0..7 supported this *pairwise* — a request had to name its source
sequence by id (``share_with=``/``shared_len=``) and do the page
arithmetic itself.  This module generalizes that into a global,
automatic prefix cache:

* the tree is a trie whose edges are **token-id pages** — a node's key
  is the exact ``page_size``-token tuple stored in one arena page, so a
  root-to-node path spells a committed prompt prefix and maps it to the
  arena pages holding its KV;
* :meth:`RadixPrefixCache.match` walks the longest full-page prefix of
  a new prompt and returns the arena pages to attach — an automatic
  longest-prefix match on submit, no source id, no arithmetic;
* nodes hold their own reference on the underlying page (through the
  owner-supplied ``retain``/``release`` callbacks, which bridge into
  :class:`repro.serving.kv_cache.PagedKVCache` refcounting), so an
  indexed prefix survives the request that created it;
* unreferenced subtrees evict **LRU, leaves first**
  (:meth:`evict_lru`): dropping a leaf releases the tree's reference,
  and when no live sequence shares the page it returns to the allocator
  through the normal init-on-free path (a batched RowClone-init — the
  eviction itself is accounted PiM traffic).

Only *full* pages are indexed — a partial tail page is still writable
(decode appends into it), so sharing it would force CoW on every
append.  Matching therefore advances in whole pages, which is also what
makes every hit a well-defined bulk operation: attaching N pages stands
in for the N-row bulk copy a CoW-less system would pay (RowClone on the
model face, memcpy on the CPU baseline), which is exactly how
``record_trace=True`` replay accounts it
(:meth:`repro.serving.trace.PimTrace.record_prefix_hit`).

The tree never touches device memory itself: it is host-side metadata,
and all page lifetime flows through the owner's refcounts.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


@dataclass
class _RadixNode:
    """One full token page: ``key`` is the page's token-id tuple (the
    edge label from the parent), ``page`` the arena page holding its
    KV.  ``last_used`` is the LRU clock stamp of the last match/insert
    that walked through this node."""

    key: Tuple[int, ...]
    page: int
    parent: Optional["_RadixNode"]
    children: Dict[Tuple[int, ...], "_RadixNode"] = field(default_factory=dict)
    last_used: int = 0


class RadixPrefixCache:
    """Trie of committed prompt prefixes, one node per full KV page.

    ``retain(page)``/``release(page)`` are the refcount bridge into the
    owning :class:`PagedKVCache`: the tree retains a page when it
    indexes it and releases it when the node evicts, so indexed pages
    outlive their creating sequence but still free (and zero, via the
    batched ``page_init`` path) once evicted and unshared.
    """

    def __init__(self, page_size: int, *,
                 retain: Callable[[int], None],
                 release: Callable[[int], None]) -> None:
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = page_size
        self._retain = retain
        self._release = release
        self._root = _RadixNode(key=(), page=-1, parent=None)
        self._clock = 0
        self.stats = {"hits": 0, "hit_tokens": 0, "misses": 0,
                      "inserts": 0, "nodes": 0, "evictions": 0}

    # ------------------------------ helpers ---------------------------- #

    def _pages_of(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        """Split ``tokens`` into full-page token tuples (the partial
        tail, if any, is dropped — only full pages are indexable)."""
        ps = self.page_size
        n_full = len(tokens) // ps
        return [tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])
                for i in range(n_full)]

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    # ------------------------------ queries ---------------------------- #

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Longest-prefix match: the arena pages holding the longest
        committed full-page prefix of ``tokens`` (possibly empty).
        Touches the matched path's LRU stamps; bumps hit/miss stats.
        The caller owns attaching the pages (refcount++ per page)."""
        now = self._tick()
        node = self._root
        pages: List[int] = []
        for key in self._pages_of(tokens):
            child = node.children.get(key)
            if child is None:
                break
            child.last_used = now
            pages.append(child.page)
            node = child
        if pages:
            self.stats["hits"] += 1
            self.stats["hit_tokens"] += len(pages) * self.page_size
        else:
            self.stats["misses"] += 1
        return pages

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Index a committed prompt: ``pages[i]`` holds the KV of the
        i-th full token page.  Existing nodes are kept (first committer
        wins — a duplicate prefill's pages stay owned by its sequence
        alone and die with it); each NEW node retains its page.  Returns
        the number of new nodes created."""
        keys = self._pages_of(tokens)
        if len(pages) < len(keys):
            keys = keys[:len(pages)]
        now = self._tick()
        node = self._root
        created = 0
        for key, page in zip(keys, pages):
            child = node.children.get(key)
            if child is None:
                child = _RadixNode(key=key, page=int(page), parent=node)
                node.children[key] = child
                self._retain(int(page))
                created += 1
                self.stats["nodes"] += 1
                self.stats["inserts"] += 1
            child.last_used = now
            node = child
        return created

    # ------------------------------ eviction --------------------------- #

    def _leaves(self) -> Iterable[_RadixNode]:
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                yield n

    def _drop(self, node: _RadixNode) -> None:
        assert not node.children, "only leaves evict"
        del node.parent.children[node.key]
        self._release(node.page)
        self.stats["nodes"] -= 1
        self.stats["evictions"] += 1

    def evict_lru(self, n_pages: int = 1) -> int:
        """Evict up to ``n_pages`` least-recently-used LEAF nodes
        (evicting a leaf may expose its parent as the next candidate —
        unreferenced subtrees therefore drain leaves-first, deepest
        coldest path first).  Returns the number evicted; 0 means the
        tree is empty."""
        evicted = 0
        while evicted < n_pages:
            leaf = min(self._leaves(), default=None,
                       key=lambda n: n.last_used)
            if leaf is None:
                break
            self._drop(leaf)
            evicted += 1
        return evicted

    def evict_all(self) -> int:
        """Drop every node (releases every tree-held page reference) —
        the shutdown/leak-audit path."""
        total = 0
        while True:
            n = self.evict_lru(1 << 30)
            total += n
            if n == 0:
                return total

    # ------------------------------ views ------------------------------ #

    @property
    def n_nodes(self) -> int:
        return self.stats["nodes"]

    def pages_indexed(self) -> List[int]:
        out = []
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            out.append(n.page)
            stack.extend(n.children.values())
        return out

"""Async continuous-batching front door over :class:`PagedEngine`.

`launch/serve.py` is a closed-loop batch script: it submits everything,
calls ``run()``, and reads the results.  Production traffic is an open
system — requests arrive on their own clock, want their tokens streamed
as they are produced, and carry latency expectations.  This module is
that front door:

* **continuous batching** — one asyncio task owns the engine and calls
  :meth:`PagedEngine.step` (exactly one engine round) in a loop,
  yielding to the event loop between rounds so arrivals join the very
  next round.  The engine's own scheduler keeps its guarantees (chunked
  prefill, decode every round, fused/mixed dispatches); the server adds
  nothing to the hot path but a host-side diff of each request's token
  list;

* **streaming** — :meth:`AsyncServer.submit` returns a
  :class:`TokenStream`, an async iterator that yields each new token id
  the round it is emitted (``async for tok in stream``), with
  TTFT/inter-token timestamps recorded per token;

* **deadlines + SLO-aware admission** — each round's work is split by
  construction: the chunked scheduler caps prefill at
  ``max_prefill_chunk`` tokens and always runs the decode round, so
  admission's job is to keep the *prefill backlog* bounded
  (``admit_backlog_chunks`` × chunk budget).  Requests whose
  first-token / completion deadline cannot be met even if admitted now
  (estimated from the measured round-time EWMA and their queue
  position) are rejected immediately — shedding load early is what
  keeps goodput from collapsing past saturation;

* **chunk auto-tuning** — PR 5's ``max_prefill_chunk`` was hand-tuned.
  :class:`ChunkAutoTuner` closes the loop: it watches the p99 of
  measured decode-carrying round times (the inter-token latency a
  decoding request actually experiences) and halves/doubles the chunk
  budget between pow2 bounds to hold a target, via
  :meth:`PagedEngine.set_prefill_chunk`.

Determinism: the server changes *when* work is scheduled, never *what*
a request computes — greedy (temperature-0) streams are bit-identical
to a closed-loop ``engine.run()`` of the same requests, which CI pins.
"""

from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass, field
from typing import AsyncIterator, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import PagedEngine, Request

_DONE = object()          # stream terminator sentinel


def _now_ms() -> float:
    return time.perf_counter() * 1e3


class TokenStream:
    """Async iterator over one request's generated tokens.

    Yields token ids as the engine emits them; iteration ends when the
    request finishes (EOS / budget) or is rejected by admission control
    (``rejected`` is set and nothing yields).  Timing marks
    (``submitted_ms`` / ``first_token_ms`` / ``finished_ms`` and the
    per-token ``token_ms`` list) are stamped server-side for SLO
    accounting; :meth:`drain` collects the remainder into ``tokens``.
    """

    def __init__(self, req: Request) -> None:
        self.req = req
        self.req_id = req.req_id
        self.tokens: List[int] = []
        self.token_ms: List[float] = []
        self.rejected = False
        self.reject_reason: Optional[str] = None
        self.submitted_ms = _now_ms()
        self.admitted_ms: Optional[float] = None
        self.first_token_ms: Optional[float] = None
        self.finished_ms: Optional[float] = None
        self._q: asyncio.Queue = asyncio.Queue()

    def __aiter__(self) -> AsyncIterator[int]:
        return self

    async def __anext__(self) -> int:
        item = await self._q.get()
        if item is _DONE:
            raise StopAsyncIteration
        return item

    async def drain(self) -> List[int]:
        """Consume the rest of the stream; returns the full token list."""
        async for _ in self:
            pass
        return self.tokens

    # -- server-side publishing ----------------------------------------- #

    def _push(self, toks: Sequence[int], now_ms: float) -> None:
        for t in toks:
            if self.first_token_ms is None:
                self.first_token_ms = now_ms
            self.tokens.append(int(t))
            self.token_ms.append(now_ms)
            self._q.put_nowait(int(t))

    def _finish(self, now_ms: float) -> None:
        self.finished_ms = now_ms
        self._q.put_nowait(_DONE)

    def _reject(self, reason: str, now_ms: float) -> None:
        self.rejected = True
        self.reject_reason = reason
        self.finished_ms = now_ms
        self._q.put_nowait(_DONE)

    # -- derived metrics ------------------------------------------------- #

    @property
    def ttft_ms(self) -> Optional[float]:
        if self.first_token_ms is None:
            return None
        return self.first_token_ms - self.submitted_ms

    @property
    def e2e_ms(self) -> Optional[float]:
        if self.finished_ms is None:
            return None
        return self.finished_ms - self.submitted_ms

    def itl_ms(self) -> List[float]:
        """Inter-token gaps (ms) — the decode-latency samples SLO p99s
        are computed over."""
        return [b - a for a, b in zip(self.token_ms, self.token_ms[1:])]


class ChunkAutoTuner:
    """Feedback controller for ``max_prefill_chunk``.

    Every ``window`` decode-carrying rounds, compare the window's p99
    round time (≈ the inter-token latency decoding requests saw) to the
    target: over target → halve the chunk budget (less prefill per
    round, decodes tick faster); under half the target with prefill
    backlogged → double it (spare latency headroom converts to prefill
    throughput).  Moves stay inside [min_chunk, max_chunk] and on pow2
    values, so each budget the tuner visits reuses one compiled
    chunk-length bucket per chunk shape.
    """

    def __init__(self, engine: PagedEngine, target_p99_ms: float, *,
                 min_chunk: int = 8, max_chunk: int = 512,
                 window: int = 16) -> None:
        if engine.max_prefill_chunk is None:
            raise ValueError("auto-tuning needs a chunked engine "
                             "(max_prefill_chunk set at construction)")
        self.engine = engine
        self.target_p99_ms = float(target_p99_ms)
        self.min_chunk = min_chunk
        self.max_chunk = max_chunk
        self.window = window
        self._samples: List[float] = []
        self.history: List[Dict[str, float]] = []

    def observe(self, round_ms: float, *, decoded: bool,
                backlog_tokens: int) -> None:
        if not decoded:
            return
        self._samples.append(round_ms)
        if len(self._samples) < self.window:
            return
        p99 = float(np.percentile(self._samples, 99))
        self._samples.clear()
        chunk = self.engine.max_prefill_chunk
        new = chunk
        if p99 > self.target_p99_ms and chunk > self.min_chunk:
            new = max(self.min_chunk, chunk // 2)
        elif (p99 < 0.5 * self.target_p99_ms and chunk < self.max_chunk
              and backlog_tokens > chunk):
            new = min(self.max_chunk, chunk * 2)
        if new != chunk:
            self.engine.set_prefill_chunk(new)
        self.history.append({"p99_ms": p99, "chunk": float(new)})


@dataclass
class _Waiting:
    stream: TokenStream
    ttft_deadline_ms: Optional[float]      # absolute, server clock
    deadline_ms: Optional[float]           # absolute, server clock


class AsyncServer:
    """The asyncio continuous-batching loop over one ``PagedEngine``.

    Use as an async context manager::

        async with AsyncServer(engine, ttft_slo_ms=200) as srv:
            stream = await srv.submit(prompt, max_new_tokens=32)
            async for tok in stream:
                ...

    Knobs:

    * ``ttft_slo_ms`` — default first-token deadline applied to every
      request (per-request ``deadline_ms`` bounds *completion* time);
      requests that cannot make their deadline are rejected at
      admission (``stream.rejected``).  ``None`` = no shedding.
    * ``admit_backlog_chunks`` — admission stops adding prompts once
      the engine's uncommitted prefill backlog exceeds this many chunk
      budgets (the round's prefill/decode split: prefill is capped at
      one chunk per round by the engine, decode always runs; the
      backlog cap bounds how long an admitted prompt waits for its
      first token).  Ignored without chunked prefill.
    * ``itl_p99_target_ms`` — enables the :class:`ChunkAutoTuner`
      against this decode-p99 target (needs a chunked engine).
    """

    def __init__(self, engine: PagedEngine, *,
                 ttft_slo_ms: Optional[float] = None,
                 admit_backlog_chunks: float = 4.0,
                 itl_p99_target_ms: Optional[float] = None,
                 tune_window: int = 16, min_chunk: int = 8,
                 max_chunk: int = 512, round_ewma: float = 0.25) -> None:
        self.engine = engine
        self.ttft_slo_ms = ttft_slo_ms
        self.admit_backlog_chunks = admit_backlog_chunks
        self.tuner: Optional[ChunkAutoTuner] = None
        if itl_p99_target_ms is not None:
            self.tuner = ChunkAutoTuner(engine, itl_p99_target_ms,
                                        min_chunk=min_chunk,
                                        max_chunk=max_chunk,
                                        window=tune_window)
        self._alpha = round_ewma
        self.round_ms_ewma: Optional[float] = None
        self._waiting: List[_Waiting] = []
        self._live: Dict[int, TokenStream] = {}
        self._gap_rounds: Dict[int, int] = {}
        self._next_id = 0
        self._wake = asyncio.Event()
        self._closing = False
        self._task: Optional[asyncio.Task] = None
        self.stats = {"rounds": 0, "submitted": 0, "admitted": 0,
                      "rejected": 0, "completed": 0, "max_round_gap": 0}

    # ------------------------------ lifecycle -------------------------- #

    async def __aenter__(self) -> "AsyncServer":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    def start(self) -> None:
        if self._task is None:
            self._task = asyncio.get_running_loop().create_task(self._loop())

    async def close(self) -> None:
        """Drain in-flight work, then stop the loop."""
        self._closing = True
        self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None

    # ------------------------------ submit ----------------------------- #

    async def submit(self, prompt, *, max_new_tokens: int = 16,
                     temperature: float = 0.0,
                     eos_token_id: Optional[int] = None,
                     deadline_ms: Optional[float] = None,
                     ttft_slo_ms: Optional[float] = None,
                     req_id: Optional[int] = None) -> TokenStream:
        """Enqueue a request; returns its :class:`TokenStream`.

        ``deadline_ms`` / ``ttft_slo_ms`` are relative to now
        (``ttft_slo_ms`` defaults to the server-wide SLO).  The request
        reaches the engine at the next admission pass; if its deadline
        is already infeasible it is rejected there instead
        (``stream.rejected``, empty stream).
        """
        if req_id is None:
            req_id = self._next_id
        self._next_id = max(self._next_id, req_id + 1)
        req = Request(req_id, np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      eos_token_id=eos_token_id)
        stream = TokenStream(req)
        now = stream.submitted_ms
        ttft = ttft_slo_ms if ttft_slo_ms is not None else self.ttft_slo_ms
        self._waiting.append(_Waiting(
            stream,
            ttft_deadline_ms=(now + ttft) if ttft is not None else None,
            deadline_ms=(now + deadline_ms) if deadline_ms is not None
            else None))
        self.stats["submitted"] += 1
        self._wake.set()
        return stream

    # ------------------------------ the loop --------------------------- #

    async def _loop(self) -> None:
        while True:
            self._admit()
            if not self.engine.has_work:
                if self._closing and not self._waiting:
                    return
                if not self._waiting:
                    self._wake.clear()
                    await self._wake.wait()
                    continue
                # waiting but nothing admitted (backlog cap with an
                # empty engine cannot happen; deadline-infeasible were
                # rejected) — admit pass will take them next iteration
                await asyncio.sleep(0)
                continue
            t0 = _now_ms()
            before_toks = self.engine.stats["tokens_out"]
            finished = self.engine.step()
            dt = _now_ms() - t0
            self._observe_round(dt, self.engine.stats["tokens_out"]
                                - before_toks)
            self._publish(finished)
            # let arrivals (and consumers) run before the next round
            await asyncio.sleep(0)

    def _observe_round(self, dt_ms: float, decoded_tokens: int) -> None:
        self.stats["rounds"] += 1
        self.round_ms_ewma = (dt_ms if self.round_ms_ewma is None else
                              self._alpha * dt_ms
                              + (1 - self._alpha) * self.round_ms_ewma)
        if self.tuner is not None:
            self.tuner.observe(dt_ms, decoded=decoded_tokens > 0,
                               backlog_tokens=self.engine
                               .prefill_backlog_tokens())

    # ------------------------------ admission -------------------------- #

    def _est_rounds_to_first_token(self, prompt_len: int) -> float:
        """Rounds until a prompt admitted NOW emits its first token:
        the uncommitted backlog plus this prompt, paid down one chunk
        budget per round (monolithic engines prefill in the next
        round)."""
        chunk = self.engine.max_prefill_chunk
        work = self.engine.prefill_backlog_tokens() + prompt_len
        return float(-(-work // chunk)) if chunk else 1.0

    def _admit(self) -> None:
        """One admission pass over the wait queue (FIFO).

        Feasibility shed: with a measured round time, a request whose
        first-token (or completion) deadline cannot be met even from
        the front of the backlog is rejected now — it would only burn
        chunk budget other requests could meet *their* deadlines with.
        Backlog cap: admission pauses (requests stay queued, order
        kept) while the engine's uncommitted prefill backlog exceeds
        ``admit_backlog_chunks`` chunk budgets.
        """
        still: List[_Waiting] = []
        chunk = self.engine.max_prefill_chunk
        for w in self._waiting:
            now = _now_ms()
            prompt_len = len(w.stream.req.prompt)
            if self.round_ms_ewma is not None and (
                    w.ttft_deadline_ms is not None
                    or w.deadline_ms is not None):
                est = self._est_rounds_to_first_token(prompt_len)
                ttft_eta = now + est * self.round_ms_ewma
                if (w.ttft_deadline_ms is not None
                        and ttft_eta > w.ttft_deadline_ms):
                    w.stream._reject("ttft_slo", now)
                    self.stats["rejected"] += 1
                    continue
                if w.deadline_ms is not None:
                    eta = ttft_eta + ((w.stream.req.max_new_tokens - 1)
                                      * self.round_ms_ewma)
                    if eta > w.deadline_ms:
                        w.stream._reject("deadline", now)
                        self.stats["rejected"] += 1
                        continue
            if (chunk is not None
                    and self.engine.prefill_backlog_tokens() + prompt_len
                    > self.admit_backlog_chunks * chunk
                    and self.engine.has_work):
                still.append(w)          # backlog cap: wait, don't shed
                continue
            w.stream.admitted_ms = now
            self.engine.submit(w.stream.req)
            self._live[w.stream.req_id] = w.stream
            self._gap_rounds[w.stream.req_id] = 0
            self.stats["admitted"] += 1
        self._waiting = still

    # ------------------------------ streaming -------------------------- #

    def _publish(self, finished: Dict[int, List[int]]) -> None:
        """Push tokens emitted this round into their streams (diff of
        each live request's ``out_tokens``) and close finished ones.
        Tracks the longest run of rounds any started request went
        without a token (``stats["max_round_gap"]`` — the chunked
        scheduler's no-starvation guarantee makes this 1)."""
        now = _now_ms()
        for rid, stream in list(self._live.items()):
            new = stream.req.out_tokens[len(stream.tokens):]
            if new:
                stream._push(new, now)
                self._gap_rounds[rid] = 0
            elif stream.tokens and not stream.req.done:
                # a started request went a whole round without a token
                # — the starvation the chunked scheduler exists to
                # prevent (stays 0 when it holds)
                self._gap_rounds[rid] += 1
                self.stats["max_round_gap"] = max(
                    self.stats["max_round_gap"], self._gap_rounds[rid])
            if rid in finished or stream.req.done:
                stream._finish(now)
                del self._live[rid]
                del self._gap_rounds[rid]
                self.stats["completed"] += 1

"""Continuous-batching serving engine over the paged PiM KV cache.

Request lifecycle: queue -> prefill (model prefill pass, KV written into
arena pages) -> decode rounds (paged attention over block tables, one
token per active sequence per round, new arrivals join between rounds)
-> finish (pages freed with pim_init, stats recorded).

The engine runs the *paged* attention path: per-layer KV lives only in
the arena; the model's dense-cache path is never materialized.  Forking
(`n>1` samples sharing a prompt) uses the cache's RowClone CoW.
Sampling consumes the D-RaNGe TPU generator (`pim_rand`).

A decode round is ONE compiled dispatch (the fused decode step):

* the layer loop is a ``jax.lax.scan`` over the stacked ``group0``
  params and the per-layer arena slices, so the traced program is O(1)
  in depth;
* the current token's K/V merge into attention happens *inside* the
  paged-attention kernel (``k_self``/``v_self``) — no post-kernel pass
  re-reads the arena history;
* the round's KV scatter and the token selection (greedy argmax or
  D-RaNGe inverse-CDF sample, per request) run in the same jit, with
  both arenas donated on backends that support donation, so the round
  issues no separate mutation launch and exactly one device->host
  transfer (the chosen tokens);
* block-table widths and the active batch are bucketed to powers of two
  (padding rows duplicate sequence 0, whose duplicate scatter writes
  identical values to identical slots), so growing/forking workloads
  retrace only at bucket boundaries — ``stats["jit_traces"]`` counts
  retraces, ``PimOpQueue`` counts dispatches.

Pre-round CoW copies still route through the cache's batched PiM op
scheduler: one coalesced copy flush (only when some sequence forks)
lands before the fused step reads the arena.  ``fused=False`` keeps the
pre-fusion eager path (a Python loop over layers, one launch per layer)
as the benchmark baseline.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.kernels.drange import ops as dr_ops
from repro.kernels.paged_attention import ops as pa_ops
from repro.kernels.rowclone import ops as rc_ops
from repro.models import transformer as T
from repro.models.layers import (rmsnorm, cast, logits_out, embed, mlp,
                                 apply_rope, rope_sincos)
from .kv_cache import PagedKVCache, _bucket_pow2


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                    # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 1.0
    share_with: Optional[int] = None      # prefix sharing source
    shared_len: int = 0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class PagedEngine:
    """Single-host engine for GQA decoder-only models (the paged path)."""

    def __init__(self, cfg: ModelConfig, params, *, page_size: int = 16,
                 num_pages: int = 256, pcfg: Optional[ParallelConfig] = None,
                 seed: int = 0, use_pallas: bool = False,
                 interpret: Optional[bool] = None, fused: bool = True,
                 lib=None, record_trace: bool = False):
        assert cfg.family in ("dense", "vlm"), "paged engine: GQA archs"
        self.cfg = cfg
        self.params = params
        self.pcfg = pcfg or ParallelConfig(attention_impl="naive", remat="none")
        # lib: caller-supplied JAX-face PimLib (pimolib v2) the cache
        # binds its arenas to — shares the op queue / launch accounting;
        # record_trace: keep a PimTrace for model-face replay
        self.cache = PagedKVCache(cfg, num_pages=num_pages,
                                  page_size=page_size, use_pallas=use_pallas,
                                  lib=lib, record_trace=record_trace)
        self.use_pallas = use_pallas
        # interpret-mode plumbing (was hardcoded True): default follows
        # the backend — compiled kernels on TPU, interpreter elsewhere
        self.interpret = ((jax.default_backend() != "tpu")
                          if interpret is None else interpret)
        self.fused = fused
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.rng_seed = jnp.asarray([seed, seed ^ 0x9E3779B9], jnp.uint32)
        self.rng_ctr = 0
        self.stats = {"prefills": 0, "decode_rounds": 0, "tokens_out": 0,
                      "jit_traces": 0, "fused_dispatches": 0}
        self._step = self._build_fused_step() if fused else None

    # ----------------------------- API -------------------------------- #

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_rounds: int = 1000) -> Dict[int, List[int]]:
        results: Dict[int, List[int]] = {}
        rounds = 0
        while (self.queue or self.active) and rounds < max_rounds:
            while self.queue:
                self._prefill(self.queue.pop(0))
            self._decode_round()
            rounds += 1
            for rid in list(self.active):
                r = self.active[rid]
                if len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
                    results[rid] = r.out_tokens
                    self.cache.free(rid)
                    del self.active[rid]
        return results

    # --------------------------- internals ----------------------------- #

    def _layer_params(self):
        return self.params["group0"]

    def _build_fused_step(self):
        """One jit covering forward + KV scatter + token selection.

        The Python body only runs when jax traces (cache miss), so the
        closure's counter bump is exactly a retrace counter.  Arenas are
        donated where the backend supports it (TPU/GPU) so the in-jit
        scatter is an in-place update.
        """
        eng = self

        def step(params, last, k_arena, v_arena, bt, lens, pages, slots,
                 seed, temps):
            eng.stats["jit_traces"] += 1
            return _fused_decode_step(
                eng.cfg, eng.pcfg, params, last, k_arena, v_arena, bt, lens,
                pages, slots, seed, temps, use_pallas=eng.use_pallas,
                interpret=eng.interpret)

        donate = (2, 3) if jax.default_backend() in ("tpu", "gpu") else ()
        return jax.jit(step, donate_argnums=donate)

    def _prefill(self, req: Request) -> None:
        cfg, p = self.cfg, self.params
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        seq = self.cache.create(req.req_id, len(req.prompt),
                                share_with=req.share_with,
                                shared_len=req.shared_len)
        start = seq.shared_prefix_pages * self.cache.page_size
        # full prefill forward (dense prefill math), then write kv pages
        max_len = len(req.prompt)
        cache = T.init_cache(cfg, 1, max_len)
        logits, dense_cache, _ = T.forward(
            cfg, self.pcfg, p, {"tokens": toks}, mode="prefill", cache=cache,
            lengths=jnp.asarray([max_len], jnp.int32))
        g = dense_cache["group0"]
        # g: {i_attn: (k,v)} stacked (L, 1, S, kvh, hd)
        for key, (k, v) in g.items():
            kk = k[:, 0].transpose(0, 1, 2, 3)       # (L, S, kvh, hd)
            self.cache.write_prompt_kv(seq, kk[:, start:max_len],
                                       v[:, 0][:, start:max_len], start=start)
        tok = self._sample(logits[:, -1], req.temperature)
        req.out_tokens.append(int(tok[0]))
        self.active[req.req_id] = req
        self.stats["prefills"] += 1

    def _decode_round(self) -> None:
        if not self.active:
            return
        rids = sorted(self.active)
        # reserve the slot for the incoming token on every sequence; the
        # CoW copies all land in ONE batched launch before attention reads
        # the arena (constant dispatch count, however many sequences fork)
        for r in rids:
            self.cache.ensure_writable_tail(self.cache.seqs[r])
        self.cache.flush_pending()
        if self.fused:
            toks = self._decode_round_fused(rids)
        else:
            toks = self._decode_round_eager(rids)
        for i, r in enumerate(rids):
            self.active[r].out_tokens.append(int(toks[i]))
        self.stats["decode_rounds"] += 1
        self.stats["tokens_out"] += len(rids)

    def _decode_round_fused(self, rids: List[int]) -> np.ndarray:
        """One compiled dispatch for the whole round; one host transfer."""
        B = len(rids)
        Bp = _bucket_pow2(B)
        # batch bucketing: pad rows duplicate sequence 0 — the duplicate
        # attention is wasted compute, and the duplicate scatter writes
        # the *same* values to the *same* (page, slot), so it is a no-op
        idx = list(range(B)) + [0] * (Bp - B)
        seqs = [self.cache.seqs[rids[i]] for i in idx]
        last = np.asarray([[self.active[rids[i]].out_tokens[-1]]
                           for i in idx], np.int32)
        temps = np.asarray([self.active[rids[i]].temperature for i in idx],
                           np.float32)
        pages = np.asarray([s.pages[-1] for s in seqs], np.int32)
        slots = np.asarray([s.length % self.cache.page_size for s in seqs],
                           np.int32)
        bt, lens = self.cache.block_table([rids[i] for i in idx])
        self.rng_ctr += 1
        seed = self.rng_seed + jnp.uint32(self.rng_ctr)
        tokens, k_arena, v_arena = self._step(
            self.params, jnp.asarray(last), self.cache.k_arena,
            self.cache.v_arena, bt, lens, jnp.asarray(pages),
            jnp.asarray(slots), seed, jnp.asarray(temps))
        self.cache.commit_fused_round(rids, k_arena, v_arena)
        # per-engine count: the queue's fused_decode counter is global
        # to the (possibly shared) lib, this one is this engine's own
        self.stats["fused_dispatches"] += 1
        return np.asarray(tokens)[:B]      # the round's one host transfer

    def _decode_round_eager(self, rids: List[int]) -> np.ndarray:
        """Pre-fusion baseline: Python layer loop, separate scatter."""
        last = jnp.asarray([[self.active[r].out_tokens[-1]] for r in rids],
                           jnp.int32)
        bt, lens = self.cache.block_table(rids)
        logits, k_new, v_new = _eager_decode_forward(
            self.cfg, self.pcfg, self.params, last, self.cache.k_arena,
            self.cache.v_arena, bt, lens, use_pallas=self.use_pallas,
            interpret=self.interpret)
        # account the per-layer jitted paged-attention dispatches (the
        # O(num_layers) launches fusion removes) so fused-vs-eager
        # dispatch comparisons measure the real gap
        self.cache.queue.count_external("eager_attn_layer",
                                        self.cache.n_layers)
        # scatter the whole round's new KV (all layers, all sequences) in
        # one coalesced launch per arena
        self.cache.write_token_kv_batch(rids, k_new[:, :, 0], v_new[:, :, 0])
        temps = jnp.asarray([self.active[r].temperature for r in rids],
                            jnp.float32)
        self.rng_ctr += 1
        seed = self.rng_seed + jnp.uint32(self.rng_ctr)
        toks = _select_tokens(logits[:, 0], temps, seed,
                              use_pallas=self.use_pallas,
                              interpret=self.interpret)
        return np.asarray(toks)            # one host transfer

    def _sample(self, logits: jax.Array, temperature: float) -> np.ndarray:
        """Prefill-time sampling: delegates to the round sampler so the
        inverse-CDF draw has exactly one implementation."""
        if temperature == 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.rng_ctr += 1
        seed = self.rng_seed + jnp.uint32(self.rng_ctr)
        temps = jnp.full((logits.shape[0],), temperature, jnp.float32)
        return np.asarray(_select_tokens(logits, temps, seed,
                                         use_pallas=self.use_pallas,
                                         interpret=self.interpret))


# ---------------------------------------------------------------------- #
# Fused decode step (traced under one jax.jit per engine)
# ---------------------------------------------------------------------- #


def _fused_decode_step(cfg, pcfg, params, last, k_arena, v_arena, bt, lens,
                       pages, slots, seed, temps, *, use_pallas: bool,
                       interpret: bool):
    """Forward (scan over layers) + KV scatter + token selection: the
    whole decode round as one compiled program over donated arenas."""
    logits, k_new, v_new = _paged_decode_forward(
        cfg, pcfg, params, last, k_arena, v_arena, bt, lens,
        use_pallas=use_pallas, interpret=interpret)
    k_arena = rc_ops.kv_scatter_inline(
        k_arena, pages, slots, k_new[:, :, 0].astype(k_arena.dtype),
        use_pallas=use_pallas, interpret=interpret)
    v_arena = rc_ops.kv_scatter_inline(
        v_arena, pages, slots, v_new[:, :, 0].astype(v_arena.dtype),
        use_pallas=use_pallas, interpret=interpret)
    tokens = _select_tokens(logits[:, 0], temps, seed,
                            use_pallas=use_pallas, interpret=interpret)
    return tokens, k_arena, v_arena


def _select_tokens(logits: jax.Array, temps: jax.Array, seed: jax.Array, *,
                   use_pallas: bool, interpret: bool) -> jax.Array:
    """Per-request token choice: greedy rows take the argmax, sampled
    rows take a D-RaNGe inverse-CDF draw at their own temperature.  An
    all-greedy batch skips the TRNG + softmax entirely (lax.cond), and
    nothing here syncs to host — callers do one transfer per round."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def sampled(_):
        u = dr_ops.pim_random_uniform(seed, logits.shape[0], 1,
                                      use_pallas=use_pallas,
                                      interpret=interpret)[:, 0]
        t = jnp.where(temps > 0.0, temps, 1.0)
        probs = jax.nn.softmax(logits.astype(jnp.float32) / t[:, None], axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        drawn = jnp.argmax(cum > u[:, None], axis=-1).astype(jnp.int32)
        return jnp.where(temps == 0.0, greedy, drawn)

    return jax.lax.cond(jnp.all(temps == 0.0), lambda _: greedy, sampled,
                        operand=None)


def _decode_layer(cfg, kind, sp, x, sin, cos, k_l, v_l, attend):
    """One sublayer of the single-token decode forward — the one source
    of truth shared by the fused scan body and the eager baseline loop.
    ``attend(q, k_self, v_self)`` supplies the paged-attention call (the
    two paths differ only in how that dispatch is issued).  Returns
    (x, (k_tok, v_tok) | None)."""
    hd = cfg.resolved_head_dim
    h = rmsnorm(x, sp["norm"], cfg.norm_eps)
    if kind != "attn":
        return x + mlp(sp["mlp"], h, cfg.activation), None
    q = jnp.einsum("bsd,dhk->bshk", h, cast(sp["attn"]["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", h, cast(sp["attn"]["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", h, cast(sp["attn"]["wv"]))
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    # attention over arena pages with the fresh token (not yet written)
    # merged in-kernel
    o = attend(q[:, 0], k_l, v_l, k[:, 0], v[:, 0])
    out = jnp.einsum("bshk,hkd->bsd", o[:, None], cast(sp["attn"]["wo"]))
    return x + out, (k[:, 0], v[:, 0])


def _paged_decode_forward(cfg: ModelConfig, pcfg, params, tokens, k_arena,
                          v_arena, block_tables, lengths, *,
                          use_pallas: bool = False, interpret: bool = True):
    """Decoder forward for one token: ``lax.scan`` over the stacked
    layer params and the per-layer arena slices — O(1) program size in
    depth, and the current token's K/V merges inside the paged kernel.

    Returns (logits (b,1,V), k_new, v_new (L, b, 1, kvh, hd)).
    """
    hd = cfg.resolved_head_dim
    x = embed(params["embed"], tokens, cfg)
    positions = lengths[:, None].astype(jnp.int32)  # token pos == length
    sin, cos = rope_sincos(positions, hd, cfg.rope_theta)
    kinds = T.layer_groups(cfg)[0][1]

    def attend(q, k_l, v_l, k_self, v_self):
        return pa_ops.paged_attention_inline(
            q, k_l, v_l, block_tables, lengths, sm_scale=hd ** -0.5,
            use_pallas=use_pallas, interpret=interpret,
            k_self=k_self, v_self=v_self)

    def body(x, xs):
        p_layer, k_l, v_l = xs
        k_tok = v_tok = None
        for i, kind in enumerate(kinds):
            x, kv = _decode_layer(cfg, kind, p_layer[f"{i}_{kind}"], x,
                                  sin, cos, k_l, v_l, attend)
            if kv is not None:
                k_tok, v_tok = kv
        return x, (k_tok, v_tok)

    x, (k_news, v_news) = jax.lax.scan(
        body, x, (params["group0"], k_arena, v_arena))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_out(params["embed"], x, cfg)
    return logits, k_news[:, :, None], v_news[:, :, None]


def _eager_decode_forward(cfg: ModelConfig, pcfg, params, tokens, k_arena,
                          v_arena, block_tables, lengths, *,
                          use_pallas: bool = False, interpret: bool = True):
    """Pre-fusion baseline: Python loop over layers, one jitted
    paged-attention dispatch per layer.  Shares ``_decode_layer`` with
    the fused path (the self-token merge still happens in-kernel — the
    old full-history re-reading merge pass is gone)."""
    hd = cfg.resolved_head_dim
    x = embed(params["embed"], tokens, cfg)
    positions = lengths[:, None].astype(jnp.int32)  # token pos == length
    sin, cos = rope_sincos(positions, hd, cfg.rope_theta)
    gparams = params["group0"]
    L, kinds = T.layer_groups(cfg)[0]

    def attend(q, k_l, v_l, k_self, v_self):
        return pa_ops.paged_attention(
            q, k_l, v_l, block_tables, lengths, sm_scale=hd ** -0.5,
            use_pallas=use_pallas, interpret=interpret,
            k_self=k_self, v_self=v_self)

    k_news, v_news = [], []
    for li in range(L):
        p_layer = jax.tree.map(lambda a: a[li], gparams)
        for i, kind in enumerate(kinds):
            x, kv = _decode_layer(cfg, kind, p_layer[f"{i}_{kind}"], x,
                                  sin, cos, k_arena[li], v_arena[li], attend)
            if kv is not None:
                k_news.append(kv[0][None])   # (1, b, kvh, hd)
                v_news.append(kv[1][None])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_out(params["embed"], x, cfg)
    k_new = jnp.concatenate(k_news, axis=0)[:, :, None]   # (L, b, 1, kvh, hd)
    v_new = jnp.concatenate(v_news, axis=0)[:, :, None]
    return logits, k_new, v_new

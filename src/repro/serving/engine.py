"""Continuous-batching serving engine over the paged PiM KV cache.

Request lifecycle: queue -> prefill (model prefill pass, KV written into
arena pages) -> decode rounds (paged attention over block tables, one
token per active sequence per round, new arrivals join between rounds)
-> finish (pages freed with pim_init, stats recorded).

The engine runs the *paged* attention path: per-layer KV lives only in
the arena; the model's dense-cache path is never materialized.  Forking
(`n>1` samples sharing a prompt) uses the cache's RowClone CoW.
Sampling consumes the D-RaNGe TPU generator (`pim_rand`).

Arena mutations go through the cache's batched PiM op scheduler: a
decode round issues one flush for the round's CoW copies (before
attention reads the arena) and one for the round's KV scatter — a
constant number of kernel launches per round, independent of
``num_layers`` and the active-batch size.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.kernels.drange import ops as dr_ops
from repro.kernels.paged_attention import ops as pa_ops
from repro.models import transformer as T
from repro.models import attention as attn_mod
from repro.models.layers import rmsnorm, cast, logits_out, embed, apply_rope, rope_sincos
from .kv_cache import PagedKVCache


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                    # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 1.0
    share_with: Optional[int] = None      # prefix sharing source
    shared_len: int = 0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class PagedEngine:
    """Single-host engine for GQA decoder-only models (the paged path)."""

    def __init__(self, cfg: ModelConfig, params, *, page_size: int = 16,
                 num_pages: int = 256, pcfg: Optional[ParallelConfig] = None,
                 seed: int = 0, use_pallas: bool = False):
        assert cfg.family in ("dense", "vlm"), "paged engine: GQA archs"
        self.cfg = cfg
        self.params = params
        self.pcfg = pcfg or ParallelConfig(attention_impl="naive", remat="none")
        self.cache = PagedKVCache(cfg, num_pages=num_pages,
                                  page_size=page_size, use_pallas=use_pallas)
        self.use_pallas = use_pallas
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.rng_seed = jnp.asarray([seed, seed ^ 0x9E3779B9], jnp.uint32)
        self.rng_ctr = 0
        self.stats = {"prefills": 0, "decode_rounds": 0, "tokens_out": 0}

    # ----------------------------- API -------------------------------- #

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_rounds: int = 1000) -> Dict[int, List[int]]:
        results: Dict[int, List[int]] = {}
        rounds = 0
        while (self.queue or self.active) and rounds < max_rounds:
            while self.queue:
                self._prefill(self.queue.pop(0))
            self._decode_round()
            rounds += 1
            for rid in list(self.active):
                r = self.active[rid]
                if len(r.out_tokens) >= r.max_new_tokens:
                    r.done = True
                    results[rid] = r.out_tokens
                    self.cache.free(rid)
                    del self.active[rid]
        return results

    # --------------------------- internals ----------------------------- #

    def _layer_params(self):
        return self.params["group0"]

    def _prefill(self, req: Request) -> None:
        cfg, p = self.cfg, self.params
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        seq = self.cache.create(req.req_id, len(req.prompt),
                                share_with=req.share_with,
                                shared_len=req.shared_len)
        start = seq.shared_prefix_pages * self.cache.page_size
        # full prefill forward (dense prefill math), then write kv pages
        max_len = len(req.prompt)
        cache = T.init_cache(cfg, 1, max_len)
        logits, dense_cache, _ = T.forward(
            cfg, self.pcfg, p, {"tokens": toks}, mode="prefill", cache=cache,
            lengths=jnp.asarray([max_len], jnp.int32))
        g = dense_cache["group0"]
        # g: {i_attn: (k,v)} stacked (L, 1, S, kvh, hd)
        for key, (k, v) in g.items():
            kk = k[:, 0].transpose(0, 1, 2, 3)       # (L, S, kvh, hd)
            self.cache.write_prompt_kv(seq, kk[:, start:max_len],
                                       v[:, 0][:, start:max_len], start=start)
        tok = self._sample(logits[:, -1], req.temperature)
        req.out_tokens.append(int(tok[0]))
        self.active[req.req_id] = req
        self.stats["prefills"] += 1

    def _decode_round(self) -> None:
        if not self.active:
            return
        cfg, p = self.cfg, self.params
        rids = sorted(self.active)
        last = jnp.asarray([[self.active[r].out_tokens[-1]] for r in rids],
                           jnp.int32)
        # reserve the slot for the incoming token on every sequence; the
        # CoW copies all land in ONE batched launch before attention reads
        # the arena (constant dispatch count, however many sequences fork)
        for r in rids:
            self.cache.ensure_writable_tail(self.cache.seqs[r])
        self.cache.flush_pending()
        max_pages = max(len(self.cache.seqs[r].pages) for r in rids)
        bt, lens = self.cache.block_table(rids, max_pages)

        logits, k_new, v_new = _paged_decode_forward(
            cfg, self.pcfg, p, last, self.cache.k_arena, self.cache.v_arena,
            bt, lens, use_pallas=self.use_pallas)

        # scatter the whole round's new KV (all layers, all sequences) in
        # one coalesced launch per arena
        self.cache.write_token_kv_batch(rids, k_new[:, :, 0], v_new[:, :, 0])
        sampled = self._sample(logits[:, 0], 1.0)
        greedy = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, r in enumerate(rids):
            t = self.active[r].temperature
            self.active[r].out_tokens.append(int(greedy[i] if t == 0.0
                                                 else sampled[i]))
        self.stats["decode_rounds"] += 1
        self.stats["tokens_out"] += len(rids)

    def _sample(self, logits: jax.Array, temperature: float) -> np.ndarray:
        if temperature == 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        # D-RaNGe randomness: uniform from the pim TRNG kernel
        self.rng_ctr += 1
        u = dr_ops.pim_random_uniform(
            self.rng_seed + jnp.uint32(self.rng_ctr), logits.shape[0], 1,
            use_pallas=self.use_pallas)[:, 0]
        probs = jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        return np.asarray(jnp.argmax(cum > u[:, None], axis=-1))


def _paged_decode_forward(cfg: ModelConfig, pcfg, params, tokens, k_arena,
                          v_arena, block_tables, lengths, *,
                          use_pallas: bool = False):
    """Decoder forward for one token using paged attention per layer.

    Returns (logits (b,1,V), k_new, v_new (L, b, 1, kvh, hd)).
    Python loop over layers (host engine; CPU-scale models).
    """
    hd = cfg.resolved_head_dim
    x = embed(params["embed"], tokens, cfg)
    positions = lengths[:, None].astype(jnp.int32)  # token pos == length
    gparams = params["group0"]
    L = T.layer_groups(cfg)[0][0]
    kinds = T.layer_groups(cfg)[0][1]
    k_news, v_news = [], []
    for li in range(L):
        p_layer = jax.tree.map(lambda a: a[li], gparams)
        for i, kind in enumerate(kinds):
            sp = p_layer[f"{i}_{kind}"]
            h = rmsnorm(x, sp["norm"], cfg.norm_eps)
            if kind == "attn":
                q = jnp.einsum("bsd,dhk->bshk", h, cast(sp["attn"]["wq"]))
                k = jnp.einsum("bsd,dhk->bshk", h, cast(sp["attn"]["wk"]))
                v = jnp.einsum("bsd,dhk->bshk", h, cast(sp["attn"]["wv"]))
                sin, cos = rope_sincos(positions, hd, cfg.rope_theta)
                q = apply_rope(q, sin, cos)
                k = apply_rope(k, sin, cos)
                k_news.append(k[:, 0][None])   # (1, b, kvh, hd)
                v_news.append(v[:, 0][None])
                # attention over arena pages + the fresh token (not yet
                # written): paged part + correction term
                o_paged = pa_ops.paged_attention(
                    q[:, 0], k_arena[li], v_arena[li],
                    block_tables, lengths, use_pallas=use_pallas,
                    sm_scale=hd ** -0.5, interpret=True)
                # include self-attention to the current token via the
                # streaming softmax merge
                o = _merge_self_token(q[:, 0], k[:, 0], v[:, 0], o_paged,
                                      k_arena[li], v_arena[li],
                                      block_tables, lengths, hd)
                out = jnp.einsum("bshk,hkd->bsd", o[:, None], cast(sp["attn"]["wo"]))
            else:
                from repro.models.layers import mlp
                out = mlp(sp["mlp"], h, cfg.activation)
            x = x + out
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_out(params["embed"], x, cfg)
    k_new = jnp.concatenate(k_news, axis=0)[:, :, None]   # (L, b, 1, kvh, hd)
    v_new = jnp.concatenate(v_news, axis=0)[:, :, None]
    return logits, k_new, v_new


def _merge_self_token(q, k_self, v_self, o_paged, k_arena, v_arena, bt, lens, hd):
    """Numerically merge paged attention (history) with the current
    token's self-attention using log-sum-exp streaming combination."""
    b, h, d = q.shape
    kvh = k_self.shape[1]
    g = h // kvh
    scale = hd ** -0.5
    qg = q.reshape(b, kvh, g, d).astype(jnp.float32)
    # history lse: recompute from arena (small b on host engine)
    khist = k_arena[bt]                                  # (b, P, ps, kvh, hd)
    vhist = v_arena[bt]
    P, ps = khist.shape[1], khist.shape[2]
    khist = khist.reshape(b, P * ps, kvh, d)
    s_hist = jnp.einsum("bkgd,bskd->bkgs", qg, khist.astype(jnp.float32)) * scale
    pos = jnp.arange(P * ps)[None, None, None, :]
    s_hist = jnp.where(pos < lens[:, None, None, None], s_hist, -1e30)
    m_hist = jnp.max(s_hist, axis=-1)
    l_hist = jnp.sum(jnp.exp(s_hist - m_hist[..., None]), axis=-1)
    s_self = jnp.einsum("bkgd,bkd->bkg", qg,
                        k_self.astype(jnp.float32)) * scale
    m_new = jnp.maximum(m_hist, s_self)
    l_new = l_hist * jnp.exp(m_hist - m_new) + jnp.exp(s_self - m_new)
    w_hist = (l_hist * jnp.exp(m_hist - m_new) / l_new)
    w_self = (jnp.exp(s_self - m_new) / l_new)
    o = (o_paged.reshape(b, kvh, g, d).astype(jnp.float32) * w_hist[..., None]
         + v_self.astype(jnp.float32)[:, :, None, :] * w_self[..., None])
    return o.reshape(b, h, d).astype(o_paged.dtype)

"""Continuous-batching serving engine over the paged PiM KV cache.

Request lifecycle: queue -> prefill (model prefill pass, KV written into
arena pages) -> decode rounds (paged attention over block tables, one
token per active sequence per round, new arrivals join between rounds)
-> finish (pages freed with pim_init, stats recorded).

The engine runs the *paged* attention path: per-layer KV lives only in
the arena; the model's dense-cache path is never materialized.  Forking
(`n>1` samples sharing a prompt) uses the cache's RowClone CoW.
Sampling consumes the D-RaNGe TPU generator (`pim_rand`).

A decode round is ONE compiled dispatch (the fused decode step):

* the layer loop is a ``jax.lax.scan`` over the stacked ``group0``
  params and the per-layer arena slices, so the traced program is O(1)
  in depth;
* the current token's K/V merge into attention happens *inside* the
  paged-attention kernel (``k_self``/``v_self``) — no post-kernel pass
  re-reads the arena history;
* the round's KV scatter and the token selection (greedy argmax or
  D-RaNGe inverse-CDF sample, per request) run in the same jit, with
  both arenas donated on backends that support donation, so the round
  issues no separate mutation launch and exactly one device->host
  transfer (the chosen tokens);
* block-table widths and the active batch are bucketed to powers of two
  (padding rows duplicate sequence 0, whose duplicate scatter writes
  identical values to identical slots), so growing/forking workloads
  retrace only at bucket boundaries — ``stats["jit_traces"]`` counts
  retraces, ``PimOpQueue`` counts dispatches.

Pre-round CoW copies still route through the cache's batched PiM op
scheduler: one coalesced copy flush (only when some sequence forks)
lands before the fused step reads the arena.  ``fused=False`` keeps the
pre-fusion eager path (a Python loop over layers, one launch per layer)
as the benchmark baseline.

A prefill batch is ONE compiled dispatch too (the fused bucketed
prefill, symmetric with the decode round):

* queued prompts are bucketed by length to powers of two (padding
  positions carry attention-masked tokens) and stacked into one batch
  per bucket, the batch itself bucketed to a power of two (padding rows
  duplicate request 0);
* the forward is a ``jax.lax.scan`` over the stacked layer params with
  a length-masked flash-attention prefill
  (``repro.kernels.flash_attention``, ``lengths`` masking), so the
  traced program is O(1) in depth and a batch retraces only per
  distinct (length-bucket, batch-bucket) pair —
  ``stats["prefill_jit_traces"]`` counts retraces;
* every prompt's new KV pages scatter straight into the donated arenas
  *inside* the jit (``rc_ops.kv_scatter_inline`` against the cache's
  host-side ``prefill_scatter_plan``), recorded through ``PimOpQueue``
  accounting as the ``fused_prefill`` kind — no host-side
  ``write_prompt_kv`` round-trip;
* first-token selection runs in the same jit with one host transfer
  per batch.

When forking/active sequences coexist with queued prompts, the
pre-round CoW copy flush is dispatched *before* the prefill host work
(``PimOpQueue.flush_overlapped``), so the coalesced copies execute on
device behind prefill batch assembly instead of stalling the decode
round.  ``fused_prefill=False`` keeps the eager per-request path (one
un-jitted dense ``T.forward`` per prompt + host-side KV writes) as the
parity oracle and benchmark baseline.  The oracle contract is exact for
greedy requests (``temperature == 0``); sampled requests draw one TRNG
seed per fused *batch* vs one per eager *request*, so the two modes'
random streams — and therefore sampled tokens — legitimately differ.

Chunked prefill with decode-interleaved scheduling
(``max_prefill_chunk=N``): a monolithic prefill batch makes in-flight
decodes wait behind the whole prompt, so a long arriving prompt
stretches every active request's inter-token latency by its full
forward.  With a chunk budget set, prompts are split into
``max_prefill_chunk``-sized chunks processed across successive engine
rounds — each round runs at most ONE fused chunk batch (pending chunks
fill the round's token budget, FIFO, same chunk-length bucket) *and*
the fused decode round, so decodes emit a token every round regardless
of arriving prompt length.  A chunk's queries attend causally over the
chunk itself **plus**, non-causally, the sequence's already-committed
arena KV (the flash kernel's prefix-KV operands; the prefix rides in as
an in-scan arena gather over the sequence's block table, masked by the
committed length).  Chunk KV scatters in-jit against the cache's
per-chunk ``prefill_scatter_plan(start, stop)`` and is accounted as the
same ``fused_prefill`` kind.  The prefix block table spans the
sequence's FULL page list (valid length = committed tokens), so every
chunk of one prompt shares one table-width bucket and chunk batches
retrace only per distinct (chunk-bucket, batch-bucket, table-width)
triple — never per chunk count.  ``stats["prefill_chunks"]`` counts
chunks dispatched; ``stats["decode_stall_rounds"]`` counts rounds in
which active decodes waited behind an over-budget (un-chunked) prefill
— structurally zero when chunking is on, nonzero for the eager oracle
fed the same long-prompt workload.

Multi-round fusion (the "one dispatch per N rounds" step).  Two layers:

* **Mixed rounds** (``mixed_rounds=True``, chunked + fused): a round
  that runs both a chunk batch and a decode round used to cost two
  jitted dispatches; they already share ``_sublayer``, the donated
  arenas, and bucketed shapes, so the engine traces them as ONE program
  — the chunk half's first tokens wire straight into the decode half's
  inputs (``d_from_chunk``), the chunk KV scatter is traced before the
  decode forward so a prompt finishing this round decodes against its
  own just-written KV, and the whole mixed round is accounted as ONE
  ``fused_mixed`` launch (``stats["mixed_dispatches"]``).
* **K-blocked decode** (``decode_block_rounds=K``): when no admissions
  are pending, the engine runs up to K decode rounds inside a
  ``jax.lax.while_loop`` in ONE dispatch — one host round-trip (and one
  token transfer) per K tokens.  The host reserves every row's K-token
  arena capacity up front (``PagedKVCache.reserve_tokens``) so each
  in-loop round has a host-planned (page, slot) destination; in-loop
  stop detection covers per-request EOS and token budgets, and a row
  that stops writes the value *already in its slot* back to it (a
  masked write-back via ``kv_gather_inline``) so the scatter stays a
  structural no-op for dead rows and the arena is bit-identical to a
  round-at-a-time run.  Blocks are counted in
  ``stats["multi_round_blocks"]``; the per-block launch is the
  ``fused_decode_block`` kind, so dispatches-per-token falls below 1
  after warmup.  ``decode_block_rounds=1`` (default) and the eager path
  are kept as round-at-a-time oracles.

Tensor-parallel sharded serving (``mesh=``): pass a mesh with a
``model`` axis and every fused step runs as a ``shard_map`` program
spanning all N devices — still ONE dispatch per round.  Layer params
shard Megatron-style over their logical axes (``heads`` / ``kv_heads``
/ ``ff`` / ``vocab`` -> ``model``; the spec tree comes from
``models.params.param_specs`` under a ``sharding_env``), the KV arenas
split on the KV-head axis (each device holds its head slice of every
page — page ids, block tables, and the op queue stay mesh-wide), and
block tables / lengths / sampling state are replicated.  Inside the
program: vocab-parallel embedding and logits (masked local lookup /
local partial logits placed at ``axis_index * V_local``, both reduced
with an exact-zeros ``psum`` — bit-identical to host-local math),
row-parallel attention-out and MLP-down ``psum``s (the only float
reordering vs host-local), and the final logit reduce routed through
``distributed.compression.psum_compressed`` when
``compressed_collectives=True`` (int8 wire traffic, logits within
quantization tolerance).  Token selection runs replicated from the full
logits, so every shard picks the same token and the round's single
host transfer is unchanged.  CPU dev boxes get a real multi-device
mesh with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

Hybrid layouts (SSM / MoE sublayers).  The engine serves every
single-group decoder in the zoo — dense GQA, pure-SSM (mamba2), hybrid
attention+Mamba+MoE (jamba), and non-MLA MoE — with per-layer-kind
dispatch *inside* the existing ``lax.scan`` (:func:`_run_kinds`), so a
hybrid decode round is still ONE compiled dispatch:

* attention sublayers keep the paged KV arenas exactly as above;
* Mamba sublayers carry per-sequence recurrent state in the cache's
  :class:`~repro.serving.kv_cache.PagedStateArena` — constant-size rows
  (no growth, no prefix sharing, copy-on-fork), gathered at the batch's
  state rows and scattered back in-jit, so the fused steps add zero
  launches (the scan's xs extend with the (conv, ssm) arenas and the
  updated arenas ride out as stacked ys on donated buffers);
* MoE sublayers route in-jit through the exact dense-fallback MoE
  (``models.moe._dense_moe`` — per-token independent, jit-traceable),
  so expert routing adds zero launches and the eager oracle stays
  bit-identical;
* the eager paths pay their state writes through the op queue's
  ``ssm_state_write`` kind instead (the ``SSM_STATE_WRITE`` opcode's
  JAX face): ONE coalesced state-scatter launch per arena per round,
  constant in depth and batch, hazard-tracked against copy-on-fork.

Chunked prefill over SSM layers must split prompts at multiples of
``cfg.ssm.chunk_size`` — the SSD chunk scan regroups bit-identically
only at chunk boundaries — so the engine requires
``max_prefill_chunk % chunk_size == 0`` for state-arena families.
MLA (latent-KV) and multi-group layouts still serve through the dense
path; ``mesh=`` serving stays dense-only.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.distributed.compression import _axis_size, psum_compressed
from repro.distributed.sharding import sharding_env
from repro.kernels.drange import ops as dr_ops
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.paged_attention import ops as pa_ops
from repro.kernels.rowclone import ops as rc_ops
from repro.models import moe as moe_mod
from repro.models import params as P_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as T
from repro.models.layers import (rmsnorm, cast, logits_out, embed, mlp,
                                 apply_rope, rope_sincos)
from .kv_cache import PagedKVCache, _bucket_pow2


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                    # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 1.0
    # stop generating after emitting this token (the EOS token itself is
    # kept in out_tokens); None = budget-only stopping.  The K-blocked
    # decode loop detects this on device, between host round-trips.
    eos_token_id: Optional[int] = None
    share_with: Optional[int] = None      # prefix sharing source
    shared_len: int = 0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class _ChunkPrefill:
    """A mid-prefill request on the chunk backlog: ``off`` tokens of its
    prompt (shared prefix included) are committed to the arena.

    ``dep``/``dep_len``: a prefix-sharing request reads its *source*
    sequence's pages — under chunked prefill those commit across rounds,
    so this state may not be scheduled until the source has committed at
    least ``dep_len`` tokens (the monolithic path never sees this hazard
    because all prefill completes before any decode).

    ``write=False``: a prompt fully covered by a shared prefix has no KV
    of its own to commit — it runs as a single 1-token chunk (the last
    prompt position recomputed against the committed prefix) whose
    scatter is suppressed, so even a very long covered sharer costs one
    bounded chunk round, never a whole-prompt forward."""
    req: Request
    off: int
    dep: Optional[int] = None
    dep_len: int = 0
    write: bool = True

    @property
    def remaining(self) -> int:
        return len(self.req.prompt) - self.off


class PagedEngine:
    """Single-host engine for GQA decoder-only models (the paged path)."""

    def __init__(self, cfg: ModelConfig, params, *, page_size: int = 16,
                 num_pages: int = 256, pcfg: Optional[ParallelConfig] = None,
                 seed: int = 0, use_pallas: bool = False,
                 interpret: Optional[bool] = None, fused: bool = True,
                 fused_prefill: bool = True,
                 max_prefill_chunk: Optional[int] = None,
                 decode_block_rounds: int = 1, mixed_rounds: bool = True,
                 lib=None, record_trace: bool = False,
                 mesh=None, compressed_collectives: bool = False,
                 prefix_cache: bool = False):
        assert cfg.family in ("dense", "vlm", "ssm", "hybrid", "moe"), \
            "paged engine: decoder-only GQA / SSM / hybrid / MoE archs"
        if cfg.mla is not None:
            raise ValueError(
                "paged engine: MLA latent-KV attention is not paged — "
                "deepseek-style archs serve through the dense path")
        if len(T.layer_groups(cfg)) != 1:
            raise ValueError(
                "paged engine: single-group layouts only (leading dense "
                "layers split the scan; set first_dense_layers=0)")
        self.cfg = cfg
        self.pcfg = pcfg or ParallelConfig(attention_impl="naive", remat="none")
        # per-scan-step sublayer kinds — the hybrid dispatch plan every
        # forward (fused scans AND the eager oracle) follows in lockstep
        self._kinds = T.layer_groups(cfg)[0][1]
        self._has_attn = "attn" in self._kinds
        self._has_ssm = "mamba" in self._kinds
        self._has_moe = "moe" in self._kinds
        # tensor-parallel sharded serving: fused steps become shard_map
        # programs over the mesh's `model` axis (see module docstring)
        self.mesh = mesh
        self.compressed_collectives = compressed_collectives
        if compressed_collectives and mesh is None:
            raise ValueError("compressed_collectives requires mesh=")
        self._param_specs = None
        self._arena_spec = None
        if mesh is not None:
            if self._has_ssm or self._has_moe:
                raise ValueError(
                    "paged engine: mesh= serving is dense-only (SSM state "
                    "arenas and in-jit MoE routing are host-local)")
            if "model" not in dict(mesh.shape):
                raise ValueError("engine mesh needs a 'model' axis")
            n = mesh.shape["model"]
            if n > 1:
                bad = {name: dim for name, dim in
                       (("num_heads", cfg.num_heads),
                        ("num_kv_heads", cfg.num_kv_heads),
                        ("d_ff", cfg.d_ff),
                        ("vocab_size", cfg.vocab_size))
                       if dim % n != 0}
                if bad:
                    # resolve_spec would silently replicate a non-divisible
                    # dim, and the steps' unconditional psums would then
                    # over-count that path by N — refuse instead
                    raise ValueError(
                        f"model dims {bad} not divisible by mesh model "
                        f"axis {n}")
            with sharding_env(mesh, fsdp=False):
                self._param_specs = P_mod.param_specs(T.model_defs(cfg))
            shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), self._param_specs,
                is_leaf=lambda s: isinstance(s, P))
            params = jax.device_put(params, shardings)
            self._arena_spec = P(None, None, None, "model", None)
        self.params = params
        # lib: caller-supplied JAX-face PimLib (pimolib v2) the cache
        # binds its arenas to — shares the op queue / launch accounting;
        # record_trace: keep a PimTrace for model-face replay
        # prefix_cache: radix-tree prefix cache over pages — prompts
        # automatically attach the longest committed full-page prefix of
        # any earlier prompt (create(..., tokens=)), committed prompts
        # index on completion (commit_prefix), cold entries evict LRU
        # under arena pressure
        self.prefix_cache = prefix_cache
        self.cache = PagedKVCache(cfg, num_pages=num_pages,
                                  page_size=page_size, use_pallas=use_pallas,
                                  lib=lib, record_trace=record_trace,
                                  mesh=mesh, prefix_cache=prefix_cache)
        self.use_pallas = use_pallas
        # interpret-mode plumbing (was hardcoded True): default follows
        # the backend — compiled kernels on TPU, interpreter elsewhere
        self.interpret = ((jax.default_backend() != "tpu")
                          if interpret is None else interpret)
        self.fused = fused
        self.fused_prefill = fused_prefill
        if max_prefill_chunk is not None and max_prefill_chunk < 1:
            raise ValueError("max_prefill_chunk must be >= 1 (or None to "
                             "disable chunked prefill)")
        if (max_prefill_chunk is not None and self._has_ssm
                and max_prefill_chunk % cfg.ssm.chunk_size != 0):
            raise ValueError(
                f"max_prefill_chunk={max_prefill_chunk} must be a multiple "
                f"of cfg.ssm.chunk_size={cfg.ssm.chunk_size}: the SSD chunk "
                "scan only regroups bit-identically when prompts split at "
                "chunk-size boundaries")
        # chunked prefill: prompts longer than this are split into
        # chunk-sized pieces processed across successive rounds, decode
        # interleaved (None = monolithic: a prompt prefills whole)
        self.max_prefill_chunk = max_prefill_chunk
        if decode_block_rounds < 1:
            raise ValueError("decode_block_rounds must be >= 1")
        if decode_block_rounds > 1 and not fused:
            raise ValueError("decode_block_rounds > 1 requires fused=True "
                             "(the eager path is the round-at-a-time oracle)")
        # persistent decode loop: with no admissions pending, run up to K
        # decode rounds per host round-trip in one lax.while_loop dispatch
        # (1 = round-at-a-time, the single-round fused oracle)
        self.decode_block_rounds = decode_block_rounds
        # fuse a round's chunk batch + decode round into one dispatch
        # (only reachable with chunking + both fused paths on)
        self.mixed_rounds = mixed_rounds
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        # chunk backlog: requests mid-prefill under the chunked scheduler
        self._chunk_q: List[_ChunkPrefill] = []
        self._chunk_by_id: Dict[int, _ChunkPrefill] = {}
        self.rng_seed = jnp.asarray([seed, seed ^ 0x9E3779B9], jnp.uint32)
        self.rng_ctr = 0
        self.stats = {"prefills": 0, "decode_rounds": 0, "tokens_out": 0,
                      "jit_traces": 0, "fused_dispatches": 0,
                      "prefill_jit_traces": 0, "fused_prefill_dispatches": 0,
                      "prefill_chunks": 0, "decode_stall_rounds": 0,
                      "multi_round_blocks": 0, "block_jit_traces": 0,
                      "mixed_dispatches": 0, "mixed_jit_traces": 0,
                      "prefix_hits": 0, "prefix_hit_tokens": 0,
                      "prefix_evictions": 0,
                      "state_pages": 0, "state_forks": 0,
                      "prefix_declined_ssm": 0}
        self._step = self._build_fused_step() if fused else None
        self._prefill_step = (self._build_fused_prefill_step()
                              if fused_prefill else None)
        self._chunk_step = (self._build_fused_chunk_step()
                            if fused_prefill and max_prefill_chunk is not None
                            else None)
        self._block_step = (self._build_fused_block_step()
                            if fused and decode_block_rounds > 1 else None)
        self._mixed_step = (self._build_fused_mixed_step()
                            if mixed_rounds and self._chunk_step is not None
                            and fused else None)
        # decode tails already reserved this round (the pre-prefill
        # overlap path reserves early; _decode_round must not re-reserve)
        self._reserved_tails: set = set()

    # ----------------------------- API -------------------------------- #

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def has_work(self) -> bool:
        """Anything queued, mid-prefill, or decoding?"""
        return bool(self.queue or self._chunk_q or self.active)

    def prefill_backlog_tokens(self) -> int:
        """Prompt tokens admitted but not yet committed to the arena:
        the chunk backlog's remaining work plus everything still in the
        submit queue.  The server's admission control divides this by
        the chunk budget to estimate how many rounds a new prompt waits
        before its first token."""
        return (sum(st.remaining for st in self._chunk_q)
                + sum(len(r.prompt) for r in self.queue))

    def set_prefill_chunk(self, n: int) -> None:
        """Retarget the per-round prefill chunk budget at runtime — the
        server's auto-tuner hook.  Legal only when the engine was built
        chunked (``max_prefill_chunk`` set at construction compiles the
        chunk/mixed steps); the budget is read fresh each scheduling
        tick, and chunk lengths bucket to powers of two, so moving it
        between pow2 values costs at most one retrace per new bucket."""
        if self.max_prefill_chunk is None:
            raise ValueError(
                "engine was built without chunked prefill "
                "(max_prefill_chunk=None); the chunk step only compiles "
                "at construction")
        if n < 1:
            raise ValueError("max_prefill_chunk must be >= 1")
        if self._has_ssm and n % self.cfg.ssm.chunk_size != 0:
            raise ValueError(
                f"max_prefill_chunk={n} must stay a multiple of "
                f"cfg.ssm.chunk_size={self.cfg.ssm.chunk_size} (SSD "
                "chunk-boundary bit-identity)")
        self.max_prefill_chunk = int(n)

    def step(self) -> Dict[int, List[int]]:
        """Run ONE engine round (the async server's unit of work):
        bounded prefill + the round's decode, returning any requests
        that finished.  With ``decode_block_rounds=K`` a pure-decode
        step may burn up to K rounds in its one dispatch — still one
        bounded unit between two looks at the arrival queue."""
        return self.run(max_rounds=1)

    def run(self, max_rounds: int = 1000) -> Dict[int, List[int]]:
        """Engine rounds until done: every round runs (at most) one
        prefill step AND the fused decode round.  With chunking on
        (``max_prefill_chunk``), the prefill step is at most one fused
        chunk batch — bounded work — so in-flight decodes emit a token
        every round however long the arriving prompts are.  Without it,
        the prefill step drains the whole queue (monolithic batches):
        rounds where that overshoots the chunk budget while decodes
        waited are counted in ``stats["decode_stall_rounds"]``.

        Multi-round fusion hooks in here: a chunk batch coexisting with
        decodes runs as ONE mixed dispatch (``mixed_rounds``, the tick
        reports it already decoded), and with nothing to admit the
        engine burns up to ``decode_block_rounds`` rounds per dispatch
        in the persistent K-block loop — ``rounds`` advances by the
        rounds the block actually consumed, so ``max_rounds`` keeps its
        round-at-a-time meaning."""
        results: Dict[int, List[int]] = {}
        rounds = 0
        chunked = self.fused_prefill and self.max_prefill_chunk is not None
        while ((self.queue or self._chunk_q or self.active)
               and rounds < max_rounds):
            had_active = bool(self.active)
            decoded = False
            if self.queue or self._chunk_q:
                if self.active:
                    # overlap the pre-round CoW flush with prefill work:
                    # reserve the decode tails NOW and dispatch the
                    # coalesced copies, so forking workloads pay the
                    # flush behind prefill host work (JAX dispatch is
                    # async), not in front of the decode step
                    self._reserve_tails(sorted(self.active))
                    self.cache.queue.flush_overlapped(self.cache.lib.flush)
                if chunked:
                    prefill_toks, decoded = self._prefill_tick(
                        allow_mixed=self._mixed_step is not None)
                else:
                    prefill_toks = self._prefill_round()
                if (had_active and self.max_prefill_chunk is not None
                        and prefill_toks > self.max_prefill_chunk):
                    # an un-chunked prefill blew the per-round budget
                    # while decodes were in flight: they waited behind
                    # it — the latency chunking bounds.  Never
                    # increments when the chunked scheduler is on.
                    self.stats["decode_stall_rounds"] += 1
                # a budget of 1 is satisfied by the prefill token alone:
                # retire those now instead of decoding a surplus token
                self._finish_done(results)
            elif self.active and self._block_step is not None:
                # pure decode, nothing to admit: one dispatch covers up
                # to K rounds (never past the caller's round budget)
                rounds += self._decode_block(max_rounds - rounds)
                self._finish_done(results)
                continue
            if not decoded:
                self._decode_round()
            rounds += 1
            self._finish_done(results)
        return results

    def _finish_done(self, results: Dict[int, List[int]]) -> None:
        # mirror the cache's prefix-sharing counters (engine.stats is
        # the one stats surface servers/benches read)
        for key in ("prefix_hits", "prefix_hit_tokens", "prefix_evictions",
                    "state_pages", "state_forks", "prefix_declined_ssm"):
            self.stats[key] = self.cache.stats[key]
        for rid in list(self.active):
            r = self.active[rid]
            hit_eos = (r.eos_token_id is not None and r.out_tokens
                       and r.out_tokens[-1] == r.eos_token_id)
            if len(r.out_tokens) >= r.max_new_tokens or hit_eos:
                r.done = True
                results[rid] = r.out_tokens
                self.cache.free(rid)
                del self.active[rid]
                self._reserved_tails.discard(rid)

    # --------------------------- internals ----------------------------- #

    def _layer_params(self):
        return self.params["group0"]

    def _sharded_specs(self, n_args, arena_at):
        """in_specs for a shard_map-wrapped step: params (arg 0) follow
        the resolved spec tree, arenas split on the KV-head axis, and
        everything else — block tables, lengths, scatter plans, seeds,
        temperatures — is replicated."""
        specs = [P()] * n_args
        specs[0] = self._param_specs
        for i in arena_at:
            specs[i] = self._arena_spec
        return tuple(specs)

    def _shard_wrap(self, fn, n_args, arena_at, n_extra_out=1):
        """Wrap a fused step fn as a shard_map program over the mesh:
        one dispatch spanning every device.  Outputs are ``n_extra_out``
        replicated values (tokens — identical on every shard, the final
        logit reduce and sampling run replicated) followed by the two
        sharded arenas and the (conv, ssm) state outputs — always None
        under a mesh (the constructor rejects mesh+SSM), so their P()
        specs map over zero leaves.  ``check_rep=False``: the
        collectives guarantee the replication the spec claims; jax's
        checker cannot see through the masked gathers."""
        out_specs = (P(),) * n_extra_out + (self._arena_spec,
                                            self._arena_spec, P(), P())
        return shard_map(fn, mesh=self.mesh,
                         in_specs=self._sharded_specs(n_args, arena_at),
                         out_specs=out_specs, check_rep=False)

    def _step_kwargs(self):
        kw = dict(use_pallas=self.use_pallas, interpret=self.interpret)
        if self.mesh is not None:
            kw.update(axis="model", compressed=self.compressed_collectives)
        return kw

    def _build_fused_step(self):
        """One jit covering forward + KV scatter + token selection.

        The Python body only runs when jax traces (cache miss), so the
        closure's counter bump is exactly a retrace counter.  Arenas are
        donated where the backend supports it (TPU/GPU) so the in-jit
        scatter is an in-place update.  With a mesh, the whole step runs
        as a shard_map program (constructed inside the traced body, so
        the retrace counter keeps its meaning).
        """
        eng = self

        def step(params, last, k_arena, v_arena, bt, lens, pages, slots,
                 seed, temps, conv_arena, ssm_arena, srows):
            eng.stats["jit_traces"] += 1
            fn = functools.partial(_fused_decode_step, eng.cfg, eng.pcfg,
                                   **eng._step_kwargs())
            if eng.mesh is not None:
                fn = eng._shard_wrap(fn, 13, (2, 3))
            return fn(params, last, k_arena, v_arena, bt, lens,
                      pages, slots, seed, temps, conv_arena, ssm_arena,
                      srows)

        donate = ((2, 3, 10, 11) if jax.default_backend() in ("tpu", "gpu")
                  else ())
        return jax.jit(step, donate_argnums=donate)

    def _build_fused_prefill_step(self):
        """One jit covering the whole prefill batch: masked forward +
        in-jit KV scatter + first-token selection.  Retraces only per
        distinct (length-bucket, batch-bucket) pair; the closure's
        counter bump is exactly a retrace counter (the body only runs on
        a trace-cache miss)."""
        eng = self

        def step(params, toks, lens, k_arena, v_arena, pages, slots, src,
                 seed, temps, conv_arena, ssm_arena, srows, has_writes):
            eng.stats["prefill_jit_traces"] += 1
            fn = functools.partial(_fused_prefill_step, eng.cfg, eng.pcfg,
                                   has_writes=has_writes,
                                   **eng._step_kwargs())
            if eng.mesh is not None:
                fn = eng._shard_wrap(fn, 13, (3, 4))
            return fn(params, toks, lens, k_arena, v_arena,
                      pages, slots, src, seed, temps, conv_arena,
                      ssm_arena, srows)

        donate = ((3, 4, 10, 11) if jax.default_backend() in ("tpu", "gpu")
                  else ())
        return jax.jit(step, donate_argnums=donate,
                       static_argnames=("has_writes",))

    def _build_fused_chunk_step(self):
        """One jit covering a whole chunk batch: prefix-KV masked chunk
        forward + in-jit chunk scatter + token selection.  Retraces only
        per distinct (chunk-bucket, batch-bucket, table-width) triple —
        counted in the same ``stats["prefill_jit_traces"]`` as the
        monolithic prefill (the body only runs on a trace-cache miss)."""
        eng = self

        def step(params, toks, lens, offs, k_arena, v_arena, bt, plens,
                 pages, slots, src, seed, temps, conv_arena, ssm_arena,
                 srows, has_writes):
            eng.stats["prefill_jit_traces"] += 1
            fn = functools.partial(_fused_chunk_prefill_step, eng.cfg,
                                   eng.pcfg, has_writes=has_writes,
                                   **eng._step_kwargs())
            if eng.mesh is not None:
                fn = eng._shard_wrap(fn, 16, (4, 5))
            return fn(params, toks, lens, offs, k_arena, v_arena, bt,
                      plens, pages, slots, src, seed, temps, conv_arena,
                      ssm_arena, srows)

        donate = ((4, 5, 13, 14) if jax.default_backend() in ("tpu", "gpu")
                  else ())
        return jax.jit(step, donate_argnums=donate,
                       static_argnames=("has_writes",))

    def _build_fused_block_step(self):
        """One jit covering up to K decode rounds (``lax.while_loop``):
        K forwards + K masked KV scatters + K token selections, one host
        transfer.  K is baked into the plan arrays' trailing dim, so a
        fixed ``decode_block_rounds`` retraces only per (batch-bucket,
        table-width) pair like the single-round step; the closure's
        counter bump is exactly a retrace counter."""
        eng = self

        def step(params, last, steps, k_arena, v_arena, bt, lens, pages,
                 slots, eos, seed, temps, rowmap, conv_arena, ssm_arena,
                 srows):
            eng.stats["block_jit_traces"] += 1
            fn = functools.partial(_fused_block_step, eng.cfg, eng.pcfg,
                                   **eng._step_kwargs())
            if eng.mesh is not None:
                fn = eng._shard_wrap(fn, 16, (3, 4))
            return fn(params, last, steps, k_arena, v_arena, bt, lens,
                      pages, slots, eos, seed, temps, rowmap, conv_arena,
                      ssm_arena, srows)

        donate = ((3, 4, 13, 14) if jax.default_backend() in ("tpu", "gpu")
                  else ())
        return jax.jit(step, donate_argnums=donate)

    def _build_fused_mixed_step(self):
        """One jit covering a whole mixed round: chunk batch forward +
        chunk scatter + first-token selection, THEN the decode round —
        whose inputs for rows finishing their prompt this round come
        straight from the chunk half (``d_from_chunk``), never touching
        the host.  Retraces per distinct (chunk, decode) operand-shape
        pair; counted separately so the single-path counters stay
        comparable oracles."""
        eng = self

        def step(params, c_toks, c_lens, c_offs, k_arena, v_arena, c_bt,
                 c_plens, c_pages, c_slots, c_src, c_seed, c_temps,
                 d_last, d_bt, d_lens, d_pages, d_slots, d_seed, d_temps,
                 d_from_chunk, conv_arena, ssm_arena, c_srows, d_srows,
                 has_writes):
            eng.stats["mixed_jit_traces"] += 1
            fn = functools.partial(_fused_mixed_step, eng.cfg, eng.pcfg,
                                   has_writes=has_writes,
                                   **eng._step_kwargs())
            if eng.mesh is not None:
                fn = eng._shard_wrap(fn, 25, (4, 5), n_extra_out=2)
            return fn(params, c_toks, c_lens, c_offs, k_arena, v_arena,
                      c_bt, c_plens, c_pages, c_slots, c_src, c_seed,
                      c_temps, d_last, d_bt, d_lens, d_pages, d_slots,
                      d_seed, d_temps, d_from_chunk, conv_arena,
                      ssm_arena, c_srows, d_srows)

        donate = ((4, 5, 21, 22) if jax.default_backend() in ("tpu", "gpu")
                  else ())
        return jax.jit(step, donate_argnums=donate,
                       static_argnames=("has_writes",))

    def _prefill_round(self) -> int:
        """Drain the request queue: one fused jitted dispatch per
        (length-bucket) prefill batch, or the eager per-request oracle
        with ``fused_prefill=False`` (exact parity for greedy requests;
        sampled requests consume the TRNG per batch vs per request, so
        their streams differ by construction).  Returns the prompt
        tokens processed (the round's prefill work, for stall
        accounting)."""
        reqs, self.queue = self.queue, []
        toks = sum(len(r.prompt) for r in reqs)
        if not self.fused_prefill:
            for r in reqs:
                self._prefill(r)
            return toks
        # create every sequence in submission order first, so shared
        # prefixes (`share_with`) resolve across bucket groups; tokens=
        # lets the radix prefix cache longest-prefix-match each prompt
        # against every previously COMMITTED prompt (a batch submitted
        # together can't hit on itself — inserts happen at commit)
        for r in reqs:
            self.cache.create(r.req_id, len(r.prompt),
                              share_with=r.share_with,
                              shared_len=r.shared_len,
                              tokens=r.prompt)
        groups: Dict[int, List[Request]] = {}
        for r in reqs:
            groups.setdefault(_bucket_pow2(len(r.prompt)), []).append(r)
        for sp in sorted(groups):
            self._prefill_batch_fused(groups[sp], sp)
        return toks

    # ---------------- chunked prefill (decode-interleaved) ------------- #

    def _prefill_tick(self, allow_mixed: bool = False):
        """One round's bounded prefill work under the chunked scheduler:
        admit newly queued requests to the chunk backlog, then dispatch
        at most ONE fused chunk batch — FIFO over the backlog, rows
        sharing one chunk-length bucket, at most ``max_prefill_chunk``
        real prompt tokens.  Unfinished prompts return to the backlog
        front (their next chunk leads the next round), so a long prompt
        streams across rounds while the decode round keeps dispatching
        every round.

        With ``allow_mixed`` and decode rows present (active sequences,
        or prompts finishing this very chunk), the chunk batch and the
        round's decode fuse into ONE dispatch (``_mixed_round``).
        Returns ``(prompt_tokens_processed, decoded)`` — ``decoded``
        tells the caller this round's decode already ran."""
        self._admit_queue()
        batch, sc = self._select_chunk_batch()
        if not batch:
            return 0, False
        toks = sum(clen for _, clen in batch)
        if allow_mixed:
            fin = {st.req.req_id for st, clen in batch
                   if st.off + clen >= len(st.req.prompt)
                   and st.req.max_new_tokens > 1}
            d_rids = sorted(set(self.active) | fin)
            if d_rids:
                unfinished = self._mixed_round(batch, sc, d_rids)
                self._chunk_q = unfinished + self._chunk_q
                return toks, True
        unfinished = self._prefill_chunk_batch_fused(batch, sc)
        self._chunk_q = unfinished + self._chunk_q
        return toks, False

    def _select_chunk_batch(self):
        """Pick this round's chunk batch off the backlog: FIFO, one
        chunk-length bucket, within the round's token budget; states
        passed over (bucket mismatch, budget, unmet share dependency)
        stay queued in order.  Returns ``(batch, sc)`` — (state, len)
        pairs and their shared length bucket."""
        if not self._chunk_q:
            return [], None
        budget = self.max_prefill_chunk
        batch: List[tuple] = []          # (_ChunkPrefill, chunk_len)
        keep: List[_ChunkPrefill] = []
        sc = None                        # the batch's chunk-length bucket
        for st in self._chunk_q:
            if st.dep is not None:
                if not self._source_committed(st.dep, st.dep_len):
                    keep.append(st)      # shared pages not yet committed
                    continue
                st.dep = None            # satisfied once = satisfied forever
            clen = min(self.max_prefill_chunk, st.remaining)
            cb = _bucket_pow2(clen)
            if batch and (cb != sc or clen > budget):
                keep.append(st)
                continue
            sc = cb
            batch.append((st, clen))
            budget -= clen
        self._chunk_q = keep
        return batch, sc

    def _source_committed(self, src_id: Optional[int], n: int) -> bool:
        """Has sequence ``src_id`` committed at least ``n`` prompt
        tokens to the arena?  True when it is not mid-prefill (finished,
        or never chunked); sharers gate on this before reading shared
        pages."""
        if src_id is None:
            return True
        st = self._chunk_by_id.get(src_id)
        return st is None or st.off >= n

    def _admit_queue(self) -> None:
        """Create sequences for queued requests (submission order, so
        ``share_with`` resolves) and push them onto the chunk backlog.

        A prompt fully covered by a shared prefix has no KV of its own
        to commit: it becomes a single NO-WRITE chunk — the last prompt
        position recomputed against the committed prefix, scatter
        suppressed — gated until the source commits the whole prompt.
        That keeps even very long covered sharers inside the per-round
        chunk budget (a whole-prompt forward here would reintroduce the
        decode stall this scheduler exists to remove)."""
        reqs, self.queue = self.queue, []
        for r in reqs:
            seq = self.cache.create(r.req_id, len(r.prompt),
                                    share_with=r.share_with,
                                    shared_len=r.shared_len,
                                    tokens=r.prompt)
            off = seq.shared_prefix_pages * self.cache.page_size
            n = len(r.prompt)
            if off >= n:
                st = _ChunkPrefill(r, n - 1, dep=r.share_with, dep_len=n,
                                   write=False)
            else:
                st = _ChunkPrefill(r, off, dep=r.share_with, dep_len=off)
            self._chunk_q.append(st)
            self._chunk_by_id[r.req_id] = st

    def _chunk_operands(self, batch: List[tuple], sc: int) -> dict:
        """Assemble a chunk batch's device operands + scatter plan
        (shared by the standalone chunk dispatch and the mixed round,
        which must plan AFTER reserving decode tails so CoW retargets
        are seen).  Pad rows duplicate row 0; pad scatter entries
        duplicate entry 0 (identical (page, slot, value) writes are a
        deterministic no-op); an all-no-write batch skips the scatter
        entirely (``has_writes=False``, its own trace)."""
        B = len(batch)
        Bp = _bucket_pow2(B)
        idx = list(range(B)) + [0] * (Bp - B)   # pad rows duplicate row 0
        toks = np.zeros((Bp, sc), np.int32)
        lens = np.zeros((Bp,), np.int32)
        offs = np.zeros((Bp,), np.int32)
        temps = np.zeros((Bp,), np.float32)
        for row, i in enumerate(idx):
            st, clen = batch[i]
            toks[row, :clen] = st.req.prompt[st.off:st.off + clen]
            lens[row] = clen
            offs[row] = st.off
            temps[row] = st.req.temperature
        # prefix block table over each sequence's FULL page list, valid
        # length = committed tokens: the width bucket is per-prompt
        # constant, so chunk count never forces a retrace
        rids = [batch[i][0].req.req_id for i in idx]
        bt, plens = self.cache.block_table(rids,
                                           lengths=[int(o) for o in offs])
        pages: List[int] = []
        slots: List[int] = []
        src: List[int] = []
        for i, (st, clen) in enumerate(batch):
            if not st.write:             # covered sharer: recompute only
                continue
            seq = self.cache.seqs[st.req.req_id]
            p_i, s_i = self.cache.prefill_scatter_plan(seq, start=st.off,
                                                       stop=st.off + clen)
            pages += p_i
            slots += s_i
            src += [i * sc + j for j in range(clen)]
        n_valid = len(pages)
        N = Bp * sc
        if n_valid:
            pages += [pages[0]] * (N - n_valid)
            slots += [slots[0]] * (N - n_valid)
            src += [src[0]] * (N - n_valid)
        else:
            pages = [0] * N
            slots = [0] * N
            src = [0] * N
        return {
            "toks": jnp.asarray(toks), "lens": jnp.asarray(lens),
            "offs": jnp.asarray(offs), "bt": bt, "plens": plens,
            "pages": jnp.asarray(pages, jnp.int32),
            "slots": jnp.asarray(slots, jnp.int32),
            "src": jnp.asarray(src, jnp.int32),
            "temps": jnp.asarray(temps),
            "plan_pages": pages[:n_valid], "plan_slots": slots[:n_valid],
            "n_valid": n_valid, "rids": rids,
        }

    def _finish_chunks(self, batch: List[tuple],
                       tokens) -> List[_ChunkPrefill]:
        """Advance chunk offsets; rows whose chunk completed the prompt
        consume their first token (one lazy host transfer per batch) and
        join the active set.  Returns the still-unfinished states."""
        toks_np = None
        unfinished: List[_ChunkPrefill] = []
        for i, (st, clen) in enumerate(batch):
            st.off += clen
            if st.remaining <= 0:
                if toks_np is None:         # the batch's one host transfer
                    toks_np = np.asarray(tokens)
                st.req.out_tokens.append(int(toks_np[i]))
                self.active[st.req.req_id] = st.req
                self.stats["prefills"] += 1
                del self._chunk_by_id[st.req.req_id]
                # the prompt's full pages now hold real KV: index them
                self.cache.commit_prefix(st.req.req_id, st.req.prompt)
            else:
                unfinished.append(st)
        return unfinished

    def _prefill_chunk_batch_fused(self, batch: List[tuple],
                                   sc: int) -> List[_ChunkPrefill]:
        """One compiled dispatch for a same-bucket batch of prefill
        chunks: length-masked chunk forward with prefix-KV flash
        attention over each sequence's committed arena pages (gathered
        in-scan via the block table), in-jit chunk-KV scatter against
        the cache's per-chunk plan, in-jit token selection.  One host
        transfer per batch, consumed only by rows whose chunk completes
        the prompt.  Returns the still-unfinished chunk states."""
        # the step READS the arena (prefix gather): any pending backlog
        # must land first
        self.cache.flush_pending()
        c = self._chunk_operands(batch, sc)
        srows, conv, ssm = self._state_operands(c["rids"])
        self.rng_ctr += 1
        seed = self.rng_seed + jnp.uint32(self.rng_ctr)
        tokens, k_arena, v_arena, conv_a, ssm_a = self._chunk_step(
            self.params, c["toks"], c["lens"], c["offs"],
            self.cache.k_arena, self.cache.v_arena, c["bt"], c["plens"],
            c["pages"], c["slots"], c["src"], seed, c["temps"],
            conv, ssm, srows,
            has_writes=c["n_valid"] > 0 and self._has_attn)
        # chunk scatters account as the fused_prefill kind, same as the
        # monolithic batch (PimOpQueue.launches_by_kind, trace kv_writes)
        kv_plan = (c["plan_pages"], c["plan_slots"]) if self._has_attn \
            else ([], [])
        self.cache.commit_fused_prefill(k_arena, v_arena, *kv_plan)
        if self._has_ssm:
            self.cache.state.adopt(conv_a, ssm_a)
            self.cache.state.record_fused_write(
                [st.req.req_id for st, _ in batch])
        self.stats["prefill_chunks"] += len(batch)
        self.stats["fused_prefill_dispatches"] += 1
        return self._finish_chunks(batch, tokens)

    def _mixed_round(self, batch: List[tuple], sc: int,
                     d_rids: List[int]) -> List[_ChunkPrefill]:
        """ONE compiled dispatch for a whole mixed round: the chunk
        batch AND the decode round (which today's sequential path pays
        two dispatches for).  The decode half covers every active
        sequence plus every prompt finishing in this very chunk batch —
        their first token never touches the host; ``d_from_chunk`` wires
        it from the chunk half's selection into the decode input in-jit.

        Bookkeeping: both commits run with ``kind=None`` and the round
        is accounted as ONE ``fused_mixed`` launch; the rng counter
        advances twice (chunk seed, then decode seed), matching the
        sequential two-dispatch schedule, so sampled streams are
        unchanged by the fusion.  A finishing row whose FIRST token
        turns out to be its EOS has its decode token discarded host-side
        (the speculative KV write beyond its committed length dies with
        the sequence's pages — ``free`` zeroes them).  Returns the
        still-unfinished chunk states."""
        fin = {st.req.req_id: st.req for st, clen in batch
               if st.off + clen >= len(st.req.prompt)}
        reqmap = dict(self.active)
        reqmap.update(fin)
        # reserve every decode row's tail BEFORE planning the chunk
        # scatter: a CoW retarget must be seen by the plan, and the
        # coalesced copies must land before the step reads the arena
        self._reserve_tails(d_rids)
        self._reserved_tails.clear()
        self.cache.flush_pending()
        c = self._chunk_operands(batch, sc)
        row_of = {st.req.req_id: i for i, (st, _) in enumerate(batch)}
        B = len(d_rids)
        Bp = _bucket_pow2(B)
        idx = list(range(B)) + [0] * (Bp - B)   # pad rows duplicate row 0
        seqs = [self.cache.seqs[d_rids[i]] for i in idx]
        d_last = np.zeros((Bp,), np.int32)
        d_from = np.full((Bp,), -1, np.int32)
        d_temps = np.zeros((Bp,), np.float32)
        for row, i in enumerate(idx):
            rid = d_rids[i]
            r = reqmap[rid]
            d_temps[row] = r.temperature
            if rid in fin:               # token arrives in-jit
                d_from[row] = row_of[rid]
            else:
                d_last[row] = r.out_tokens[-1]
        d_pages = np.asarray([s.pages[-1] for s in seqs], np.int32)
        d_slots = np.asarray([s.length % self.cache.page_size
                              for s in seqs], np.int32)
        d_bt, d_lens = self.cache.block_table([d_rids[i] for i in idx])
        c_srows, conv, ssm = self._state_operands(c["rids"])
        d_srows, _, _ = self._state_operands([d_rids[i] for i in idx])
        self.rng_ctr += 1
        c_seed = self.rng_seed + jnp.uint32(self.rng_ctr)
        self.rng_ctr += 1
        d_seed = self.rng_seed + jnp.uint32(self.rng_ctr)
        c_tokens, d_tokens, k_arena, v_arena, conv_a, ssm_a = \
            self._mixed_step(
                self.params, c["toks"], c["lens"], c["offs"],
                self.cache.k_arena, self.cache.v_arena, c["bt"],
                c["plens"], c["pages"], c["slots"], c["src"], c_seed,
                c["temps"], jnp.asarray(d_last), d_bt, d_lens,
                jnp.asarray(d_pages), jnp.asarray(d_slots), d_seed,
                jnp.asarray(d_temps), jnp.asarray(d_from), conv, ssm,
                c_srows, d_srows,
                has_writes=c["n_valid"] > 0 and self._has_attn)
        kv_plan = (c["plan_pages"], c["plan_slots"]) if self._has_attn \
            else ([], [])
        self.cache.commit_fused_prefill(k_arena, v_arena, *kv_plan,
                                        kind=None)
        self.cache.commit_fused_round(d_rids, k_arena, v_arena, kind=None,
                                      wrote_kv=self._has_attn)
        if self._has_ssm:
            self.cache.state.adopt(conv_a, ssm_a)
            # trace both halves' state writes: the chunk rows' prefill
            # state and the decode rows' round state (one fused launch)
            self.cache.state.record_fused_write(
                [st.req.req_id for st, _ in batch])
            self.cache.state.record_fused_write(d_rids)
        # the whole round — chunk scatter included — was ONE launch
        self.cache.queue.count_external("fused_mixed")
        self.stats["prefill_chunks"] += len(batch)
        self.stats["mixed_dispatches"] += 1
        unfinished = self._finish_chunks(batch, c_tokens)
        d_toks = np.asarray(d_tokens)[:B]
        emitted = 0
        for i, rid in enumerate(d_rids):
            r = reqmap[rid]
            if (rid in fin and r.eos_token_id is not None
                    and r.out_tokens[-1] == r.eos_token_id):
                continue       # first token was EOS: decode token is dead
            r.out_tokens.append(int(d_toks[i]))
            emitted += 1
        self.stats["decode_rounds"] += 1
        self.stats["tokens_out"] += emitted
        return unfinished

    def _prefill_batch_fused(self, reqs: List[Request], sp: int) -> None:
        """One compiled dispatch for a same-length-bucket prefill batch;
        one host transfer (the batch's first tokens)."""
        B = len(reqs)
        Bp = _bucket_pow2(B)
        idx = list(range(B)) + [0] * (Bp - B)   # pad rows duplicate req 0
        toks = np.zeros((Bp, sp), np.int32)
        lens = np.zeros((Bp,), np.int32)
        temps = np.zeros((Bp,), np.float32)
        for row, i in enumerate(idx):
            r = reqs[i]
            toks[row, :len(r.prompt)] = r.prompt
            lens[row] = len(r.prompt)
            temps[row] = r.temperature
        # host-side arena-destination plan: (page, slot) per prompt token
        # the batch must write, plus the flat (row*sp + pos) source index
        # into the forward's stacked K/V output
        pages: List[int] = []
        slots: List[int] = []
        src: List[int] = []
        for i, r in enumerate(reqs):
            seq = self.cache.seqs[r.req_id]
            start = seq.shared_prefix_pages * self.cache.page_size
            p_i, s_i = self.cache.prefill_scatter_plan(seq, start=start)
            pages += p_i
            slots += s_i
            src += [i * sp + pos for pos in range(start, seq.length)]
        n_valid = len(pages)
        N = Bp * sp
        if n_valid:
            # pad entries duplicate entry 0: identical (page, slot,
            # value) writes are a deterministic no-op — the same trick
            # the decode round plays with pad rows
            pages += [pages[0]] * (N - n_valid)
            slots += [slots[0]] * (N - n_valid)
            src += [src[0]] * (N - n_valid)
        else:
            # batch fully covered by shared prefixes: nothing to write —
            # has_writes=False skips the scatter inside the jit (its own
            # trace, but the normal path never pays a no-op gather)
            pages = [0] * N
            slots = [0] * N
            src = [0] * N
        # the step reads the arena (shared-prefix gathers) — any backlog
        # (e.g. prefix-cache eviction inits from create-time pressure)
        # must land first
        self.cache.flush_pending()
        srows, conv, ssm = self._state_operands(
            [reqs[i].req_id for i in idx])
        self.rng_ctr += 1
        seed = self.rng_seed + jnp.uint32(self.rng_ctr)
        tokens, k_arena, v_arena, conv_a, ssm_a = self._prefill_step(
            self.params, jnp.asarray(toks), jnp.asarray(lens),
            self.cache.k_arena, self.cache.v_arena,
            jnp.asarray(pages, jnp.int32), jnp.asarray(slots, jnp.int32),
            jnp.asarray(src, jnp.int32), seed, jnp.asarray(temps),
            conv, ssm, srows, has_writes=n_valid > 0 and self._has_attn)
        kv_plan = (pages[:n_valid], slots[:n_valid]) if self._has_attn \
            else ([], [])
        self.cache.commit_fused_prefill(k_arena, v_arena, *kv_plan)
        if self._has_ssm:
            self.cache.state.adopt(conv_a, ssm_a)
            self.cache.state.record_fused_write([r.req_id for r in reqs])
        toks_np = np.asarray(tokens)[:B]    # the batch's one host transfer
        for i, r in enumerate(reqs):
            r.out_tokens.append(int(toks_np[i]))
            self.active[r.req_id] = r
            self.stats["prefills"] += 1
            self.cache.commit_prefix(r.req_id, r.prompt)
        self.stats["fused_prefill_dispatches"] += 1

    def _prefill(self, req: Request) -> None:
        """Eager per-request prefill — the fused path's parity oracle:
        un-jitted dense ``T.forward`` (a fresh XLA trace per distinct
        prompt length) plus host-side coalesced KV writes."""
        cfg, p = self.cfg, self.params
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        seq = self.cache.create(req.req_id, len(req.prompt),
                                share_with=req.share_with,
                                shared_len=req.shared_len,
                                tokens=req.prompt)
        start = seq.shared_prefix_pages * self.cache.page_size
        # full prefill forward (dense prefill math), then write kv pages
        max_len = len(req.prompt)
        cache = T.init_cache(cfg, 1, max_len)
        logits, dense_cache, _ = T.forward(
            cfg, self.pcfg, p, {"tokens": toks}, mode="prefill", cache=cache,
            lengths=jnp.asarray([max_len], jnp.int32))
        g = dense_cache["group0"]
        # g: {i_attn: (k,v)} stacked (L, 1, S, kvh, hd) for attention
        # sublayers; {i_mamba: (conv, ssm)} final recurrent state for
        # SSM sublayers ((G, 1, W-1, ch) / (G, 1, h, p, n))
        for key in (k for k in g if k.endswith("_attn")):
            k, v = g[key]
            self.cache.write_prompt_kv(seq, k[:, 0][:, start:max_len],
                                       v[:, 0][:, start:max_len], start=start)
        mamba_keys = sorted((k for k in g if k.endswith("_mamba")),
                            key=lambda s: int(s.split("_")[0]))
        if mamba_keys:
            conv = jnp.stack([g[k][0] for k in mamba_keys], axis=1)
            ssm = jnp.stack([g[k][1] for k in mamba_keys], axis=1)
            st = self.cache.state
            self.cache.queue.count_external(
                "eager_ssm_layer", st.conv.shape[0] * st.conv.shape[1])
            st.write([req.req_id], conv, ssm)
        tok = self._sample(logits[:, -1], req.temperature)
        req.out_tokens.append(int(tok[0]))
        self.active[req.req_id] = req
        self.stats["prefills"] += 1
        self.cache.commit_prefix(req.req_id, req.prompt)

    def _state_operands(self, rids_padded: List[int]):
        """The fused steps' state-arena operands for a (padded) row
        list: (srows, conv, ssm) — or three ``None``s on a dense engine
        (None is an empty pytree, so it threads through jit, donation,
        scan xs, and shard_map specs with zero leaves)."""
        if not self._has_ssm:
            return None, None, None
        st = self.cache.state
        srows = jnp.asarray(st.rows_for(rids_padded), jnp.int32)
        return srows, st.conv, st.ssm

    def _reserve_tails(self, rids: List[int]) -> None:
        """Reserve the incoming token's slot on every sequence in
        ``rids`` exactly once per round (CoW-copies shared tails,
        allocates boundary pages); idempotent within a round so the
        pre-prefill overlap path and the decode round compose."""
        for r in rids:
            if r not in self._reserved_tails:
                self.cache.ensure_writable_tail(self.cache.seqs[r])
                self._reserved_tails.add(r)

    def _decode_round(self) -> None:
        if not self.active:
            return
        rids = sorted(self.active)
        # reserve the slot for the incoming token on every sequence; the
        # CoW copies all land in ONE batched launch before attention reads
        # the arena (constant dispatch count, however many sequences fork)
        self._reserve_tails(rids)
        self._reserved_tails.clear()
        self.cache.flush_pending()
        if self.fused:
            toks = self._decode_round_fused(rids)
        else:
            toks = self._decode_round_eager(rids)
        for i, r in enumerate(rids):
            self.active[r].out_tokens.append(int(toks[i]))
        self.stats["decode_rounds"] += 1
        self.stats["tokens_out"] += len(rids)

    def _decode_round_fused(self, rids: List[int]) -> np.ndarray:
        """One compiled dispatch for the whole round; one host transfer."""
        B = len(rids)
        Bp = _bucket_pow2(B)
        # batch bucketing: pad rows duplicate sequence 0 — the duplicate
        # attention is wasted compute, and the duplicate scatter writes
        # the *same* values to the *same* (page, slot), so it is a no-op
        idx = list(range(B)) + [0] * (Bp - B)
        seqs = [self.cache.seqs[rids[i]] for i in idx]
        last = np.asarray([[self.active[rids[i]].out_tokens[-1]]
                           for i in idx], np.int32)
        temps = np.asarray([self.active[rids[i]].temperature for i in idx],
                           np.float32)
        pages = np.asarray([s.pages[-1] for s in seqs], np.int32)
        slots = np.asarray([s.length % self.cache.page_size for s in seqs],
                           np.int32)
        bt, lens = self.cache.block_table([rids[i] for i in idx])
        srows, conv, ssm = self._state_operands([rids[i] for i in idx])
        self.rng_ctr += 1
        seed = self.rng_seed + jnp.uint32(self.rng_ctr)
        tokens, k_arena, v_arena, conv_a, ssm_a = self._step(
            self.params, jnp.asarray(last), self.cache.k_arena,
            self.cache.v_arena, bt, lens, jnp.asarray(pages),
            jnp.asarray(slots), seed, jnp.asarray(temps), conv, ssm,
            srows)
        self.cache.commit_fused_round(rids, k_arena, v_arena,
                                      wrote_kv=self._has_attn)
        if self._has_ssm:
            self.cache.state.adopt(conv_a, ssm_a)
            self.cache.state.record_fused_write(rids)
        # per-engine count: the queue's fused_decode counter is global
        # to the (possibly shared) lib, this one is this engine's own
        self.stats["fused_dispatches"] += 1
        return np.asarray(tokens)[:B]      # the round's one host transfer

    def _decode_block(self, max_allowed: int) -> int:
        """Up to ``decode_block_rounds`` decode rounds in ONE dispatch —
        the persistent ``lax.while_loop`` inner loop, entered only when
        no admissions are pending.  Returns the rounds actually consumed
        (the longest row's emitted-token count), never more than
        ``max_allowed``.

        Host side: reserve each row's whole token block up front
        (``reserve_tokens`` — CoW + page allocation, one coalesced
        flush), build a (row, round) -> (page, slot) plan over the
        reserved pages, dispatch, then read the block's ONE host
        transfer and replay the device's stop rule (-1 sentinel = row
        already stopped; EOS stops after its own round).  Device side:
        the loop carries lengths/last-token/alive flags; a stopped row's
        scatter writes its slot's current value back (structural no-op),
        so the arena is bit-identical to a round-at-a-time run.  Plan
        arrays are always K wide (budget-short rows clamp to their last
        reserved slot), so a fixed K never retraces on workload
        stragglers."""
        rids = sorted(self.active)
        K = self.decode_block_rounds
        steps = [min(max_allowed, K,
                     self.active[r].max_new_tokens
                     - len(self.active[r].out_tokens))
                 for r in rids]
        if max(steps) <= 1:
            self._decode_round()
            return 1
        for r, n in zip(rids, steps):
            self.cache.reserve_tokens(self.cache.seqs[r], n)
        self._reserved_tails.clear()
        self.cache.flush_pending()
        B = len(rids)
        Bp = _bucket_pow2(B)
        idx = list(range(B)) + [0] * (Bp - B)   # pad rows duplicate row 0
        ps = self.cache.page_size
        pages = np.zeros((Bp, K), np.int32)
        slots = np.zeros((Bp, K), np.int32)
        last = np.zeros((Bp,), np.int32)
        steps_arr = np.zeros((Bp,), np.int32)
        eos = np.full((Bp,), -1, np.int32)
        temps = np.zeros((Bp,), np.float32)
        for row, i in enumerate(idx):
            r = rids[i]
            req, seq, n = self.active[r], self.cache.seqs[r], steps[i]
            for t in range(K):
                pos = seq.length + min(t, n - 1)
                pages[row, t] = seq.pages[pos // ps]
                slots[row, t] = pos % ps
            last[row] = req.out_tokens[-1]
            steps_arr[row] = n
            if req.eos_token_id is not None:
                eos[row] = req.eos_token_id
            temps[row] = req.temperature
        # table spans the reserved pages (block_table covers the full
        # page list); lens stay the committed lengths — the loop carries
        # them forward round by round
        bt, lens = self.cache.block_table([rids[i] for i in idx])
        # K sequential rounds consume K seeds: pass round 0's, the loop
        # derives round t's by offset — the same stream a round-at-a-time
        # run would draw
        self.rng_ctr += K
        seed = self.rng_seed + jnp.uint32(self.rng_ctr - K + 1)
        srows, conv, ssm = self._state_operands([rids[i] for i in idx])
        tokens, k_arena, v_arena, conv_a, ssm_a = self._block_step(
            self.params, jnp.asarray(last), jnp.asarray(steps_arr),
            self.cache.k_arena, self.cache.v_arena, bt, lens,
            jnp.asarray(pages), jnp.asarray(slots), jnp.asarray(eos),
            seed, jnp.asarray(temps), jnp.asarray(idx, dtype=jnp.int32),
            conv, ssm, srows)
        toks_np = np.asarray(tokens)[:B]   # the block's ONE host transfer
        counts = []
        for i, r in enumerate(rids):
            req = self.active[r]
            n_i = 0
            for t in range(steps[i]):
                tok = int(toks_np[i, t])
                if tok < 0:                # device stopped this row earlier
                    break
                req.out_tokens.append(tok)
                n_i += 1
                if (req.eos_token_id is not None
                        and tok == req.eos_token_id):
                    break
            counts.append(n_i)
        consumed = max(counts)
        self.cache.commit_fused_block(rids, counts, k_arena, v_arena,
                                      rounds=consumed,
                                      wrote_kv=self._has_attn)
        if self._has_ssm:
            self.cache.state.adopt(conv_a, ssm_a)
            self.cache.state.record_fused_write(rids, rounds=consumed)
        self.stats["decode_rounds"] += consumed
        self.stats["tokens_out"] += sum(counts)
        self.stats["multi_round_blocks"] += 1
        return consumed

    def _decode_round_eager(self, rids: List[int]) -> np.ndarray:
        """Pre-fusion baseline: Python layer loop, separate scatter."""
        last = jnp.asarray([[self.active[r].out_tokens[-1]] for r in rids],
                           jnp.int32)
        bt, lens = self.cache.block_table(rids)
        srows, conv_arena, ssm_arena = self._state_operands(rids)
        logits, k_new, v_new, conv_new, ssm_new = _eager_decode_forward(
            self.cfg, self.pcfg, self.params, last, self.cache.k_arena,
            self.cache.v_arena, bt, lens, use_pallas=self.use_pallas,
            interpret=self.interpret, conv_arena=conv_arena,
            ssm_arena=ssm_arena, srows=srows)
        if self._has_attn:
            # account the per-layer jitted paged-attention dispatches
            # (the O(num_layers) launches fusion removes) so
            # fused-vs-eager dispatch comparisons measure the real gap
            self.cache.queue.count_external("eager_attn_layer",
                                            self.cache.n_layers)
            # scatter the whole round's new KV (all layers, all
            # sequences) in one coalesced launch per arena
            self.cache.write_token_kv_batch(rids, k_new[:, :, 0],
                                            v_new[:, :, 0])
        else:
            # pure-SSM round: no KV write advances lengths, but the
            # block tables / reserved pages still track token count
            for r in rids:
                self.cache.seqs[r].length += 1
        if self._has_ssm:
            st = self.cache.state
            # per-layer eager SSM launches (what the fused scan removes)
            self.cache.queue.count_external(
                "eager_ssm_layer", st.conv.shape[0] * st.conv.shape[1])
            # ONE coalesced ssm_state_write flush for the whole round —
            # the SSM_STATE_WRITE opcode's JAX face, constant in depth
            # and batch
            st.write(rids, conv_new, ssm_new)
        temps = jnp.asarray([self.active[r].temperature for r in rids],
                            jnp.float32)
        self.rng_ctr += 1
        seed = self.rng_seed + jnp.uint32(self.rng_ctr)
        toks = _select_tokens(logits[:, 0], temps, seed,
                              use_pallas=self.use_pallas,
                              interpret=self.interpret)
        return np.asarray(toks)            # one host transfer

    def _sample(self, logits: jax.Array, temperature: float) -> np.ndarray:
        """Eager-prefill sampling: delegates to ``_select_tokens`` — the
        same helper the fused prefill/decode steps trace in-jit — so the
        greedy/inverse-CDF choice has exactly one implementation."""
        self.rng_ctr += 1
        seed = self.rng_seed + jnp.uint32(self.rng_ctr)
        temps = jnp.full((logits.shape[0],), temperature, jnp.float32)
        return np.asarray(_select_tokens(logits, temps, seed,
                                         use_pallas=self.use_pallas,
                                         interpret=self.interpret))


# ---------------------------------------------------------------------- #
# Fused decode step (traced under one jax.jit per engine)
# ---------------------------------------------------------------------- #


def _fused_decode_step(cfg, pcfg, params, last, k_arena, v_arena, bt, lens,
                       pages, slots, seed, temps, conv_arena=None,
                       ssm_arena=None, srows=None, *, use_pallas: bool,
                       interpret: bool, axis: Optional[str] = None,
                       compressed: bool = False):
    """Forward (scan over layers) + KV scatter + token selection: the
    whole decode round as one compiled program over donated arenas.
    SSM state scatters inside the forward's scan (zero extra launches);
    a pure-SSM round (no attn sublayer) skips the KV scatter entirely.
    With ``axis`` (inside shard_map) the forward is tensor-parallel and
    the scatter writes each shard's local head slice."""
    logits, k_new, v_new, conv_arena, ssm_arena = _paged_decode_forward(
        cfg, pcfg, params, last, k_arena, v_arena, bt, lens,
        use_pallas=use_pallas, interpret=interpret, axis=axis,
        compressed=compressed, conv_arena=conv_arena,
        ssm_arena=ssm_arena, srows=srows)
    if k_new is not None:
        k_arena = rc_ops.kv_scatter_inline(
            k_arena, pages, slots, k_new[:, :, 0].astype(k_arena.dtype),
            use_pallas=use_pallas, interpret=interpret)
        v_arena = rc_ops.kv_scatter_inline(
            v_arena, pages, slots, v_new[:, :, 0].astype(v_arena.dtype),
            use_pallas=use_pallas, interpret=interpret)
    tokens = _select_tokens(logits[:, 0], temps, seed,
                            use_pallas=use_pallas, interpret=interpret)
    return tokens, k_arena, v_arena, conv_arena, ssm_arena


# ---------------------------------------------------------------------- #
# Fused multi-round decode block (persistent lax.while_loop inner loop)
# ---------------------------------------------------------------------- #


def _fused_block_step(cfg, pcfg, params, last, steps, k_arena, v_arena, bt,
                      lens, pages, slots, eos, seed, temps, rowmap,
                      conv_arena=None, ssm_arena=None, srows=None, *,
                      use_pallas: bool, interpret: bool,
                      axis: Optional[str] = None, compressed: bool = False):
    """Up to K decode rounds as ONE compiled program: a ``while_loop``
    whose carry holds the per-row state a round-at-a-time host loop
    would bounce through Python — current lengths, last tokens, alive
    flags — plus the donated arenas.

    Per round ``t``: forward at the carried lengths, a MASKED KV scatter
    (dead rows re-write their slot's current value via
    ``kv_gather_inline``, keeping the scatter a structural no-op and the
    arena bit-identical to sequential rounds), token selection at
    ``seed + t`` (the seed a sequential round would draw), then the stop
    rule — a row dies when it has emitted its ``steps`` quota or its EOS
    token.  Emitted tokens land in a (B, K) buffer, ``-1`` marking
    rounds after a row stopped; the loop exits early once every row is
    dead, so an all-EOS round costs no further forwards.  ``rowmap``
    folds pad rows onto row 0's sampled draw so duplicate scatter
    destinations always carry identical values, sampled or greedy.
    SSM state arenas ride the carry; a dead row's in-scan state scatter
    writes its current value back (``alive`` masking inside
    :func:`_run_kinds`), the state analogue of the masked KV write-back.
    """
    K = pages.shape[1]

    def cond(carry):
        t, alive = carry[0], carry[1]
        return (t < K) & jnp.any(alive)

    def body(carry):
        (t, alive, lens, last, toks, k_arena, v_arena, conv_arena,
         ssm_arena) = carry
        logits, k_new, v_new, conv_arena, ssm_arena = _paged_decode_forward(
            cfg, pcfg, params, last[:, None], k_arena, v_arena, bt, lens,
            use_pallas=use_pallas, interpret=interpret, axis=axis,
            compressed=compressed, conv_arena=conv_arena,
            ssm_arena=ssm_arena, srows=srows, alive=alive)
        p_t = jax.lax.dynamic_index_in_dim(pages, t, axis=1, keepdims=False)
        s_t = jax.lax.dynamic_index_in_dim(slots, t, axis=1, keepdims=False)

        def masked_scatter(arena, new):
            old = rc_ops.kv_gather_inline(arena, p_t, s_t)
            val = jnp.where(alive[None, :, None, None],
                            new.astype(arena.dtype), old)
            return rc_ops.kv_scatter_inline(arena, p_t, s_t, val,
                                            use_pallas=use_pallas,
                                            interpret=interpret)

        if k_new is not None:
            k_arena = masked_scatter(k_arena, k_new[:, :, 0])
            v_arena = masked_scatter(v_arena, v_new[:, :, 0])
        raw = _select_tokens(logits[:, 0], temps,
                             seed + t.astype(jnp.uint32),
                             use_pallas=use_pallas, interpret=interpret,
                             rowmap=rowmap)
        toks = jax.lax.dynamic_update_slice(
            toks, jnp.where(alive, raw, -1)[:, None], (0, t))
        lens = lens + alive.astype(lens.dtype)
        last = jnp.where(alive, raw, last)
        hit_eos = alive & (eos >= 0) & (raw == eos)
        alive = alive & ((t + 1) < steps) & ~hit_eos
        return (t + 1, alive, lens, last, toks, k_arena, v_arena,
                conv_arena, ssm_arena)

    Bp = last.shape[0]
    carry = (jnp.int32(0), steps > 0, lens, last,
             jnp.full((Bp, K), -1, jnp.int32), k_arena, v_arena,
             conv_arena, ssm_arena)
    out = jax.lax.while_loop(cond, body, carry)
    _, _, _, _, toks, k_arena, v_arena, conv_arena, ssm_arena = out
    return toks, k_arena, v_arena, conv_arena, ssm_arena


# ---------------------------------------------------------------------- #
# Fused mixed round (one chunk batch + one decode round, one dispatch)
# ---------------------------------------------------------------------- #


def _fused_mixed_step(cfg, pcfg, params, c_toks, c_lens, c_offs, k_arena,
                      v_arena, c_bt, c_plens, c_pages, c_slots, c_src,
                      c_seed, c_temps, d_last, d_bt, d_lens, d_pages,
                      d_slots, d_seed, d_temps, d_from_chunk,
                      conv_arena=None, ssm_arena=None, c_srows=None,
                      d_srows=None, *, has_writes: bool, use_pallas: bool,
                      interpret: bool, axis: Optional[str] = None,
                      compressed: bool = False):
    """A whole mixed round as one compiled program: the chunk half runs
    first (its scatter is traced before the decode forward, so a prompt
    finishing this round decodes against its own just-written KV — the
    data dependency that makes XLA sequence the halves correctly on
    donated arenas), then the decode half, whose input token for rows
    with ``d_from_chunk[j] >= 0`` comes from the chunk half's selection
    instead of the host-supplied ``d_last``.  The state arenas thread
    chunk half -> decode half the same way: a prompt finishing this
    round decodes from its own just-scattered recurrent state."""
    c_tokens, k_arena, v_arena, conv_arena, ssm_arena = \
        _fused_chunk_prefill_step(
            cfg, pcfg, params, c_toks, c_lens, c_offs, k_arena, v_arena,
            c_bt, c_plens, c_pages, c_slots, c_src, c_seed, c_temps,
            conv_arena, ssm_arena, c_srows, has_writes=has_writes,
            use_pallas=use_pallas, interpret=interpret, axis=axis,
            compressed=compressed)
    last = jnp.where(d_from_chunk >= 0,
                     c_tokens[jnp.clip(d_from_chunk, 0, None)], d_last)
    d_tokens, k_arena, v_arena, conv_arena, ssm_arena = _fused_decode_step(
        cfg, pcfg, params, last[:, None], k_arena, v_arena, d_bt, d_lens,
        d_pages, d_slots, d_seed, d_temps, conv_arena, ssm_arena, d_srows,
        use_pallas=use_pallas, interpret=interpret, axis=axis,
        compressed=compressed)
    return c_tokens, d_tokens, k_arena, v_arena, conv_arena, ssm_arena


# ---------------------------------------------------------------------- #
# Fused bucketed prefill step (traced once per (length, batch) bucket)
# ---------------------------------------------------------------------- #


def _fused_prefill_step(cfg, pcfg, params, toks, lens, k_arena, v_arena,
                        pages, slots, src, seed, temps, conv_arena=None,
                        ssm_arena=None, srows=None, *,
                        has_writes: bool, use_pallas: bool,
                        interpret: bool, axis: Optional[str] = None,
                        compressed: bool = False):
    """Masked prefill forward + in-jit KV scatter + first-token
    selection: a whole prefill batch as one compiled program over
    donated arenas.

    ``pages``/``slots``/``src`` are the host-side scatter plan (length
    ``B*S`` flat entries): entry ``n`` writes the forward's stacked K/V
    at flat source index ``src[n]`` to ``arena[:, pages[n], slots[n]]``
    (pad entries duplicate entry 0 — identical writes, a deterministic
    no-op).  ``has_writes=False`` (static: the all-shared-prefix batch,
    or a pure-SSM engine with no KV to write) skips the scatter
    entirely; SSM state scatters inside the forward's scan.
    """
    logits, k_all, v_all, conv_arena, ssm_arena = _prefill_forward(
        cfg, pcfg, params, toks, lens, use_pallas=use_pallas,
        interpret=interpret, axis=axis, compressed=compressed,
        conv_arena=conv_arena, ssm_arena=ssm_arena, srows=srows)
    Bp, Sp = toks.shape

    def scatter(arena, new_all):
        L = new_all.shape[0]
        flat = new_all.reshape((L, Bp * Sp) + new_all.shape[3:])[:, src]
        return rc_ops.kv_scatter_inline(arena, pages, slots,
                                        flat.astype(arena.dtype),
                                        use_pallas=use_pallas,
                                        interpret=interpret)

    if has_writes and k_all is not None:
        k_arena = scatter(k_arena, k_all)
        v_arena = scatter(v_arena, v_all)
    tokens = _select_tokens(logits, temps, seed, use_pallas=use_pallas,
                            interpret=interpret)
    return tokens, k_arena, v_arena, conv_arena, ssm_arena


def _fused_chunk_prefill_step(cfg, pcfg, params, toks, lens, offs, k_arena,
                              v_arena, bt, plens, pages, slots, src, seed,
                              temps, conv_arena=None, ssm_arena=None,
                              srows=None, *, has_writes: bool,
                              use_pallas: bool, interpret: bool,
                              axis: Optional[str] = None,
                              compressed: bool = False):
    """Chunk forward (prefix-KV attention over committed arena pages) +
    in-jit chunk-KV scatter + token selection: one prefill chunk batch
    as one compiled program over donated arenas.

    ``pages``/``slots``/``src`` are the chunk scatter plan, exactly as
    in :func:`_fused_prefill_step`; ``offs`` (B,) are the chunks'
    absolute position offsets (RoPE), ``bt``/``plens`` the prefix block
    tables and committed lengths.  ``has_writes=False`` (static: a batch
    of only no-write covered-sharer chunks) skips the scatter.  The
    scatter is traced *after* the forward's arena reads, so XLA orders
    the prefix gather before the in-place update on donated buffers.
    """
    logits, k_all, v_all, conv_arena, ssm_arena = _chunk_prefill_forward(
        cfg, pcfg, params, toks, lens, offs, k_arena, v_arena, bt, plens,
        use_pallas=use_pallas, interpret=interpret, axis=axis,
        compressed=compressed, conv_arena=conv_arena,
        ssm_arena=ssm_arena, srows=srows)
    Bp, Sp = toks.shape

    def scatter(arena, new_all):
        L = new_all.shape[0]
        flat = new_all.reshape((L, Bp * Sp) + new_all.shape[3:])[:, src]
        return rc_ops.kv_scatter_inline(arena, pages, slots,
                                        flat.astype(arena.dtype),
                                        use_pallas=use_pallas,
                                        interpret=interpret)

    if has_writes and k_all is not None:
        k_arena = scatter(k_arena, k_all)
        v_arena = scatter(v_arena, v_all)
    tokens = _select_tokens(logits, temps, seed, use_pallas=use_pallas,
                            interpret=interpret)
    return tokens, k_arena, v_arena, conv_arena, ssm_arena


def _chunk_prefill_forward(cfg: ModelConfig, pcfg, params, toks, lens, offs,
                           k_arena, v_arena, bt, plens, *,
                           use_pallas: bool = False, interpret: bool = True,
                           axis: Optional[str] = None,
                           compressed: bool = False, conv_arena=None,
                           ssm_arena=None, srows=None):
    """Batched forward over one prefill *chunk* per row: ``lax.scan``
    over the stacked layer params AND the per-layer arena slices, with
    prefix-KV flash attention — each row's queries attend causally over
    the chunk and non-causally over the row's already-committed arena KV
    (gathered through its block table, masked at ``plens[b]`` so partial
    tail pages and table padding never leak).

    toks: (B, S) int32 chunk tokens; lens: (B,) valid chunk lengths
    (>= 1); offs: (B,) absolute position of each chunk's first token
    (drives RoPE); bt: (B, W) prefix block tables; plens: (B,) committed
    prefix lengths (0 = no prefix).  Returns (last-real-chunk-token
    logits (B, V), k_all, v_all (L, B, S, kvh, hd))."""
    hd = cfg.resolved_head_dim
    B, S = toks.shape
    ps = k_arena.shape[2]                # page size
    W = bt.shape[1]
    x = _embed_tokens(params["embed"], toks, cfg, axis)
    positions = offs[:, None] + jnp.broadcast_to(
        jnp.arange(S, dtype=jnp.int32), (B, S))
    sin, cos = rope_sincos(positions, hd, cfg.rope_theta)
    kinds = T.layer_groups(cfg)[0][1]
    has_attn = "attn" in kinds
    has_ssm = conv_arena is not None

    def body(x, xs):
        if has_ssm:
            p_layer, k_l, v_l, conv_l, ssm_l = xs
        else:
            p_layer, k_l, v_l = xs       # k_l: (pages, ps, kvh, hd)
            conv_l = ssm_l = None

        def attend(q, k, v):
            # gather this layer's committed prefix: (B, W*ps, kvh, hd)
            kp = k_l[bt].reshape(B, W * ps, k_l.shape[-2], k_l.shape[-1])
            vp = v_l[bt].reshape(B, W * ps, v_l.shape[-2], v_l.shape[-1])
            o = fa_ops.attention_inline(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=True, sm_scale=hd ** -0.5,
                lengths=lens, k_prefix=kp.transpose(0, 2, 1, 3),
                v_prefix=vp.transpose(0, 2, 1, 3), prefix_lengths=plens,
                use_pallas=use_pallas, interpret=interpret)
            return o.transpose(0, 2, 1, 3)

        x, kv, conv_l, ssm_l = _run_kinds(
            cfg, pcfg, kinds, p_layer, x, sin, cos, attend, conv_l,
            ssm_l, srows, lens=lens, axis=axis)
        ys = ()
        if has_attn:
            ys += (kv,)
        if has_ssm:
            ys += ((conv_l, ssm_l),)
        return x, ys

    xs = (params["group0"], k_arena, v_arena)
    if has_ssm:
        xs += (conv_arena, ssm_arena)
    x, ys = jax.lax.scan(body, x, xs)
    k_all = v_all = conv_out = ssm_out = None
    if has_attn:
        k_all, v_all = ys[0]
    if has_ssm:
        conv_out, ssm_out = ys[-1]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    # each row's last REAL chunk token (pad rows mirror row 0, lens >= 1)
    x_last = jnp.take_along_axis(
        x, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)
    logits = _logits_reduce(params["embed"], x_last, cfg, axis, compressed,
                            fp32=pcfg.logits_fp32)
    return logits[:, 0], k_all, v_all, conv_out, ssm_out


def _prefill_forward(cfg: ModelConfig, pcfg, params, toks, lens, *,
                     use_pallas: bool = False, interpret: bool = True,
                     axis: Optional[str] = None, compressed: bool = False,
                     conv_arena=None, ssm_arena=None, srows=None):
    """Batched prefill forward over a length-padded prompt batch:
    ``lax.scan`` over the stacked layer params (O(1) program size in
    depth) with causal + per-sequence-length masked flash attention —
    padded positions are never attended and their K/V never leave the
    step (the scatter plan only sources real tokens).  SSM sublayers
    run the length-masked paged scan from the rows' (freshly
    allocated, zero) arena state — pad positions carry state through
    unchanged, so the masked batch is bit-identical per row to a solo
    forward.

    toks: (B, S) int32 padded prompts; lens: (B,) valid lengths (>= 1).
    Returns (last-real-token logits (B, V), k_all, v_all
    (L, B, S, kvh, hd) | None, conv_arena, ssm_arena | None).
    """
    hd = cfg.resolved_head_dim
    B, S = toks.shape
    x = _embed_tokens(params["embed"], toks, cfg, axis)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    sin, cos = rope_sincos(positions, hd, cfg.rope_theta)
    kinds = T.layer_groups(cfg)[0][1]
    has_attn = "attn" in kinds
    has_ssm = conv_arena is not None

    def attend(q, k, v):
        # (B, S, h, hd) <-> the kernel's (B, h, S, hd) layout
        o = fa_ops.attention_inline(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, sm_scale=hd ** -0.5,
            lengths=lens, use_pallas=use_pallas, interpret=interpret)
        return o.transpose(0, 2, 1, 3)

    def body(x, xs):
        if has_ssm:
            p_layer, conv_l, ssm_l = xs
        else:
            p_layer = xs
            conv_l = ssm_l = None
        x, kv, conv_l, ssm_l = _run_kinds(
            cfg, pcfg, kinds, p_layer, x, sin, cos, attend, conv_l,
            ssm_l, srows, lens=lens, axis=axis)
        ys = ()
        if has_attn:
            ys += (kv,)
        if has_ssm:
            ys += ((conv_l, ssm_l),)
        return x, ys

    xs = ((params["group0"], conv_arena, ssm_arena) if has_ssm
          else params["group0"])
    x, ys = jax.lax.scan(body, x, xs)
    k_all = v_all = conv_out = ssm_out = None
    if has_attn:
        k_all, v_all = ys[0]
    if has_ssm:
        conv_out, ssm_out = ys[-1]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    # each row's last REAL token (pad rows mirror row 0, lens >= 1)
    x_last = jnp.take_along_axis(
        x, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)
    logits = _logits_reduce(params["embed"], x_last, cfg, axis, compressed,
                            fp32=pcfg.logits_fp32)
    return logits[:, 0], k_all, v_all, conv_out, ssm_out


def _select_tokens(logits: jax.Array, temps: jax.Array, seed: jax.Array, *,
                   use_pallas: bool, interpret: bool,
                   rowmap: Optional[jax.Array] = None) -> jax.Array:
    """Per-request token choice: greedy rows take the argmax, sampled
    rows take a D-RaNGe inverse-CDF draw at their own temperature.  An
    all-greedy batch skips the TRNG + softmax entirely (lax.cond), and
    nothing here syncs to host — callers do one transfer per round.

    ``rowmap`` (the K-block loop's pad-row fold) remaps each row's
    uniform draw to ``u[rowmap[b]]``: real rows map to themselves, pad
    rows to row 0 — so a pad row samples the *same* token as the row it
    duplicates and the loop's next-round scatter writes identical values
    to identical slots (the single-round steps don't need this because
    their scatter values never depend on the sampled token)."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def sampled(_):
        u = dr_ops.pim_random_uniform(seed, logits.shape[0], 1,
                                      use_pallas=use_pallas,
                                      interpret=interpret)[:, 0]
        if rowmap is not None:
            u = u[rowmap]
        t = jnp.where(temps > 0.0, temps, 1.0)
        probs = jax.nn.softmax(logits.astype(jnp.float32) / t[:, None], axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        drawn = jnp.argmax(cum > u[:, None], axis=-1).astype(jnp.int32)
        return jnp.where(temps == 0.0, greedy, drawn)

    return jax.lax.cond(jnp.all(temps == 0.0), lambda _: greedy, sampled,
                        operand=None)


def _embed_tokens(p, tokens, cfg, axis=None):
    """Token embedding, host-local or vocab-parallel.

    Inside shard_map each shard holds vocab rows
    ``[axis_index * V_local, (axis_index + 1) * V_local)``: the shard
    owning a token contributes its exact (cast) table row, every other
    shard contributes exact zeros, and the ``psum`` is therefore
    bit-identical to the host-local ``jnp.take`` — adding 0.0 to a
    float is exact."""
    if axis is None:
        return embed(p, tokens, cfg)
    vloc = p["tok"].shape[0]
    start = jax.lax.axis_index(axis).astype(jnp.int32) * vloc
    local = tokens - start
    ok = (local >= 0) & (local < vloc)
    x = cast(jnp.take(p["tok"], jnp.clip(local, 0, vloc - 1), axis=0))
    x = jnp.where(ok[..., None], x, jnp.zeros_like(x))
    x = jax.lax.psum(x, axis)
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _logits_reduce(p, x, cfg, axis=None, compressed=False, fp32=True):
    """Output logits, host-local or vocab-parallel.

    Sharded: each shard computes its local (B, S, V_local) slice — the
    contraction dim (d_model) is NOT sharded, so each output element is
    the same multiply-accumulate the host-local einsum performs — then
    places it at ``axis_index * V_local`` in a zeros(V) buffer and
    reduces.  Plain ``psum`` sums exact zeros into each element
    (bit-identical to host-local math); ``compressed=True`` routes the
    reduce through :func:`repro.distributed.compression.psum_compressed`
    (int8 wire traffic, one quantization in / one out — logits agree to
    quantization tolerance, and the replicated argmax still picks one
    token for all shards)."""
    if axis is None:
        return logits_out(p, x, cfg, fp32=fp32)
    table = p.get("out", p["tok"])
    out = jnp.einsum("bsd,vd->bsv", x, cast(table))
    if fp32:
        out = out.astype(jnp.float32)
    vloc = table.shape[0]
    world = _axis_size(axis)
    full = jnp.zeros(out.shape[:-1] + (vloc * world,), out.dtype)
    idx = (jnp.int32(0),) * (out.ndim - 1) + (
        jax.lax.axis_index(axis).astype(jnp.int32) * vloc,)
    full = jax.lax.dynamic_update_slice(full, out, idx)
    if compressed:
        return psum_compressed(full, axis)
    return jax.lax.psum(full, axis)


def _sublayer(cfg, kind, sp, x, sin, cos, attend, axis=None):
    """One decoder sublayer — the one source of truth shared by the
    fused decode scan, the eager decode loop, AND the fused prefill
    scan.  ``attend(q, k, v)`` supplies the attention dispatch over the
    full (b, s, h, hd) projections (decode callers attend one token
    against the arena, prefill callers run the length-masked flash
    kernel).  Returns (x, (k, v) | None) with k/v (b, s, kvh, hd).

    ``axis`` (inside shard_map): the weights are each shard's local
    slice — wq/wk/wv column-parallel over heads, wo and the MLP down
    projection row-parallel — so the only collectives a layer needs are
    the two residual-branch ``psum``s (Megatron-style TP).  The
    returned k/v are the shard's LOCAL kv-head slice: exactly what its
    arena shard stores."""
    h = rmsnorm(x, sp["norm"], cfg.norm_eps)
    if kind != "attn":
        y = mlp(sp["mlp"], h, cfg.activation)
        if axis is not None:
            y = jax.lax.psum(y, axis)
        return x + y, None
    q = jnp.einsum("bsd,dhk->bshk", h, cast(sp["attn"]["wq"]))
    k = jnp.einsum("bsd,dhk->bshk", h, cast(sp["attn"]["wk"]))
    v = jnp.einsum("bsd,dhk->bshk", h, cast(sp["attn"]["wv"]))
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    o = attend(q, k, v)
    out = jnp.einsum("bshk,hkd->bsd", o, cast(sp["attn"]["wo"]))
    if axis is not None:
        out = jax.lax.psum(out, axis)
    return x + out, (k, v)


def _run_kinds(cfg, pcfg, kinds, p_layer, x, sin, cos, attend, conv_l,
               ssm_l, srows, lens=None, axis=None, alive=None):
    """One scan step's sublayer sequence — the hybrid dispatch every
    fused forward AND the eager oracle follow in lockstep, so per-kind
    routing has exactly one implementation.

    ``attn``/``mlp`` route through :func:`_sublayer` unchanged.
    ``mamba`` sublayers gather their per-sequence recurrent state at
    ``srows`` from this step's state-arena slices ``conv_l``/``ssm_l``
    ((sublayers, slots, ...)), run decode (``lens is None``: one token)
    or the length-masked paged prefill scan, and scatter the fresh
    state back — pad rows duplicate row 0's inputs, so duplicate
    scatter destinations carry identical values and the ``.at[].set``
    stays deterministic.  ``alive`` (the K-block loop's row mask)
    freezes a dead row's state exactly as the masked KV scatter freezes
    its slot.  ``moe`` routes through the exact in-jit MoE (host-local
    engines always resolve to the dense fallback — per-token
    independent and jit-traceable, so fused stays bit-identical to
    eager); the router aux loss is a training artifact and is dropped.

    Returns (x, last-attn (k, v) | None, conv_l, ssm_l).
    """
    kv_out = None
    j = 0
    for i, kind in enumerate(kinds):
        sp = p_layer[f"{i}_{kind}"]
        if kind == "mamba":
            h = rmsnorm(x, sp["norm"], cfg.norm_eps)
            conv_j = conv_l[j][srows]
            ssm_j = ssm_l[j][srows]
            if lens is None:
                out, (nc, ns) = ssm_mod.ssm_layer(
                    cfg, pcfg, sp["ssm"], h, mode="decode",
                    cache=(conv_j, ssm_j))
            else:
                out, (nc, ns) = ssm_mod.ssm_layer_paged(
                    cfg, pcfg, sp["ssm"], h, lengths=lens,
                    conv_state=conv_j, ssm_state=ssm_j)
            x = x + out
            nc = nc.astype(conv_l.dtype)
            ns = ns.astype(ssm_l.dtype)
            if alive is not None:
                nc = jnp.where(alive[:, None, None], nc, conv_j)
                ns = jnp.where(alive[:, None, None, None], ns, ssm_j)
            conv_l = conv_l.at[j, srows].set(nc)
            ssm_l = ssm_l.at[j, srows].set(ns)
            j += 1
        elif kind == "moe":
            h = rmsnorm(x, sp["norm"], cfg.norm_eps)
            out, _aux = moe_mod.moe_layer(cfg, pcfg, sp["moe"], h)
            x = x + out
        else:
            x, kv = _sublayer(cfg, kind, sp, x, sin, cos, attend,
                              axis=axis)
            if kv is not None:
                kv_out = kv
    return x, kv_out, conv_l, ssm_l


def _paged_decode_forward(cfg: ModelConfig, pcfg, params, tokens, k_arena,
                          v_arena, block_tables, lengths, *,
                          use_pallas: bool = False, interpret: bool = True,
                          axis: Optional[str] = None,
                          compressed: bool = False, conv_arena=None,
                          ssm_arena=None, srows=None, alive=None):
    """Decoder forward for one token: ``lax.scan`` over the stacked
    layer params and the per-layer arena slices — O(1) program size in
    depth, and the current token's K/V merges inside the paged kernel.
    With SSM sublayers (``conv_arena`` set) the scan's xs extend with
    the per-step state-arena slices and the updated arenas ride out as
    stacked ys — still one scan, zero extra launches.

    With ``axis`` (inside shard_map) the params/arenas are each shard's
    local head slice and the activations are tensor-parallel (see
    :func:`_sublayer` / :func:`_logits_reduce`).

    Returns (logits (b,1,V), k_new, v_new (L, b, 1, kvh, hd) | None,
    conv_arena, ssm_arena | None).
    """
    hd = cfg.resolved_head_dim
    x = _embed_tokens(params["embed"], tokens, cfg, axis)
    positions = lengths[:, None].astype(jnp.int32)  # token pos == length
    sin, cos = rope_sincos(positions, hd, cfg.rope_theta)
    kinds = T.layer_groups(cfg)[0][1]
    has_attn = "attn" in kinds
    has_ssm = conv_arena is not None

    def body(x, xs):
        if has_ssm:
            p_layer, k_l, v_l, conv_l, ssm_l = xs
        else:
            p_layer, k_l, v_l = xs
            conv_l = ssm_l = None

        def attend(q, k, v):
            # one token against the arena pages, with the fresh K/V
            # (not yet written) merged in-kernel
            o = pa_ops.paged_attention_inline(
                q[:, 0], k_l, v_l, block_tables, lengths,
                sm_scale=hd ** -0.5, use_pallas=use_pallas,
                interpret=interpret, k_self=k[:, 0], v_self=v[:, 0])
            return o[:, None]

        x, kv, conv_l, ssm_l = _run_kinds(
            cfg, pcfg, kinds, p_layer, x, sin, cos, attend, conv_l,
            ssm_l, srows, axis=axis, alive=alive)
        ys = ()
        if has_attn:
            ys += ((kv[0][:, 0], kv[1][:, 0]),)
        if has_ssm:
            ys += ((conv_l, ssm_l),)
        return x, ys

    xs = (params["group0"], k_arena, v_arena)
    if has_ssm:
        xs += (conv_arena, ssm_arena)
    x, ys = jax.lax.scan(body, x, xs)
    k_news = v_news = conv_out = ssm_out = None
    if has_attn:
        k_news, v_news = ys[0]
    if has_ssm:
        conv_out, ssm_out = ys[-1]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits_reduce(params["embed"], x, cfg, axis, compressed)
    if has_attn:
        k_news, v_news = k_news[:, :, None], v_news[:, :, None]
    return logits, k_news, v_news, conv_out, ssm_out


def _eager_decode_forward(cfg: ModelConfig, pcfg, params, tokens, k_arena,
                          v_arena, block_tables, lengths, *,
                          use_pallas: bool = False, interpret: bool = True,
                          conv_arena=None, ssm_arena=None, srows=None):
    """Pre-fusion baseline: Python loop over layers, one jitted
    paged-attention dispatch per layer.  Shares ``_sublayer`` and the
    hybrid :func:`_run_kinds` dispatch with the fused path (the
    self-token merge still happens in-kernel — the old full-history
    re-reading merge pass is gone).  With SSM sublayers, returns the
    batch's fresh state VALUES (G, M, b, ...) — the engine writes them
    back through the op queue's ``ssm_state_write`` kind, the eager
    analogue of the fused path's in-jit scatter."""
    hd = cfg.resolved_head_dim
    x = embed(params["embed"], tokens, cfg)
    positions = lengths[:, None].astype(jnp.int32)  # token pos == length
    sin, cos = rope_sincos(positions, hd, cfg.rope_theta)
    gparams = params["group0"]
    L, kinds = T.layer_groups(cfg)[0]
    has_ssm = conv_arena is not None

    def layer_attend(k_l, v_l):
        def attend(q, k, v):
            o = pa_ops.paged_attention(
                q[:, 0], k_l, v_l, block_tables, lengths,
                sm_scale=hd ** -0.5, use_pallas=use_pallas,
                interpret=interpret, k_self=k[:, 0], v_self=v[:, 0])
            return o[:, None]
        return attend

    k_news, v_news = [], []
    conv_news, ssm_news = [], []
    for li in range(L):
        p_layer = jax.tree.map(lambda a: a[li], gparams)
        attend = layer_attend(k_arena[li], v_arena[li])
        conv_l = conv_arena[li] if has_ssm else None
        ssm_l = ssm_arena[li] if has_ssm else None
        x, kv, conv_l, ssm_l = _run_kinds(
            cfg, pcfg, kinds, p_layer, x, sin, cos, attend, conv_l,
            ssm_l, srows)
        if kv is not None:
            k_news.append(kv[0][:, 0][None])   # (1, b, kvh, hd)
            v_news.append(kv[1][:, 0][None])
        if has_ssm:
            # eager rids are unique (no pad rows), so gathering the
            # just-set rows back yields exactly the fresh values
            conv_news.append(conv_l[:, srows][None])   # (1, M, b, ...)
            ssm_news.append(ssm_l[:, srows][None])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_out(params["embed"], x, cfg)
    k_new = v_new = conv_new = ssm_new = None
    if k_news:
        k_new = jnp.concatenate(k_news, axis=0)[:, :, None]  # (L,b,1,kvh,hd)
        v_new = jnp.concatenate(v_news, axis=0)[:, :, None]
    if has_ssm:
        conv_new = jnp.concatenate(conv_news, axis=0)   # (G, M, b, ...)
        ssm_new = jnp.concatenate(ssm_news, axis=0)
    return logits, k_new, v_new, conv_new, ssm_new

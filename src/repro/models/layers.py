"""Common neural layers: RMSNorm, rotary embeddings, gated MLPs,
embeddings/logits — all sharding-annotated and bf16-compute.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from .params import ParamDef

COMPUTE_DTYPE = jnp.bfloat16


def cast(x: jax.Array) -> jax.Array:
    return x.astype(COMPUTE_DTYPE)


# ----------------------------- RMSNorm -------------------------------- #


def rmsnorm_def(d: int) -> ParamDef:
    return ParamDef((d,), ("embed",), init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    # Variance in fp32 (fused square+reduce), normalization applied in the
    # input dtype: avoids materializing an fp32 copy of the activations,
    # which would otherwise force fp32 storage of remat-saved layer inputs.
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = (jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


# ----------------------------- RoPE ----------------------------------- #


def rope_sincos(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (..., s) int32 -> sin/cos of shape (..., s, dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (b, s, h, d); sin/cos: (b, s, d//2) — GPT-NeoX half rotation."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    sin = sin[:, :, None, :].astype(x.dtype)
    cos = cos[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ----------------------------- MLP ------------------------------------ #


def mlp_defs(d: int, ff: int, activation: str) -> Dict[str, ParamDef]:
    defs = {
        "up": ParamDef((d, ff), ("embed", "ff")),
        "down": ParamDef((ff, d), ("ff", "embed")),
    }
    if activation in ("swiglu", "geglu"):
        defs["gate"] = ParamDef((d, ff), ("embed", "ff"))
    return defs


def mlp(p: Dict[str, jax.Array], x: jax.Array, activation: str) -> jax.Array:
    """x: (b, s, d) -> (b, s, d); hidden sharded over 'ff' (TP)."""
    up = jnp.einsum("bsd,df->bsf", x, cast(p["up"]))
    up = shard(up, "batch", None, "ff")
    if activation in ("swiglu", "geglu"):
        gate = jnp.einsum("bsd,df->bsf", x, cast(p["gate"]))
        act = jax.nn.silu(gate) if activation == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(up)
    out = jnp.einsum("bsf,fd->bsd", h, cast(p["down"]))
    return shard(out, "batch", None, None)


# ----------------------------- Embedding ------------------------------ #


def embed_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    defs = {"tok": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="normal")}
    if not cfg.tie_embeddings:
        defs["out"] = ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), init="normal")
    return defs


def embed(p: Dict[str, jax.Array], tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = cast(jnp.take(p["tok"], tokens, axis=0))
    if cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return shard(x, "batch", None, None)


def logits_out(p: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
               fp32: bool = True) -> jax.Array:
    table = p.get("out", p["tok"])
    out = jnp.einsum("bsd,vd->bsv", x, cast(table))
    out = shard(out, "batch", None, "vocab")
    return out.astype(jnp.float32) if fp32 else out

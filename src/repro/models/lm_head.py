"""Memory-efficient LM head: chunked fused softmax-cross-entropy.

The fp32 logits tensor of a 256 K-vocab model at 4 K x 16 per-device
tokens is ~4.2 GB; naive autodiff holds logits + softmax + dlogits
simultaneously (~12 GB/device).  This custom-VJP computes the loss by
scanning over sequence chunks (logits chunk is live only inside the
step) and the backward recomputes each chunk's logits, emitting dx and
accumulating dW — peak extra memory drops to one chunk (~0.5 GB).

Semantics: sum of per-token NLL over non-ignored labels and the count,
so the caller controls the mean.  Labels == IGNORE contribute zero.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

IGNORE = -100


def _chunk_ce(x_c, table, labels_c):
    """x_c: (b,c,d); table: (V,d); labels_c: (b,c) -> (nll_sum, cnt)."""
    logits = jnp.einsum("bcd,vd->bcv", x_c.astype(jnp.float32),
                        table.astype(jnp.float32))
    mask = labels_c != IGNORE
    safe = jnp.where(mask, labels_c, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = jnp.where(mask, logz - gold, 0.0)
    return nll.sum(), mask.sum()


def _fused_fwd_impl(x, table, labels, chunk):
    b, s, d = x.shape
    nc = max(s // chunk, 1)
    cs = s // nc
    xs = x[:, : nc * cs].reshape(b, nc, cs, d).transpose(1, 0, 2, 3)
    ls = labels[:, : nc * cs].reshape(b, nc, cs).transpose(1, 0, 2)

    def step(carry, inp):
        nll, cnt = carry
        x_c, l_c = inp
        n, c = _chunk_ce(x_c, table, l_c)
        return (nll + n, cnt + c), None

    (nll, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.int32)), (xs, ls))
    if nc * cs < s:  # remainder
        n, c = _chunk_ce(x[:, nc * cs:], table, labels[:, nc * cs:])
        nll, cnt = nll + n, cnt + c
    return nll, cnt


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_xent(x, table, labels, chunk=512):
    return _fused_fwd_impl(x, table, labels, chunk)


def _fwd(x, table, labels, chunk):
    out = _fused_fwd_impl(x, table, labels, chunk)
    return out, (x, table, labels)


def _bwd(chunk, res, ct):
    x, table, labels = res
    dnll, _ = ct
    b, s, d = x.shape
    nc = max(s // chunk, 1)
    cs = s // nc
    xs = x[:, : nc * cs].reshape(b, nc, cs, d).transpose(1, 0, 2, 3)
    ls = labels[:, : nc * cs].reshape(b, nc, cs).transpose(1, 0, 2)

    def grad_chunk(x_c, l_c):
        logits = jnp.einsum("bcd,vd->bcv", x_c.astype(jnp.float32),
                            table.astype(jnp.float32))
        mask = l_c != IGNORE
        safe = jnp.where(mask, l_c, 0)
        p = jax.nn.softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(safe, table.shape[0], dtype=jnp.float32)
        dlog = (p - onehot) * (mask[..., None] * dnll)
        dx_c = jnp.einsum("bcv,vd->bcd", dlog, table.astype(jnp.float32))
        dW_c = jnp.einsum("bcv,bcd->vd", dlog, x_c.astype(jnp.float32))
        return dx_c.astype(x.dtype), dW_c

    from repro.distributed.sharding import shard as _shard

    def step(dW, inp):
        x_c, l_c = inp
        dx_c, dW_c = grad_chunk(x_c, l_c)
        return _shard(dW + dW_c, "vocab", None), dx_c

    dW0 = _shard(jnp.zeros(table.shape, jnp.float32), "vocab", None)
    dW, dxs = jax.lax.scan(step, dW0, (xs, ls))
    dx = dxs.transpose(1, 0, 2, 3).reshape(b, nc * cs, d)
    if nc * cs < s:
        dx_r, dW_r = grad_chunk(x[:, nc * cs:], labels[:, nc * cs:])
        dx = jnp.concatenate([dx, dx_r], axis=1)
        dW = dW + dW_r
    return dx, dW.astype(table.dtype), np.zeros(labels.shape, jax.dtypes.float0)


fused_xent.defvjp(_fwd, _bwd)

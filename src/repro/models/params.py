"""Parameter descriptors: one definition drives init, sharding and shape
checking.

A model module builds a pytree of :class:`ParamDef` (shape + logical axes
+ initializer).  From that single tree we derive:

* ``init_params``  — materialized arrays (fp32 masters),
* ``param_specs``  — `PartitionSpec` tree (TP rules + FSDP), via
  `repro.distributed.sharding.resolve_spec`,
* analytic parameter counts (cross-checked against `ModelConfig.param_count`).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import resolve_spec


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    laxes: Tuple[Optional[str], ...]
    init: str = "fan_in"     # fan_in | normal | zeros | ones | small
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.laxes), (self.shape, self.laxes)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64))


def is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def stacked(defs: Any, n: int) -> Any:
    """Prepend a scan dim of length n to every ParamDef in a tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.laxes, d.init, d.scale),
        defs, is_leaf=is_def)


def _materialize(d: ParamDef, key: jax.Array, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        return (jax.random.normal(key, d.shape, jnp.float32) * (0.02 * d.scale)).astype(dtype)
    if d.init == "small":
        return (jax.random.normal(key, d.shape, jnp.float32) * (0.006 * d.scale)).astype(dtype)
    # fan_in: truncated-normal-ish scaled by 1/sqrt(fan_in); fan_in is the
    # second-to-last dim for stacked defs, first dim otherwise.
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[0]
    std = d.scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)


def init_params(defs: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    arrs = [_materialize(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def param_specs(defs: Any) -> Any:
    """PartitionSpec tree (requires an active sharding_env)."""
    return jax.tree.map(
        lambda d: resolve_spec(d.shape, d.laxes, fsdp_hint=True),
        defs, is_leaf=is_def)


def param_count(defs: Any) -> int:
    return sum(d.size for d in jax.tree.leaves(defs, is_leaf=is_def))


def abstract_params(defs: Any, dtype=jnp.float32) -> Any:
    """ShapeDtypeStruct tree (for dry-run lowering without allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)

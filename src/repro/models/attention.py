"""Attention: GQA/MQA, MLA (DeepSeek), cross-attention; chunked-flash
training path and cache-based decode path.

Implementation notes (see DESIGN.md SS4):

* **chunked attention** — an online-softmax pair-scan: the static tile
  list [(i, j) | tile j reachable from tile i] is scanned with running
  (m, l, acc) carried per q position.  No (sq, sk) score tensor is ever
  materialized, HLO stays O(1) in sequence length, causal tiles that
  cannot contribute are never enqueued, and the whole thing is
  reverse-differentiable (plain `lax.scan`).  This is the jnp twin of
  `repro.kernels.flash_attention` (which is the TPU hot-spot kernel,
  used on real hardware for inference).
* **decode** — single-token attention over a dense KV cache whose
  sequence axis is sharded over the `model` mesh axis (sequence
  parallelism).  Softmax statistics over the sharded axis become two
  small all-reduces (flash-decoding style), inserted by SPMD.
* **MLA** — training/prefill expand the latent to per-head k/v;
  decode runs in *absorbed* form: queries are pulled into the latent
  space, attention happens against the (tiny) compressed cache, and the
  context is up-projected once per token.  The cache stores only
  (c_kv, k_rope) — the property that makes MLA pages ~11x smaller in the
  PiM arena.
"""

from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.distributed.sharding import shard
from .layers import apply_rope, cast, rope_sincos
from .params import ParamDef

_NEG_INF = -1e30


# --------------------------------------------------------------------- #
# Parameter definitions
# --------------------------------------------------------------------- #


def attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.mla:
        m = cfg.mla
        defs = {
            "wq": ParamDef((d, h, m.nope_head_dim + m.rope_head_dim), ("embed", "heads", None)),
            "wkv_a": ParamDef((d, m.kv_lora_rank + m.rope_head_dim), ("embed", None)),
            "ckv_norm": ParamDef((m.kv_lora_rank,), (None,), init="ones"),
            "wk_b": ParamDef((m.kv_lora_rank, h, m.nope_head_dim), ("kv_lora", "heads", None)),
            "wv_b": ParamDef((m.kv_lora_rank, h, m.v_head_dim), ("kv_lora", "heads", None)),
            "wo": ParamDef((h, m.v_head_dim, d), ("heads", None, "embed")),
        }
        if m.q_lora_rank:
            defs["wq_a"] = ParamDef((d, m.q_lora_rank), ("embed", None))
            defs["q_norm"] = ParamDef((m.q_lora_rank,), (None,), init="ones")
            defs["wq"] = ParamDef((m.q_lora_rank, h, m.nope_head_dim + m.rope_head_dim),
                                  (None, "heads", None))
        return defs
    return {
        # 'dmodel_rp' is inactive by default; enabling it (ParallelConfig.
        # row_parallel_attn) shards the d_model contraction dim over
        # `model` — the Megatron row-parallel fallback for head counts
        # that do not divide the TP axis (e.g. llama4's 40 heads on 16).
        "wq": ParamDef((d, h, hd), ("dmodel_rp", "heads", None)),
        "wk": ParamDef((d, kvh, hd), ("dmodel_rp", "kv_heads", None)),
        "wv": ParamDef((d, kvh, hd), ("dmodel_rp", "kv_heads", None)),
        "wo": ParamDef((h, hd, d), ("heads", None, "dmodel_rp")),
    }


# --------------------------------------------------------------------- #
# Chunked (flash-style) attention — differentiable, O(chunk^2) memory
# --------------------------------------------------------------------- #


def _tile_pairs(nq: int, nk: int, causal: bool, cq: int, ck: int,
                q_offset: int) -> np.ndarray:
    pairs = []
    for i in range(nq):
        q_end = q_offset + (i + 1) * cq - 1
        for j in range(nk):
            if causal and j * ck > q_end:
                continue
            pairs.append((i, j))
    return np.asarray(pairs, np.int32)


def _pack(q, k, v, cq, ck):
    """Pad seq dims to tile multiples; return (b,kvh,g,SQ,dh)/(b,kvh,SK,dh)."""
    b, sq, h, dh = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    pq, pk = (-sq) % cq, (-sk) % ck
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b, kvh, g, sq + pq, dh)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    return qf, kf, vf, (b, sq, sk, h, kvh, g, dh, pq, pk)


def _tile_mask(s_shape, bias_d, i, j, cq, ck, q_offset, causal):
    """Additive mask for tile (i, j); bias_d: (b,ck) slice of the length
    bias. s_shape = (b,kvh,g,cq,ck)."""
    m = bias_d[:, None, None, None, :]
    if causal:
        qpos = q_offset + i * cq + jnp.arange(cq)
        kpos = j * ck + jnp.arange(ck)
        m = m + jnp.where(kpos[None, :] <= qpos[:, None], 0.0, _NEG_INF
                          )[None, None, None, :, :]
    return m


def _flash_fwd_scan(qf, kf, vf, bias, pairs, *, cq, ck, q_offset, causal,
                    scale, unroll):
    b, kvh, g, SQ, dh = qf.shape

    m0 = jnp.full((b, kvh, g, SQ, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, SQ, 1), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, SQ, dh), jnp.float32)

    def step(carry, ij):
        m, l, acc = carry
        i, j = ij[0], ij[1]
        qd = jax.lax.dynamic_slice_in_dim(qf, i * cq, cq, axis=3)
        kd = jax.lax.dynamic_slice_in_dim(kf, j * ck, ck, axis=2)
        vd = jax.lax.dynamic_slice_in_dim(vf, j * ck, ck, axis=2)
        bd = jax.lax.dynamic_slice_in_dim(bias, j * ck, ck, axis=1)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qd, kd) * scale
        s = s + _tile_mask(s.shape, bd, i, j, cq, ck, q_offset, causal)
        m_prev = jax.lax.dynamic_slice_in_dim(m, i * cq, cq, axis=3)
        l_prev = jax.lax.dynamic_slice_in_dim(l, i * cq, cq, axis=3)
        a_prev = jax.lax.dynamic_slice_in_dim(acc, i * cq, cq, axis=3)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        a_new = alpha * a_prev + jnp.einsum("bkgqc,bkcd->bkgqd", p, vd)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * cq, axis=3)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, i * cq, axis=3)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, i * cq, axis=3)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), pairs, unroll=unroll)
    lsafe = jnp.where(l == 0.0, 1.0, l)
    out = acc / lsafe
    lse = m + jnp.log(lsafe)           # (b,kvh,g,SQ,1)
    return out, lse


def _flash_bwd_scan(qf, kf, vf, bias, out, lse, dout, pairs, *, cq, ck,
                    q_offset, causal, scale, unroll):
    b, kvh, g, SQ, dh = qf.shape
    SK = kf.shape[2]
    delta = jnp.sum(out * dout, axis=-1, keepdims=True)      # (b,kvh,g,SQ,1)

    dq0 = jnp.zeros_like(qf)
    dk0 = jnp.zeros_like(kf)
    dv0 = jnp.zeros_like(vf)

    def step(carry, ij):
        dq, dk, dv = carry
        i, j = ij[0], ij[1]
        qd = jax.lax.dynamic_slice_in_dim(qf, i * cq, cq, axis=3)
        kd = jax.lax.dynamic_slice_in_dim(kf, j * ck, ck, axis=2)
        vd = jax.lax.dynamic_slice_in_dim(vf, j * ck, ck, axis=2)
        bd = jax.lax.dynamic_slice_in_dim(bias, j * ck, ck, axis=1)
        lsed = jax.lax.dynamic_slice_in_dim(lse, i * cq, cq, axis=3)
        deld = jax.lax.dynamic_slice_in_dim(delta, i * cq, cq, axis=3)
        dod = jax.lax.dynamic_slice_in_dim(dout, i * cq, cq, axis=3)

        s = jnp.einsum("bkgqd,bkcd->bkgqc", qd, kd) * scale
        s = s + _tile_mask(s.shape, bd, i, j, cq, ck, q_offset, causal)
        p = jnp.exp(s - lsed)                                # (b,kvh,g,cq,ck)
        dvd = jnp.einsum("bkgqc,bkgqd->bkcd", p, dod)
        dp = jnp.einsum("bkgqd,bkcd->bkgqc", dod, vd)
        ds = p * (dp - deld)
        dqd = jnp.einsum("bkgqc,bkcd->bkgqd", ds, kd) * scale
        dkd = jnp.einsum("bkgqc,bkgqd->bkcd", ds, qd) * scale

        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, i * cq, cq, axis=3) + dqd,
            i * cq, axis=3)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, j * ck, ck, axis=2) + dkd,
            j * ck, axis=2)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, j * ck, ck, axis=2) + dvd,
            j * ck, axis=2)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), pairs, unroll=unroll)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _flash(q, k, v, bias, cq, ck, q_offset, causal, scale, unroll):
    out, _ = _flash_core(q, k, v, bias, cq, ck, q_offset, causal, scale, unroll)
    return out


def _flash_core(q, k, v, bias, cq, ck, q_offset, causal, scale, unroll):
    qf, kf, vf, meta = _pack(q, k, v, cq, ck)
    b, sq, sk, h, kvh, g, dh, pq, pk = meta
    nq, nk = qf.shape[3] // cq, kf.shape[2] // ck
    pairs = jnp.asarray(_tile_pairs(nq, nk, causal, cq, ck, q_offset))
    biasp = jnp.pad(bias, ((0, 0), (0, pk)), constant_values=_NEG_INF)
    out, lse = _flash_fwd_scan(qf, kf, vf, biasp, pairs, cq=cq, ck=ck,
                               q_offset=q_offset, causal=causal, scale=scale,
                               unroll=unroll)
    o = out.reshape(b, h, sq + pq, dh).transpose(0, 2, 1, 3)[:, :sq]
    return o.astype(q.dtype), (out, lse, pairs)


def _flash_fwd(q, k, v, bias, cq, ck, q_offset, causal, scale, unroll):
    o, res = _flash_core(q, k, v, bias, cq, ck, q_offset, causal, scale, unroll)
    return o, (q, k, v, bias) + res


def _flash_bwd(cq, ck, q_offset, causal, scale, unroll, saved, do):
    q, k, v, bias, out, lse, pairs = saved
    qf, kf, vf, meta = _pack(q, k, v, cq, ck)
    b, sq, sk, h, kvh, g, dh, pq, pk = meta
    biasp = jnp.pad(bias, ((0, 0), (0, pk)), constant_values=_NEG_INF)
    SQ = qf.shape[3]
    dof = do.astype(jnp.float32).transpose(0, 2, 1, 3)   # (b, h, sq, dh)
    if SQ != sq:
        dof = jnp.pad(dof, ((0, 0), (0, 0), (0, SQ - sq), (0, 0)))
    dof = dof.reshape(b, kvh, g, SQ, dh)
    dq, dk, dv = _flash_bwd_scan(qf, kf, vf, biasp, out, lse, dof, pairs,
                                 cq=cq, ck=ck, q_offset=q_offset,
                                 causal=causal, scale=scale, unroll=unroll)
    dq = dq.reshape(b, h, SQ, dh).transpose(0, 2, 1, 3)[:, :sq].astype(q.dtype)
    dk = dk.transpose(0, 2, 1, 3)[:, :sk].astype(k.dtype)
    dv = dv.transpose(0, 2, 1, 3)[:, :sk].astype(v.dtype)
    return dq, dk, dv, jnp.zeros_like(bias)


_flash.defvjp(_flash_fwd, _flash_bwd)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk_q: int, chunk_k: int,
                      q_offset: int = 0,
                      lengths: Optional[jax.Array] = None,
                      sm_scale: Optional[float] = None,
                      unroll: int = 1) -> jax.Array:
    """Flash attention in jnp with O(s*d) memory fwd AND bwd (custom
    VJP recomputes p per tile).

    q: (b, sq, h, dh); k, v: (b, sk, kvh, dh) -> (b, sq, h, dh).
    ``q_offset``: global position of q[0]; ``lengths``: valid kv lengths.
    ``unroll``: unroll factor for the tile scan (cost-analysis lowering).
    """
    b, sq, h, dh = q.shape
    _, sk, kvh, _ = k.shape
    scale = sm_scale if sm_scale is not None else dh ** -0.5
    cq = min(chunk_q, sq)
    ck = min(chunk_k, sk)
    if lengths is None:
        bias = jnp.zeros((b, sk), jnp.float32)
    else:
        bias = jnp.where(jnp.arange(sk)[None, :] < lengths[:, None], 0.0, _NEG_INF)
    return _flash(q, k, v, bias, cq, ck, q_offset, causal, scale, unroll)


def naive_attention(q, k, v, *, causal, lengths=None, q_offset=0,
                    sm_scale=None) -> jax.Array:
    """Reference/naive path (smoke tests and small shapes)."""
    b, sq, h, dh = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = sm_scale if sm_scale is not None else dh ** -0.5
    qf = q.astype(jnp.float32).reshape(b, sq, kvh, g, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32)) * scale
    kpos = jnp.arange(sk)
    valid = jnp.ones((b, 1, 1, 1, sk), bool)
    if lengths is not None:
        valid = kpos[None, None, None, None, :] < lengths[:, None, None, None, None]
    if causal:
        qpos = q_offset + jnp.arange(sq)
        valid = valid & (kpos[None, None, None, None, :] <= qpos[None, None, None, :, None])
    s = jnp.where(valid, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, sm_scale: Optional[float] = None) -> jax.Array:
    """One-token attention over a (seq-sharded) dense cache.

    q: (b, 1, h, dh); caches: (b, S, kvh, dh); lengths: (b,).
    """
    b, _, h, dh = q.shape
    _, S, kvh, _ = k_cache.shape
    g = h // kvh
    scale = sm_scale if sm_scale is not None else dh ** -0.5
    qf = (q.astype(jnp.float32) * scale).reshape(b, kvh, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(valid, s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)          # all-reduce over seq shards
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)          # all-reduce over seq shards
    out = jnp.einsum("bkgs,bskd->bkgd", p / l, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# --------------------------------------------------------------------- #
# GQA layer (train / prefill / decode / cross)
# --------------------------------------------------------------------- #


def _attend(q, k, v, pcfg: ParallelConfig, *, causal, lengths=None, q_offset=0):
    if pcfg.attention_impl == "naive":
        return naive_attention(q, k, v, causal=causal, lengths=lengths, q_offset=q_offset)
    return chunked_attention(q, k, v, causal=causal, chunk_q=pcfg.attention_chunk,
                             chunk_k=pcfg.attention_chunk, lengths=lengths,
                             q_offset=q_offset,
                             unroll=True if pcfg.scan_unroll else 1)


def _write_kv(cache, k, v, pos):
    """Write (k, v) into pre-allocated (max_len) cache buffers at pos."""
    k_cache, v_cache = cache
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), pos, axis=1)
    return k_cache, v_cache


def gqa_attention(cfg: ModelConfig, pcfg: ParallelConfig, p: Dict[str, jax.Array],
                  x: jax.Array, positions: jax.Array, *,
                  mode: str, causal: bool = True,
                  cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  write_pos: Optional[jax.Array] = None,
                  lengths: Optional[jax.Array] = None,
                  memory: Optional[jax.Array] = None,
                  is_cross: bool = False,
                  use_rope: bool = True):
    """Returns (out, new_cache).

    mode: "train" | "prefill" | "decode".  ``is_cross``: k/v from
    ``memory`` at train/prefill; from the (projected-memory) cache at
    decode.  Self-attention prefill/decode writes k/v into the
    pre-allocated ``cache`` buffers.
    """
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"]))
    q = shard(q, "batch", None, "heads", None)

    if is_cross and mode == "decode":
        assert cache is not None
        k, v = cache  # projected memory kv, stored at prefill
        new_cache = cache
    else:
        kv_src = memory if is_cross else x
        k = jnp.einsum("bsd,dhk->bshk", kv_src, cast(p["wk"]))
        v = jnp.einsum("bsd,dhk->bshk", kv_src, cast(p["wv"]))
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
        new_cache = None

    if use_rope and not is_cross:
        sin, cos = rope_sincos(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        if not (is_cross and mode == "decode"):
            k = apply_rope(k, sin, cos)   # decode: positions = write position

    if is_cross:
        mem_len = k.shape[1]
        mem_lengths = jnp.full((x.shape[0],), mem_len, jnp.int32)
        if mode == "decode":
            out = decode_attention(q, k, v, mem_lengths)
        else:
            out = _attend(q, k, v, pcfg, causal=False)
            if mode == "prefill":
                cdt = cache[0].dtype if cache is not None else k.dtype
                new_cache = (k.astype(cdt), v.astype(cdt))
    elif mode == "decode":
        assert cache is not None and write_pos is not None
        k_cache, v_cache = _write_kv(cache, k, v, write_pos)
        out = decode_attention(q, k_cache, v_cache, lengths)
        new_cache = (k_cache, v_cache)
    else:
        out = _attend(q, k, v, pcfg, causal=causal, lengths=lengths)
        if mode == "prefill" and cache is not None:
            new_cache = _write_kv(cache, k, v, 0)

    out = jnp.einsum("bshk,hkd->bsd", out, cast(p["wo"]))
    return shard(out, "batch", None, None), new_cache


# --------------------------------------------------------------------- #
# MLA layer (DeepSeek-V2)
# --------------------------------------------------------------------- #


def _mla_q(cfg, p, x):
    m = cfg.mla
    if m.q_lora_rank:
        from .layers import rmsnorm
        cq = jnp.einsum("bsd,dr->bsr", x, cast(p["wq_a"]))
        cq = rmsnorm(cq, p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, cast(p["wq"]))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"]))
    return shard(q, "batch", None, "heads", None)


def mla_attention(cfg: ModelConfig, pcfg: ParallelConfig, p: Dict[str, jax.Array],
                  x: jax.Array, positions: jax.Array, *,
                  mode: str,
                  cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                  write_pos: Optional[jax.Array] = None,
                  lengths: Optional[jax.Array] = None):
    """MLA: cache = (c_kv (b,S,r), k_rope (b,S,rope_dim))."""
    from .layers import rmsnorm
    m = cfg.mla
    h = cfg.num_heads
    q = _mla_q(cfg, p, x)                       # (b,s,h,nope+rope)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]

    ckv_full = jnp.einsum("bsd,dr->bsr", x, cast(p["wkv_a"]))
    c_kv, k_rope = ckv_full[..., :m.kv_lora_rank], ckv_full[..., m.kv_lora_rank:]
    c_kv = rmsnorm(c_kv, p["ckv_norm"], cfg.norm_eps)

    sin, cos = rope_sincos(positions, m.rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)[:, :, 0, :]

    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5

    if mode == "decode":
        assert cache is not None and write_pos is not None
        ckv_cache, krope_cache = cache
        ckv_cache = jax.lax.dynamic_update_slice_in_dim(
            ckv_cache, c_kv.astype(ckv_cache.dtype), write_pos, axis=1)
        krope_cache = jax.lax.dynamic_update_slice_in_dim(
            krope_cache, k_rope.astype(krope_cache.dtype), write_pos, axis=1)
        # absorbed decode: q_latent = W_uk^T q_nope
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, cast(p["wk_b"]))
        s = (jnp.einsum("bshr,bSr->bhS", q_lat.astype(jnp.float32),
                        ckv_cache.astype(jnp.float32))
             + jnp.einsum("bshk,bSk->bhS", q_rope.astype(jnp.float32),
                          krope_cache.astype(jnp.float32))) * scale
        S = ckv_cache.shape[1]
        valid = jnp.arange(S)[None, None, :] < lengths[:, None, None]
        s = jnp.where(valid, s, _NEG_INF)
        mx = jnp.max(s, axis=-1, keepdims=True)
        pr = jnp.exp(s - mx)
        l = jnp.sum(pr, axis=-1, keepdims=True)
        ctx = jnp.einsum("bhS,bSr->bhr", pr / l, ckv_cache.astype(jnp.float32))
        out = jnp.einsum("bhr,rhv->bhv", ctx, cast(p["wv_b"]).astype(jnp.float32))
        out = out[:, None].astype(x.dtype)      # (b,1,h,v)
        new_cache = (ckv_cache, krope_cache)
    else:
        # expanded form: per-head k/v from the latent
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, cast(p["wk_b"]))
        vv = jnp.einsum("bsr,rhv->bshv", c_kv, cast(p["wv_b"]))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(
            k_rope[:, :, None, :], k_nope.shape[:3] + (m.rope_head_dim,))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        # pad v to qk head dim for the shared attention helper, then slice
        out = _attend(qq, k,
                      jnp.pad(vv, ((0, 0), (0, 0), (0, 0),
                                   (0, k.shape[-1] - vv.shape[-1]))),
                      pcfg, causal=True, lengths=lengths)
        out = out[..., :m.v_head_dim]
        new_cache = None
        if mode == "prefill" and cache is not None:
            ckv_cache = jax.lax.dynamic_update_slice_in_dim(
                cache[0], c_kv.astype(cache[0].dtype), 0, axis=1)
            krope_cache = jax.lax.dynamic_update_slice_in_dim(
                cache[1], k_rope.astype(cache[1].dtype), 0, axis=1)
            new_cache = (ckv_cache, krope_cache)

    y = jnp.einsum("bshv,hvd->bsd", out, cast(p["wo"]))
    return shard(y, "batch", None, None), new_cache

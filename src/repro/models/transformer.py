"""Model assembly for every assigned architecture family.

All families are built from the same sub-layer vocabulary
(attention / MLA / Mamba-2 / MLP / MoE, each pre-RMSNormed) arranged
into *layer groups*.  A group is (count, layer-kind-signature); its
parameters are stacked along a leading `layers` dim and the group is
executed with `jax.lax.scan` (+ configurable remat), which keeps the HLO
size O(1) in depth — essential for compiling 398 B-param configs.

Families -> groups:
  dense / vlm        [(L, attn+mlp)]
  moe                [(k, attn+mlp), (L-k, attn+moe)]   (k = first dense)
  moe + MLA          same, attention = MLA
  ssm                [(L, mamba)]
  hybrid (jamba)     [(L/8, superblock of 8 sublayers: attn at index 3,
                       mamba elsewhere; MoE on odd sublayers, MLP on even)]
  encdec             encoder [(Le, attn+mlp non-causal)],
                     decoder [(Ld, self-attn + cross-attn + mlp)]

KV caches are pytrees stacked along the same `layers` dim and scanned
together with the parameters.  `mode` is one of train | prefill | decode.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.distributed.sharding import shard
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (cast, embed, embed_defs, logits_out, mlp, mlp_defs,
                     rmsnorm, rmsnorm_def)
from .params import ParamDef, stacked

CACHE_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------- #
# Layer kinds
# --------------------------------------------------------------------- #


def _sublayer_defs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d = cfg.d_model
    if kind == "attn":
        return {"norm": rmsnorm_def(d), "attn": attn_mod.attn_defs(cfg)}
    if kind == "mla":
        return {"norm": rmsnorm_def(d), "attn": attn_mod.attn_defs(cfg)}
    if kind == "mamba":
        return {"norm": rmsnorm_def(d), "ssm": ssm_mod.ssm_defs(cfg)}
    if kind == "mlp":
        return {"norm": rmsnorm_def(d), "mlp": mlp_defs(d, cfg.d_ff, cfg.activation)}
    if kind == "moe":
        return {"norm": rmsnorm_def(d), "moe": moe_mod.moe_defs(cfg)}
    if kind == "cross":
        return {"norm": rmsnorm_def(d), "attn": attn_mod.attn_defs(cfg)}
    raise ValueError(kind)


def _layer_defs(cfg: ModelConfig, layer_kind: Tuple[str, ...]) -> Dict[str, Any]:
    return {f"{i}_{k}": _sublayer_defs(cfg, k) for i, k in enumerate(layer_kind)}


def layer_groups(cfg: ModelConfig) -> Tuple[Tuple[int, Tuple[str, ...]], ...]:
    """((count, (sublayer kinds...)), ...) per family."""
    L = cfg.num_layers
    if cfg.family in ("dense", "vlm"):
        return ((L, ("attn", "mlp")),)
    if cfg.family == "moe":
        attn = "mla" if cfg.mla else "attn"
        k = cfg.moe.first_dense_layers
        groups = []
        if k:
            groups.append((k, (attn, "mlp")))
        groups.append((L - k, (attn, "moe")))
        return tuple(groups)
    if cfg.family == "ssm":
        return ((L, ("mamba",)),)
    if cfg.family == "hybrid":
        period = cfg.attn_every
        kinds = []
        for i in range(period):
            mixer = "attn" if i == period // 2 - 1 else "mamba"
            ffn = "moe" if (i % 2 == 1 and cfg.moe) else "mlp"
            kinds.extend([mixer, ffn])
        return ((L // period, tuple(kinds)),)
    if cfg.family == "encdec":
        return ((cfg.enc_layers, ("attn", "mlp")),
                (cfg.dec_layers, ("attn", "cross", "mlp")))
    raise ValueError(cfg.family)


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    defs: Dict[str, Any] = {"embed": embed_defs(cfg),
                            "final_norm": rmsnorm_def(cfg.d_model)}
    for gi, (count, kinds) in enumerate(layer_groups(cfg)):
        defs[f"group{gi}"] = stacked(_layer_defs(cfg, kinds), count)
    if cfg.family == "vlm" or cfg.num_patch_tokens:
        fd = cfg.frontend_dim or cfg.d_model
        if cfg.family != "encdec":
            defs["patch_proj"] = ParamDef((fd, cfg.d_model), (None, "embed"))
    if cfg.family == "encdec":
        fd = cfg.frontend_dim or cfg.d_model
        defs["frame_proj"] = ParamDef((fd, cfg.d_model), (None, "embed"))
        defs["enc_final_norm"] = rmsnorm_def(cfg.d_model)
    return defs


# --------------------------------------------------------------------- #
# Caches
# --------------------------------------------------------------------- #


def cache_spec(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0, kv_dtype=None) -> Dict[str, Any]:
    """ShapeDtypeStructs for the decode cache (also used to allocate).

    ``kv_dtype``: attention-cache dtype override (e.g. fp8_e4m3 for the
    quantized-KV optimization; SSM/conv states keep their dtypes)."""
    hd = cfg.resolved_head_dim
    kvd = kv_dtype or CACHE_DTYPE
    spec: Dict[str, Any] = {}

    def attn_cache():
        if cfg.mla:
            m = cfg.mla
            return (jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), kvd),
                    jax.ShapeDtypeStruct((batch, max_len, m.rope_head_dim), kvd))
        return (jax.ShapeDtypeStruct((batch, max_len, cfg.num_kv_heads, hd), kvd),
                jax.ShapeDtypeStruct((batch, max_len, cfg.num_kv_heads, hd), kvd))

    def ssm_cache():
        s = cfg.ssm
        d_in = s.expand * cfg.d_model
        nheads = d_in // s.head_dim
        ch = d_in + 2 * s.state_dim
        return (jax.ShapeDtypeStruct((batch, s.conv_width - 1, ch), CACHE_DTYPE),
                jax.ShapeDtypeStruct((batch, nheads, s.head_dim, s.state_dim), jnp.float32))

    def cross_cache():
        return (jax.ShapeDtypeStruct((batch, enc_len, cfg.num_kv_heads, hd), CACHE_DTYPE),
                jax.ShapeDtypeStruct((batch, enc_len, cfg.num_kv_heads, hd), CACHE_DTYPE))

    for gi, (count, kinds) in enumerate(layer_groups(cfg)):
        if cfg.family == "encdec" and gi == 0:
            continue  # encoder holds no cache
        g: Dict[str, Any] = {}
        for i, k in enumerate(kinds):
            if k in ("attn", "mla"):
                g[f"{i}_{k}"] = attn_cache()
            elif k == "mamba":
                g[f"{i}_{k}"] = ssm_cache()
            elif k == "cross":
                g[f"{i}_{k}"] = cross_cache()
        if g:
            spec[f"group{gi}"] = jax.tree.map(
                lambda s_, c=count: jax.ShapeDtypeStruct((c,) + s_.shape, s_.dtype), g)
    return spec


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_len, enc_len))


def cache_pspecs(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    """PartitionSpecs for the cache: batch over data axes, seq over model
    (sequence parallelism for long KV)."""
    from repro.distributed.sharding import resolve_spec
    spec = cache_spec(cfg, batch, max_len, enc_len)

    def one(s: jax.ShapeDtypeStruct):
        # (layers, batch, seq?, ...) — rank-dependent logical axes
        if len(s.shape) == 4 and s.shape[2] in (max_len, enc_len):
            la = ("layers", "batch", "seq", None)
        elif len(s.shape) == 5:
            la = ("layers", "batch", "seq", None, None)
        elif len(s.shape) == 3:
            la = ("layers", "batch", None)
        else:
            la = ("layers", "batch") + (None,) * (len(s.shape) - 2)
        return resolve_spec(s.shape, la)

    return jax.tree.map(one, spec)


# --------------------------------------------------------------------- #
# Sub-layer dispatch
# --------------------------------------------------------------------- #


def _run_sublayer(cfg, pcfg, kind, p, x, positions, *, mode, cache, write_pos,
                  lengths, memory, causal):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if kind == "mla":
        out, new_cache = attn_mod.mla_attention(
            cfg, pcfg, p["attn"], h, positions, mode=mode, cache=cache,
            write_pos=write_pos, lengths=lengths)
    elif kind == "attn":
        out, new_cache = attn_mod.gqa_attention(
            cfg, pcfg, p["attn"], h, positions, mode=mode, causal=causal,
            cache=cache, write_pos=write_pos, lengths=lengths)
    elif kind == "cross":
        out, new_cache = attn_mod.gqa_attention(
            cfg, pcfg, p["attn"], h, positions, mode=mode, causal=False,
            cache=cache, write_pos=write_pos, lengths=None, memory=memory,
            is_cross=True)
    elif kind == "mamba":
        out, new_cache = ssm_mod.ssm_layer(cfg, pcfg, p["ssm"], h, mode=mode,
                                           cache=cache)
    elif kind == "mlp":
        out, new_cache = mlp(p["mlp"], h, cfg.activation), None
    elif kind == "moe":
        out, aux = moe_mod.moe_layer(cfg, pcfg, p["moe"], h)
        new_cache = None
    else:
        raise ValueError(kind)
    return x + out, new_cache, aux


def _run_group(cfg, pcfg, kinds, gparams, x, positions, *, mode, gcache,
               write_pos, lengths, memory, causal):
    """Scan one stacked layer group."""

    cached_kinds = [f"{i}_{k}" for i, k in enumerate(kinds)
                    if f"{i}_{k}" in (gcache or {})]

    def body(carry, xs):
        h, aux_sum = carry
        p_layer, c_layer = xs
        new_c = dict(c_layer)
        for i, k in enumerate(kinds):
            key = f"{i}_{k}"
            sub_cache = c_layer.get(key) if c_layer else None
            h, nc, aux = _run_sublayer(
                cfg, pcfg, k, p_layer[key], h, positions, mode=mode,
                cache=sub_cache, write_pos=write_pos, lengths=lengths,
                memory=memory, causal=causal)
            if key in (c_layer or {}):
                new_c[key] = nc if nc is not None else sub_cache
            aux_sum = aux_sum + aux
        return (h, aux_sum), new_c

    if pcfg.remat == "full":
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    elif pcfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    gcache_in = gcache if gcache else {}
    (x, aux), new_gcache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        (gparams, gcache_in),
                                        unroll=True if pcfg.scan_unroll else 1)
    return x, (new_gcache if gcache else None), aux


# --------------------------------------------------------------------- #
# Forward entry points
# --------------------------------------------------------------------- #


def _input_embed(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    """Token (+ modality-stub) embedding; returns (x, positions)."""
    x = embed(params["embed"], batch["tokens"], cfg)
    if cfg.num_patch_tokens and "patch_embeds" in batch and cfg.family != "encdec":
        pe = cast(jnp.einsum("bpe,ed->bpd", batch["patch_embeds"],
                             cast(params["patch_proj"])))
        x = jnp.concatenate([pe, x], axis=1)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                     x.shape[:2])
    return shard(x, "batch", None, None), positions


def _encoder(cfg, pcfg, params, batch):
    frames = cast(jnp.einsum("bse,ed->bsd", batch["frames"],
                             cast(params["frame_proj"])))
    frames = shard(frames, "batch", None, None)
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1], dtype=jnp.int32),
                           frames.shape[:2])
    count, kinds = layer_groups(cfg)[0]
    h, _, _ = _run_group(cfg, pcfg, kinds, params["group0"], frames, pos,
                         mode="train", gcache=None, write_pos=None,
                         lengths=None, memory=None, causal=False)
    return rmsnorm(h, params["enc_final_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, pcfg: ParallelConfig, params,
            batch: Dict[str, jax.Array], *, mode: str,
            cache: Optional[Dict[str, Any]] = None,
            write_pos: Optional[jax.Array] = None,
            lengths: Optional[jax.Array] = None):
    """Unified forward.

    train:   returns (logits, aux)
    prefill: returns (logits_last, new_cache, aux)
    decode:  returns (logits, new_cache)   [batch tokens are (b, 1)]
    """
    memory = None
    if cfg.family == "encdec":
        if mode == "decode":
            memory = None  # cross kv comes from the cache
        else:
            memory = _encoder(cfg, pcfg, params, batch)

    x, positions = _input_embed(cfg, params, batch)
    if mode == "decode" and lengths is not None:
        # lengths counts the context INCLUDING the token being decoded,
        # whose absolute position is therefore lengths - 1.
        positions = (lengths[:, None] - 1).astype(jnp.int32)

    groups = layer_groups(cfg)
    new_cache: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)
    start_g = 1 if cfg.family == "encdec" else 0
    for gi in range(start_g, len(groups)):
        count, kinds = groups[gi]
        gname = f"group{gi}"
        gcache = (cache or {}).get(gname)
        x, ngc, aux = _run_group(
            cfg, pcfg, kinds, params[gname], x, positions, mode=mode,
            gcache=gcache, write_pos=write_pos, lengths=lengths,
            memory=memory, causal=True)
        if ngc is not None:
            new_cache[gname] = ngc
        aux_total = aux_total + aux

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)

    if mode == "features":
        return x, aux_total
    if mode == "train":
        logits = logits_out(params["embed"], x, cfg, fp32=pcfg.logits_fp32)
        return logits, aux_total
    if mode == "prefill":
        logits = logits_out(params["embed"], x[:, -1:], cfg, fp32=pcfg.logits_fp32)
        return logits, new_cache, aux_total
    logits = logits_out(params["embed"], x, cfg, fp32=pcfg.logits_fp32)
    return logits, new_cache

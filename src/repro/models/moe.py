"""Mixture-of-Experts with shard_map expert parallelism.

Experts are sharded over the `model` mesh axis (EP); activations enter
replicated across `model` (they are batch-sharded over `data`/`pod`).
Inside `shard_map` each shard:

  1. computes router logits + global top-k (router weights replicated),
  2. builds a *capacity-bounded dispatch table* for its local experts
     with a sort-free cumsum ranking (no cross-shard scatter — the GSPMD
     scatter pathologies are avoided entirely; tokens routed to remote
     experts are simply handled by the shard that owns them, because
     every shard sees every token),
  3. gathers its tokens, runs the local expert FFNs as one batched
     einsum over the expert dim,
  4. scatter-adds weighted outputs into the local output buffer,
  5. `psum`s over `model` to combine expert contributions.

The `psum` doubles as the Megatron-style TP combine, so MoE layers cost
the same single all-reduce as a TP dense layer.  Capacity overflow drops
tokens (standard dropless-approximation; the aux load-balance loss keeps
overflow rare).  A `dense` fallback (every token through every expert,
einsum-only) exists for tiny smoke configs and as an oracle in tests.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig, ParallelConfig
from repro.distributed.sharding import env, shard
from .layers import cast
from .params import ParamDef


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    m = cfg.moe
    d, ff, E = cfg.d_model, m.expert_d_ff, m.num_experts
    defs = {
        "router": ParamDef((d, E), ("embed", None), init="small"),
        "w_gate": ParamDef((E, d, ff), ("experts", "embed", "ff")),
        "w_up": ParamDef((E, d, ff), ("experts", "embed", "ff")),
        "w_down": ParamDef((E, ff, d), ("experts", "ff", "embed")),
    }
    if m.num_shared_experts:
        sff = m.expert_d_ff * m.num_shared_experts
        defs.update({
            "shared_gate": ParamDef((d, sff), ("embed", "ff")),
            "shared_up": ParamDef((d, sff), ("embed", "ff")),
            "shared_down": ParamDef((sff, d), ("ff", "embed")),
        })
    return defs


def _expert_ffn(w_gate, w_up, w_down, x, activation: str) -> jax.Array:
    """x: (E, C, d) through per-expert gated FFN."""
    g = jnp.einsum("ecd,edf->ecf", x, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x, w_up)
    act = jax.nn.silu(g) if activation == "swiglu" else jax.nn.gelu(g)
    return jnp.einsum("ecf,efd->ecd", act * u, w_down)


def _local_moe(x, router_w, w_gate, w_up, w_down, *, top_k: int,
               num_experts: int, capacity: int, activation: str,
               model_axis: Optional[str],
               psum_dtype=jnp.float32) -> Tuple[jax.Array, jax.Array]:
    """Per-shard MoE body (runs under shard_map when model_axis set).

    x: (b_local, s, d) replicated over model; expert weights are the
    LOCAL slices (E_local, ...).
    """
    b, s, d = x.shape
    t = b * s
    e_local = w_gate.shape[0]
    if model_axis is not None:
        shard_idx = jax.lax.axis_index(model_axis)
    else:
        shard_idx = 0
    e_lo = shard_idx * e_local

    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)             # (t, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # aux load-balance loss terms (Switch-style)
    me = jnp.mean(probs, axis=0)                           # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, num_experts, dtype=jnp.float32), axis=1),
        axis=0)
    aux = jnp.sum(me * ce) * num_experts / top_k

    # dispatch: rank of each (token, k) within its expert, local experts only
    flat_e = top_e.reshape(-1)                             # (t*k,)
    is_local = (flat_e >= e_lo) & (flat_e < e_lo + e_local)
    local_e = jnp.where(is_local, flat_e - e_lo, e_local)  # e_local = trash
    onehot = jax.nn.one_hot(local_e, e_local + 1, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) * onehot              # 1-based rank
    rank = jnp.sum(pos, axis=1) - 1                        # (t*k,)
    ok = is_local & (rank < capacity)

    # dispatch table: buf[e, c] = token index + 1 (0 = empty)
    tok_idx = jnp.repeat(jnp.arange(t), top_k)             # (t*k,)
    buf = jnp.zeros((e_local, capacity), jnp.int32)
    buf = buf.at[
        jnp.where(ok, local_e, e_local - 1),   # clamp; masked below anyway
        jnp.where(ok, rank, capacity - 1),
    ].max(jnp.where(ok, tok_idx + 1, 0))

    gathered = jnp.where((buf > 0)[..., None],
                         xf[jnp.maximum(buf - 1, 0)], 0.0)  # (E_l, C, d)
    h = _expert_ffn(w_gate, w_up, w_down, gathered.astype(w_gate.dtype),
                    activation)

    # combine: weight by router prob, scatter-add back to tokens
    flat_p = top_p.reshape(-1)
    weight = jnp.zeros((e_local, capacity), jnp.float32)
    weight = weight.at[
        jnp.where(ok, local_e, e_local - 1),
        jnp.where(ok, rank, capacity - 1),
    ].max(jnp.where(ok, flat_p, 0.0))

    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[jnp.maximum(buf - 1, 0)].add(
        h.astype(jnp.float32) * weight[..., None] * (buf > 0)[..., None])

    if model_axis is not None:
        out = jax.lax.psum(out.astype(psum_dtype), model_axis)
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_layer(cfg: ModelConfig, pcfg: ParallelConfig, p: Dict[str, jax.Array],
              x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss)."""
    m = cfg.moe
    e = env()
    b, s, d = x.shape
    tokens = b * s

    if pcfg.moe_impl == "dense" or e.mesh is None:
        out, aux = _dense_moe(cfg, p, x)
    else:
        mesh = e.mesh
        model_ax = "model"
        msize = mesh.shape[model_ax]
        if m.num_experts % msize != 0:
            out, aux = _dense_moe(cfg, p, x)
        else:
            bsize = int(np.prod([mesh.shape[a] for a in e.batch_axes]))
            if b % bsize == 0:
                batch_spec = P(e.batch_axes if len(e.batch_axes) > 1
                               else e.batch_axes[0])
            else:  # tiny batches (e.g. long-context decode, B=1): replicate
                batch_spec = P(None)
            cf = pcfg.moe_capacity_factor or m.capacity_factor
            cap = int(np.ceil(tokens * m.top_k / m.num_experts * cf))
            cap = max(8, min(cap, tokens))
            psum_dtype = (jnp.bfloat16 if pcfg.moe_psum_dtype == "bfloat16"
                          else jnp.float32)
            fn = functools.partial(
                _local_moe, top_k=m.top_k, num_experts=m.num_experts,
                capacity=cap, activation=cfg.activation, model_axis=model_ax,
                psum_dtype=psum_dtype)
            out, aux = shard_map(
                fn, mesh=mesh,
                in_specs=(P(*batch_spec, None, None), P(None, None),
                          P(model_ax, None, None), P(model_ax, None, None),
                          P(model_ax, None, None)),
                out_specs=(P(*batch_spec, None, None), P()),
                check_rep=False,
            )(x, p["router"].astype(jnp.float32), cast(p["w_gate"]),
              cast(p["w_up"]), cast(p["w_down"]))
            aux = jnp.mean(aux)

    if m.num_shared_experts:
        g = jnp.einsum("bsd,df->bsf", x, cast(p["shared_gate"]))
        u = jnp.einsum("bsd,df->bsf", x, cast(p["shared_up"]))
        act = jax.nn.silu(g) if cfg.activation == "swiglu" else jax.nn.gelu(g)
        out = out + jnp.einsum("bsf,fd->bsd", act * u, cast(p["shared_down"]))
    return shard(out, "batch", None, None), aux


def _dense_moe(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array):
    """Oracle / fallback: every token through every expert (exact, no
    capacity drops)."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_e, m.num_experts, dtype=jnp.float32), axis=1), axis=0)
    aux = jnp.sum(me * ce) * m.num_experts / m.top_k

    h = _expert_ffn(cast(p["w_gate"]), cast(p["w_up"]), cast(p["w_down"]),
                    jnp.broadcast_to(xf.astype(cast(p["w_gate"]).dtype),
                                     (m.num_experts,) + xf.shape), cfg.activation)
    gate = jnp.zeros((b * s, m.num_experts), jnp.float32)
    gate = jnp.sum(jax.nn.one_hot(top_e, m.num_experts, dtype=jnp.float32)
                   * top_p[..., None], axis=1)
    out = jnp.einsum("te,etd->td", gate, h.astype(jnp.float32))
    return out.reshape(b, s, d).astype(x.dtype), aux

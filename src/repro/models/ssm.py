"""Mamba-2 (SSD — state-space duality) mixer layer.

Implements the chunked SSD algorithm (Dao & Gu, 2024, arXiv:2405.21060):
sequence is split into chunks of Q tokens; intra-chunk outputs use the
quadratic dual form (a masked (Q, Q) kernel — MXU-friendly), inter-chunk
contributions flow through a per-chunk state recurrence (a short
`lax.scan` of length S/Q).  Decode carries (conv_state, ssm_state) and
costs O(1) per token — this is why `mamba2-1.3b` (and the Mamba layers
of Jamba) run the `long_500k` cell.

Layout: d_inner = expand*d, H = d_inner/P heads, state N per head.
Heads are sharded over `model` (TP); batch over `data`.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.distributed.sharding import shard
from .layers import cast, rmsnorm
from .params import ParamDef


def ssm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.state_dim
    return {
        # fused in_proj -> [z, x, B, C, dt]
        "in_proj": ParamDef((d, 2 * d_in + 2 * s.state_dim + nheads), ("embed", "ff")),
        "conv_w": ParamDef((s.conv_width, conv_ch), (None, "ff")),
        "conv_b": ParamDef((conv_ch,), ("ff",), init="zeros"),
        "a_log": ParamDef((nheads,), ("heads",), init="zeros"),
        "dt_bias": ParamDef((nheads,), ("heads",), init="zeros"),
        "d_skip": ParamDef((nheads,), ("heads",), init="ones"),
        "out_norm": ParamDef((d_in,), ("ff",), init="ones"),
        "out_proj": ParamDef((d_in, d), ("ff", "embed")),
    }


def _split_in_proj(cfg: ModelConfig, y: jax.Array):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    z, xbc_dt = jnp.split(y, [d_in], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [d_in + 2 * s.state_dim], axis=-1)
    return z, xbc, dt, d_in, nheads


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: Optional[jax.Array] = None):
    """Depthwise causal conv; returns (out, new_conv_state).

    xbc: (bsz, s, ch); w: (W, ch); conv_state: (bsz, W-1, ch)."""
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i][None, None, :] for i in range(W))
    out = jax.nn.silu(out + b[None, None, :].astype(out.dtype))
    new_state = xp[:, -(W - 1):] if W > 1 else jnp.zeros_like(pad)
    return out, new_state


def _ssd_chunked(x, dt, A, B, C, chunk: int, unroll=1, init=None):
    """Chunked SSD: one scan over chunks carrying the inter-chunk state.

    Per chunk the quadratic dual form runs on (Q, Q) tiles (MXU-sized);
    the body is checkpointed so training memory stays O(b*s*h*p + state)
    instead of O(b*s*Q*h) tile residuals.

    x: (b, s, h, p); dt: (b, s, h); A: (h,) (negative); B, C: (b, s, n).
    ``init`` (b, h, p, n): carried inter-chunk state (zeros when None) —
    a run split at chunk-multiple boundaries with the final state fed
    back as ``init`` replays the exact same scan steps, so chunked
    prefill stays bit-identical to a monolithic pass.
    Returns y: (b, s, h, p), final_state: (b, h, p, n).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    Q = chunk

    # (nc, b, Q, ...) scan inputs
    xq = x.reshape(b, nc, Q, h, p).transpose(1, 0, 2, 3, 4)
    dtq = dt.reshape(b, nc, Q, h).transpose(1, 0, 2, 3)
    Bq = B.reshape(b, nc, Q, n).transpose(1, 0, 2, 3)
    Cq = C.reshape(b, nc, Q, n).transpose(1, 0, 2, 3)
    ii = jnp.arange(Q)
    tril = (ii[:, None] >= ii[None, :])[None, :, :, None]     # (1,Q,Q,1)

    def body(state, inp):
        xc, dtc, Bc, Cc = inp                                 # (b,Q,...)
        dA = dtc * A[None, None, :]                           # (b,Q,h) log-decay
        cum = jnp.cumsum(dA, axis=1)
        li = cum[:, :, None, :]
        lj = cum[:, None, :, :]
        L = jnp.where(tril, jnp.exp(li - lj), 0.0)            # (b,Q,Q,h)
        G = jnp.einsum("bin,bjn->bij", Cc.astype(jnp.float32),
                       Bc.astype(jnp.float32))                # (b,Q,Q)
        xdt = xc.astype(jnp.float32) * dtc[..., None]         # (b,Q,h,p)
        y = jnp.einsum("bijh,bij,bjhp->bihp", L, G, xdt)      # intra
        dfs = jnp.exp(cum)                                    # decay from start
        y = y + jnp.einsum("bin,bhpn,bih->bihp",
                           Cc.astype(jnp.float32), state, dfs)
        dte = jnp.exp(cum[:, -1:, :] - cum)                   # decay to end
        S_c = jnp.einsum("bjn,bjh,bjhp->bhpn", Bc.astype(jnp.float32),
                         dte, xdt)
        new_state = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + S_c
        return new_state, y.astype(x.dtype)

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    if init is None:
        init = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        init = init.astype(jnp.float32)
    final_state, ys = jax.lax.scan(body, init, (xq, dtq, Bq, Cq),
                                   unroll=unroll)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * Q, h, p)[:, :s]
    return y, final_state


def ssm_layer(cfg: ModelConfig, pcfg: ParallelConfig, p: Dict[str, jax.Array],
              x: jax.Array, *, mode: str,
              cache: Optional[Tuple[jax.Array, jax.Array]] = None):
    """Mamba-2 mixer. Returns (out, new_cache).

    cache = (conv_state (b, W-1, ch), ssm_state (b, h, p, n)).
    """
    s_cfg = cfg.ssm
    y = jnp.einsum("bsd,dk->bsk", x, cast(p["in_proj"]))
    y = shard(y, "batch", None, "ff")
    z, xbc, dt, d_in, nheads = _split_in_proj(cfg, y)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))

    if mode == "decode":
        assert cache is not None
        conv_state, ssm_state = cache
        xbc_conv, new_conv = _causal_conv(xbc, cast(p["conv_w"]), p["conv_b"],
                                          conv_state)
        xx, B, C = jnp.split(xbc_conv, [d_in, d_in + s_cfg.state_dim], axis=-1)
        xh = xx.reshape(*xx.shape[:2], nheads, s_cfg.head_dim)
        # single-step recurrence (s == 1)
        dA = jnp.exp(dt[:, 0] * A[None, :])                    # (b,h)
        dBx = jnp.einsum("bn,bh,bhp->bhpn", B[:, 0].astype(jnp.float32),
                         dt[:, 0], xh[:, 0].astype(jnp.float32))
        new_state = ssm_state * dA[:, :, None, None] + dBx
        yh = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), new_state)
        yh = yh[:, None]                                       # (b,1,h,p)
        new_cache = (new_conv.astype(conv_state.dtype),
                     new_state.astype(ssm_state.dtype))
        final = None
    else:
        xbc_conv, new_conv = _causal_conv(xbc, cast(p["conv_w"]), p["conv_b"])
        xx, B, C = jnp.split(xbc_conv, [d_in, d_in + s_cfg.state_dim], axis=-1)
        xh = xx.reshape(*xx.shape[:2], nheads, s_cfg.head_dim)
        if pcfg.ssd_unroll:
            ssd_unroll = pcfg.ssd_unroll
        else:
            ssd_unroll = True if pcfg.scan_unroll else 1
        yh, final = _ssd_chunked(xh, dt, A, B, C, s_cfg.chunk_size,
                                 unroll=ssd_unroll)
        new_cache = None
        if mode == "prefill":
            new_cache = (new_conv, final)

    yh = yh.astype(x.dtype) + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    yflat = yh.reshape(*yh.shape[:2], d_in)
    yflat = rmsnorm(yflat * jax.nn.silu(z.astype(jnp.float32)).astype(yflat.dtype),
                    p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", yflat, cast(p["out_proj"]))
    return shard(out, "batch", None, None), new_cache


def ssm_layer_paged(cfg: ModelConfig, pcfg: ParallelConfig,
                    p: Dict[str, jax.Array], x: jax.Array, *,
                    lengths: jax.Array, conv_state: jax.Array,
                    ssm_state: jax.Array):
    """Length-masked Mamba-2 prefill over a padded batch with carried state
    — the paged engine's fused-prefill/chunk entry point.

    Positions >= lengths[b] contribute nothing to the recurrence: their
    dt is zeroed after softplus, so decay is exp(0) = 1 and the input
    term vanishes — the SSD scan carries each row's state through its
    pad tail unchanged.  The new conv window is gathered at each row's
    true tail rather than the padded end.  With zero carries and
    lengths == s this computes the exact same float ops as
    ``ssm_layer(mode="prefill")``; chunked callers must split at
    multiples of ``cfg.ssm.chunk_size`` so the cross-call scan regroups
    identically (the engine enforces this).

    x: (b, s, d); lengths: (b,) valid token counts; conv_state:
    (b, W-1, ch); ssm_state: (b, h, p, n).
    Returns (out, (new_conv, new_state)).
    """
    s_cfg = cfg.ssm
    y = jnp.einsum("bsd,dk->bsk", x, cast(p["in_proj"]))
    y = shard(y, "batch", None, "ff")
    z, xbc, dt, d_in, nheads = _split_in_proj(cfg, y)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    valid = jnp.arange(x.shape[1])[None, :] < lengths[:, None]     # (b,s)
    dt = jnp.where(valid[:, :, None], dt, 0.0)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))

    W = s_cfg.conv_width
    xbc_conv, _ = _causal_conv(xbc, cast(p["conv_w"]), p["conv_b"], conv_state)
    # Conv window for the next call: the last W-1 *valid* inputs of each
    # row.  Position t of the prompt sits at index t + (W-1) of the
    # padded stream, so the window starts at lengths - (W-1) + (W-1).
    xp = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    idx = lengths[:, None] + jnp.arange(W - 1)[None, :]            # (b,W-1)
    new_conv = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    new_conv = new_conv.astype(conv_state.dtype)

    xx, B, C = jnp.split(xbc_conv, [d_in, d_in + s_cfg.state_dim], axis=-1)
    xh = xx.reshape(*xx.shape[:2], nheads, s_cfg.head_dim)
    if pcfg.ssd_unroll:
        ssd_unroll = pcfg.ssd_unroll
    else:
        ssd_unroll = True if pcfg.scan_unroll else 1
    yh, final = _ssd_chunked(xh, dt, A, B, C, s_cfg.chunk_size,
                             unroll=ssd_unroll, init=ssm_state)

    yh = yh.astype(x.dtype) + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    yflat = yh.reshape(*yh.shape[:2], d_in)
    yflat = rmsnorm(yflat * jax.nn.silu(z.astype(jnp.float32)).astype(yflat.dtype),
                    p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", yflat, cast(p["out_proj"]))
    return shard(out, "batch", None, None), (new_conv, final.astype(ssm_state.dtype))

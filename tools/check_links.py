#!/usr/bin/env python3
"""Markdown link + stale-path check for the docs tree (no deps).

Checked files: README.md, ROADMAP.md, and everything under docs/.

Two classes of reference are verified:

* **Markdown links** ``[text](target)`` with a relative target (http(s)
  and mailto links are skipped): the target file must exist, resolved
  against the referencing file's directory.  Anchors (``#...``) are
  stripped.  Checked in ALL files.

* **Backticked path references** — inline code spans that look like a
  repo path (``serving/engine.py``, ``docs/serving.md``,
  ``benchmarks/serving_e2e.py``) or a module path (``repro.core.x``):
  the file must exist relative to the repo root (paths also tried under
  ``src/``; module paths resolve under ``src/`` as a module or
  package).  This is what catches stale references like
  ``serving/pim_queue.py`` after a relocation.  Only enforced for
  README.md and docs/ — ROADMAP.md narrates history ("the
  serving/pim_queue.py shim ... retired"), where a now-dead path is the
  point, not a mistake.

Exit status 0 = clean; 1 = stale references found (listed on stderr).
Run:  python tools/check_links.py   (CI's docs job does)
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN = re.compile(r"`([^`\n]+)`")
# a backticked span counts as a path reference if it is a relative path
# with at least one directory component ending in a known extension
# (bare filenames like `trace.py` are contextual, not checkable), or a
# repro.* module path
PATHLIKE = re.compile(r"^[\w][\w.-]*(?:/[\w.-]+)+\.(?:py|md|json|toml|txt|yml)$")
MODULELIKE = re.compile(r"^repro(?:\.\w+)+$")


def checked_files():
    for name in ("README.md", "ROADMAP.md"):
        p = ROOT / name
        if p.exists():
            yield p
    yield from sorted((ROOT / "docs").glob("**/*.md"))


def path_exists(ref: str) -> bool:
    if MODULELIKE.match(ref):
        rel = Path("src", *ref.split("."))
        return ((ROOT / rel).with_suffix(".py").exists()
                or (ROOT / rel / "__init__.py").exists())
    # try repo-root-relative, then the two source prefixes docs elide
    return any((ROOT / prefix / ref).exists()
               for prefix in ("", "src", "src/repro"))


def check_file(path: Path) -> list:
    errors = []
    text = path.read_text()
    for m in MD_LINK.finditer(text):
        target = m.group(1).split("#", 1)[0]
        if not target or target.startswith(("http://", "https://", "mailto:")):
            continue
        if not (path.parent / target).exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link -> {target}")
    if path.name == "ROADMAP.md":        # historical narration: links only
        return errors
    for m in CODE_SPAN.finditer(text):
        ref = m.group(1).strip()
        if not (PATHLIKE.match(ref) or MODULELIKE.match(ref)):
            continue
        if not path_exists(ref):
            errors.append(f"{path.relative_to(ROOT)}: stale path -> {ref}")
    return errors


def main() -> int:
    errors = []
    n = 0
    for path in checked_files():
        n += 1
        errors += check_file(path)
    if errors:
        print("\n".join(errors), file=sys.stderr)
        print(f"FAIL: {len(errors)} stale reference(s) across {n} file(s)",
              file=sys.stderr)
        return 1
    print(f"OK: {n} markdown file(s), all links and path references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""D-RaNGe as a system entropy source: characterize the (simulated) DRAM,
build the TRNG, and feed真 entropy into the TPU-side block generator that
powers sampling/dropout (`pimolib.rand`).

Run:  PYTHONPATH=src python examples/drange_entropy.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (DRAMGeometry, DRangeTRNG, MemoryController,
                        PimOpsController, SimulatedDRAM, characterize)
from repro.core.drange import monobit_fraction, runs_count, serial_correlation
from repro.kernels.drange import ops as dr_ops


def main():
    dev = SimulatedDRAM(DRAMGeometry(num_subarrays=8, rows_per_subarray=32))
    mc = MemoryController(dev)
    poc = PimOpsController(mc)

    print("characterizing cells under violated tRCD ...")
    cmap = characterize(mc, rows=list(range(32)), n_bits=1024, samples=100)
    print(f"  RNG cells found: {cmap.total_cells} across "
          f"{len(cmap.cells)} rows; rows with >=4 cells: "
          f"{len(cmap.rows_with(4))}")

    trng = DRangeTRNG(poc, cmap)
    bits = trng.random_bits(4096)
    print("statistical checks on 4096 true-random bits:")
    print(f"  monobit fraction : {monobit_fraction(bits):.4f}  (ideal 0.5)")
    print(f"  serial correlation: {serial_correlation(bits):+.4f} (ideal 0)")
    print(f"  runs             : {runs_count(bits)}  (ideal ~{len(bits)//2})")

    # seed the TPU-side block generator from the DRAM entropy pool
    seed = dr_ops.entropy_seed_from_trng(trng)
    block = dr_ops.pim_random_uniform(seed, 4, 8)
    print("\nTPU block generator seeded from DRAM entropy:")
    print(np.asarray(block))


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
on the synthetic-structured stream, with checkpointing and an injected
failure mid-run (the framework restarts and the loss curve continues).

Run:  PYTHONPATH=src python examples/train_lm.py  [--steps 200]

(This wraps the production launcher `repro.launch.train`; a ~100M model
is gemma-2b reduced to width 768 / 12 layers with a 32k vocab.)
"""

import sys

sys.argv = [sys.argv[0],
            "--arch", "stablelm-3b", "--reduced",
            "--width", "256", "--layers", "6",
            "--steps", "220", "--batch", "8", "--seq", "128",
            "--lr", "1e-3",
            "--ckpt-dir", "/tmp/repro_train_lm",
            "--ckpt-every", "50", "--fail-at", "110",
            "--log-every", "20"] + sys.argv[1:]

from repro.launch.train import main

if __name__ == "__main__":
    main()

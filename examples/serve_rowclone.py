"""Serving scenario: continuous batching with RowClone-backed paged KV.

Eight requests; the second four share the first request's prompt prefix
(think: same system prompt).  Prefix pages are shared (refcounted), the
divergent tails are copy-on-write RowClone page copies, freed pages are
zeroed in-memory (pim_init).

Run:  PYTHONPATH=src python examples/serve_rowclone.py
"""

import sys

sys.argv = [sys.argv[0], "--arch", "granite-3-8b", "--requests", "8",
            "--prompt-len", "24", "--max-new", "8", "--share-prefix",
            "--page-size", "8"]

from repro.launch.serve import main

if __name__ == "__main__":
    main()

"""Quickstart: the PiDRAM workflow end to end in five minutes.

1. Simulate the prototype (DDR3 device + memory controller).
2. Discover subarrays empirically (the paper's §4.2 methodology).
3. Allocate RowClone-compatible operands and copy/init in-memory.
4. Generate true random numbers with D-RaNGe.
5. Run the *same* pimolib v2 protocol on the JAX face (HBM arena +
   Pallas kernels) — one `PimLib` API, two substrates, unified
   `OpReceipt` accounting.
6. Record a serving-style trace on the JAX face and replay it on the
   model face for paper-style RowClone-vs-CPU latency totals.

Want to add your own PiM op to this protocol?  The worked, doctested
"~60 lines" recipe (register an Ambit-style op on either face in one
`register_pim_op` call) lives in the `repro/core/op_registry.py`
module docstring; `docs/ARCHITECTURE.md` maps where the op travels.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (Blocking, DRAMGeometry, DRangeTRNG, DeviceLib,
                        EndToEndCosts, MemoryController, Opcode,
                        PimOpsController, SimulatedDRAM, TpuLib,
                        allocator_from_subarray_map, characterize,
                        discover_subarrays, make_tpu_arena)


def main():
    # -- 1. prototype ---------------------------------------------------
    dev = SimulatedDRAM(DRAMGeometry(num_subarrays=8, rows_per_subarray=32))
    mc = MemoryController(dev)
    print("== PiDRAM prototype (simulated DDR3, Rocket @ 50 MHz) ==")
    sp = EndToEndCosts(mc).speedups()
    print("RowClone speedups vs memcpy/calloc:",
          {k: round(v, 1) for k, v in sp.items()})

    # -- 2. subarray discovery ------------------------------------------
    smap = discover_subarrays(mc, max_rows=64)
    print(f"discovered {smap.num_groups} subarray groups "
          f"in {smap.trials} RowClone trials")

    # -- 3. in-DRAM copy & init (model face of the PimLib protocol) ------
    alloc = allocator_from_subarray_map(smap)
    lib = DeviceLib(PimOpsController(mc), alloc)
    src, dst = alloc.alloc_copy_pair(1, tag="demo")
    payload = np.random.default_rng(0).integers(
        0, 256, (1, dev.geometry.row_bytes), dtype=np.uint8)
    lib.write(src, payload)
    rec = lib.copy(src, dst, blocking=Blocking.FIN)
    assert (lib.read(dst) == payload).all()
    print(f"RowClone-Copy: ok={rec.ok}  latency={rec.latency_ns:.0f} ns "
          f"(memcpy would be {lib.cpu_copy(src, dst).latency_ns:.0f} ns)")
    rec = lib.init(dst)
    print(f"RowClone-Init: ok={rec.ok}  latency={rec.latency_ns:.0f} ns")

    # -- 4. D-RaNGe -------------------------------------------------------
    cmap = characterize(mc, rows=list(range(32)), n_bits=1024, samples=60)
    lib.attach_trng(DRangeTRNG(lib.poc, cmap))
    print("supports(DR_GEN) after characterization:",
          lib.supports(Opcode.DR_GEN))
    bits, rec = lib.rand(64)
    print(f"D-RaNGe: 64 true-random bits in {rec.latency_ns:.0f} ns "
          f"(ones fraction {bits.mean():.2f})")

    # -- 5. JAX face: the SAME protocol over an HBM arena -----------------
    print("\n== JAX face (HBM arena + Pallas-backed pimolib) ==")
    arena = make_tpu_arena(num_slabs=2, pages_per_slab=8, page_elems=128,
                           dtype=jnp.float32)
    tlib = TpuLib(arena)
    s, d = arena.allocator.alloc_copy_pair(2)
    tlib.write(s, jnp.arange(2 * 128, dtype=jnp.float32).reshape(2, 128))
    rec = tlib.copy(s, d, blocking=Blocking.FIN)
    print(f"pim_page_copy: ok={rec.ok}  op={rec.op}  "
          f"launches={rec.launches} (coalesced)")
    print("contents match:", bool((tlib.read(d) == tlib.read(s)).all()))
    bits, rec = tlib.rand(64, seed=jnp.asarray([1, 2], jnp.uint32))
    print(f"pim_rand (D-RaNGe kernel): ones fraction {bits.mean():.2f}, "
          f"launches={rec.launches}")
    print("stats:", tlib.stats, "| queue:", tlib.queue.stats)

    # -- 6. serving trace, replayed on the model face ---------------------
    print("\n== serving trace -> model-face replay (RowClone vs CPU) ==")
    from repro.configs import ARCHS, reduced
    from repro.serving.kv_cache import PagedKVCache
    from repro.serving.trace import replay_on_device
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    cache = PagedKVCache(cfg, num_pages=16, page_size=4, num_slabs=2,
                         record_trace=True)
    seq = cache.create(0, 10)
    k = jnp.ones((cache.n_layers, 10, cfg.num_kv_heads,
                  cfg.resolved_head_dim))
    cache.write_prompt_kv(seq, k, k)     # bulk prompt KV (one launch/arena)
    cache.fork(0, 1)                     # CoW fork: RowClone page copy
    cache.free(0)
    cache.free(1)                        # init-on-free: RowClone init
    rep = replay_on_device(cache.trace)
    print("trace ops:", rep["counts"])
    print(f"pim total:  {rep['pim_ns']['total']:.0f} ns  "
          f"(rowclone_copy {rep['pim_ns']['rowclone_copy']:.0f}, "
          f"rowclone_init {rep['pim_ns']['rowclone_init']:.0f})")
    print(f"cpu total:  {rep['cpu_ns']['total']:.0f} ns")
    print("end-to-end speedup: "
          f"{rep['speedup']['end_to_end']:.2f}x "
          f"(init {rep['speedup']['init']:.1f}x)")


if __name__ == "__main__":
    main()

"""Quickstart: the PiDRAM workflow end to end in five minutes.

1. Simulate the prototype (DDR3 device + memory controller).
2. Discover subarrays empirically (the paper's §4.2 methodology).
3. Allocate RowClone-compatible operands and copy/init in-memory.
4. Generate true random numbers with D-RaNGe.
5. Run the same pimolib ops on the TPU-face (JAX arena + kernels).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (Blocking, DRAMGeometry, DRangeTRNG, DeviceLib,
                        EndToEndCosts, MemoryController, PimOpsController,
                        SimulatedDRAM, TpuLib, allocator_from_subarray_map,
                        characterize, discover_subarrays, make_tpu_arena)


def main():
    # -- 1. prototype ---------------------------------------------------
    dev = SimulatedDRAM(DRAMGeometry(num_subarrays=8, rows_per_subarray=32))
    mc = MemoryController(dev)
    print("== PiDRAM prototype (simulated DDR3, Rocket @ 50 MHz) ==")
    sp = EndToEndCosts(mc).speedups()
    print("RowClone speedups vs memcpy/calloc:",
          {k: round(v, 1) for k, v in sp.items()})

    # -- 2. subarray discovery ------------------------------------------
    smap = discover_subarrays(mc, max_rows=64)
    print(f"discovered {smap.num_groups} subarray groups "
          f"in {smap.trials} RowClone trials")

    # -- 3. in-DRAM copy & init ------------------------------------------
    alloc = allocator_from_subarray_map(smap)
    lib = DeviceLib(PimOpsController(mc), alloc)
    src, dst = alloc.alloc_copy_pair(1, tag="demo")
    payload = np.random.default_rng(0).integers(
        0, 256, dev.geometry.row_bytes, dtype=np.uint8)
    dev.write_row(src.rows[0], payload)
    rec = lib.copy(src, dst, blocking=Blocking.FIN)
    assert (dev.read_row(dst.rows[0]) == payload).all()
    print(f"RowClone-Copy: ok={rec.ok}  latency={rec.latency_ns:.0f} ns "
          f"(memcpy would be {lib.cpu_copy(src, dst).latency_ns:.0f} ns)")
    rec = lib.init(dst)
    print(f"RowClone-Init: ok={rec.ok}  latency={rec.latency_ns:.0f} ns")

    # -- 4. D-RaNGe -------------------------------------------------------
    cmap = characterize(mc, rows=list(range(32)), n_bits=1024, samples=60)
    trng = DRangeTRNG(lib.poc, cmap)
    bits, rec = lib.rand_dram(64, trng)
    print(f"D-RaNGe: 64 true-random bits in {rec.latency_ns:.0f} ns "
          f"(ones fraction {bits.mean():.2f})")

    # -- 5. TPU face ------------------------------------------------------
    print("\n== TPU face (JAX arena + Pallas-backed pimolib) ==")
    arena = make_tpu_arena(num_slabs=2, pages_per_slab=8, page_elems=128,
                           dtype=jnp.float32)
    tlib = TpuLib(arena)
    s, d = arena.allocator.alloc_copy_pair(2)
    tlib.write_pages(s, jnp.arange(2 * 128, dtype=jnp.float32).reshape(2, 128))
    tlib.copy_pages(s, d, blocking=Blocking.FIN)
    print("pim_page_copy ok:",
          bool((tlib.read_pages(d) == tlib.read_pages(s)).all()))
    r = tlib.rand(jnp.asarray([1, 2], jnp.uint32), 2, 4)
    print("pim_rand (D-RaNGe kernel):", np.asarray(r)[0])
    print("stats:", tlib.stats)


if __name__ == "__main__":
    main()

"""Serving: paged engine vs dense decode, CoW forking, prefix sharing,
page lifecycle security (pim_init on free), allocator integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ParallelConfig, reduced
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request
from repro.serving.kv_cache import PagedKVCache

PCFG = ParallelConfig(attention_impl="naive", remat="none")


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def greedy_dense(cfg, params, prompt, new):
    toks = jnp.asarray(prompt)[None]
    n = len(prompt)
    cache = T.init_cache(cfg, 1, n + new + 1)
    lg, cache, _ = T.forward(cfg, PCFG, params, {"tokens": toks},
                             mode="prefill", cache=cache,
                             lengths=jnp.asarray([n], jnp.int32))
    out = [int(jnp.argmax(lg[0, 0]))]
    for t in range(new - 1):
        pos = n + t
        lg, cache = T.forward(cfg, PCFG, params,
                              {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
                              mode="decode", cache=cache,
                              write_pos=jnp.asarray(pos),
                              lengths=jnp.asarray([pos + 1], jnp.int32))
        out.append(int(jnp.argmax(lg[0, 0])))
    return out


class TestPagedEngine:
    def test_matches_dense_greedy(self, model, rng):
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
        ref = greedy_dense(cfg, params, prompt, 5)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        eng.submit(Request(0, prompt, max_new_tokens=5, temperature=0.0))
        assert eng.run()[0] == ref

    @pytest.mark.slow
    def test_batched_requests_isolated(self, model, rng):
        cfg, params = model
        p1 = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
        p2 = rng.integers(0, cfg.vocab_size, 14).astype(np.int32)
        ref1 = greedy_dense(cfg, params, p1, 4)
        ref2 = greedy_dense(cfg, params, p2, 4)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        eng.submit(Request(0, p1, max_new_tokens=4, temperature=0.0))
        eng.submit(Request(1, p2, max_new_tokens=4, temperature=0.0))
        res = eng.run()
        assert res[0] == ref1 and res[1] == ref2

    def test_prefix_sharing_and_page_accounting(self, model, rng):
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        eng.submit(Request(0, prompt, max_new_tokens=3, temperature=0.0))
        eng.submit(Request(1, prompt, max_new_tokens=3, temperature=0.0,
                           share_with=0, shared_len=12))
        res = eng.run()
        assert res[0] == res[1]
        assert eng.cache.stats["prefix_hits"] == 1
        assert eng.cache.pages_in_use == 0  # everything freed

    def test_pages_zeroed_on_free(self, model, rng):
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=16)
        eng.submit(Request(0, prompt, max_new_tokens=2, temperature=0.0))
        eng.run()
        assert eng.cache.stats["pages_zeroed"] > 0
        # the arena holds no residual data (security property)
        assert float(jnp.abs(eng.cache.k_arena).sum()) == 0.0
        assert float(jnp.abs(eng.cache.v_arena).sum()) == 0.0


class TestKVCacheUnit:
    def test_fork_cow_semantics(self, model):
        cfg, _ = model
        cache = PagedKVCache(cfg, num_pages=32, page_size=4)
        seq = cache.create(0, 10)  # 3 pages (2 full + 1 partial)
        k = jnp.ones((cache.n_layers, cfg.num_kv_heads, cfg.resolved_head_dim))
        forked = cache.fork(0, 1)
        assert cache.stats["cow_copies"] == 1     # partial tail copied
        assert forked.pages[:2] == cache.seqs[0].pages[:2]  # shared
        assert forked.pages[2] != cache.seqs[0].pages[2]    # CoW'd
        # appending to the original does not affect the fork
        cache.append_token_kv(cache.seqs[0], k, k)
        assert cache.seqs[1].length == 10

    def test_same_slab_preference(self, model):
        cfg, _ = model
        cache = PagedKVCache(cfg, num_pages=32, page_size=4, num_slabs=4)
        seq = cache.create(0, 16)
        groups = {cache.page_alloc[p].group for p in seq.pages}
        assert len(groups) == 1  # RowClone-constraint honoured

    def test_out_of_pages_raises(self, model):
        from repro.core.allocator import PimAllocError
        cfg, _ = model
        cache = PagedKVCache(cfg, num_pages=8, page_size=4)
        cache.create(0, 8 * 4)
        with pytest.raises(PimAllocError):
            cache.create(1, 8)


class TestDispatchCounts:
    """Regression: arena mutations cost a CONSTANT number of kernel
    launches, independent of num_layers and active-batch size (the
    batched PiM op scheduler's contract)."""

    @staticmethod
    def _cache(layers, **kw):
        cfg = reduced(ARCHS["granite-3-8b"], num_layers=layers)
        return cfg, PagedKVCache(cfg, num_pages=32, page_size=4, **kw)

    def test_cow_fork_launches_independent_of_layers(self):
        counts = []
        for layers in (1, 2, 4):
            _, cache = self._cache(layers)
            cache.create(0, 10)       # 2 full pages + partial tail
            base = cache.queue.stats["launches"]
            cache.fork(0, 1)
            counts.append(cache.queue.stats["launches"] - base)
        assert len(set(counts)) == 1, counts
        assert counts[0] == 2         # one batched copy per arena (k, v)

    def test_page_free_launches_independent_of_layers_and_size(self):
        counts = []
        for layers, prompt_len in ((1, 6), (2, 6), (4, 6), (2, 26)):
            _, cache = self._cache(layers)
            cache.create(0, prompt_len)
            base = cache.queue.stats["launches"]
            cache.free(0)
            counts.append(cache.queue.stats["launches"] - base)
        # 1..7 dead pages, 1..4 layers -> always one batched init per arena
        assert set(counts) == {2}, counts

    def test_prompt_write_launches_independent_of_length_and_layers(self):
        counts = []
        for layers, n in ((1, 3), (2, 9), (4, 14)):
            cfg, cache = self._cache(layers)
            seq = cache.create(0, n)
            k = jnp.ones((cache.n_layers, n, cfg.num_kv_heads,
                          cfg.resolved_head_dim))
            base = cache.queue.stats["launches"]
            cache.write_prompt_kv(seq, k, k)
            counts.append(cache.queue.stats["launches"] - base)
        assert set(counts) == {2}, counts   # one KV scatter per arena

    @staticmethod
    def _decode_round_launches(layers, nreqs, rng):
        cfg = reduced(ARCHS["granite-3-8b"], num_layers=layers)
        params = init_params(T.model_defs(cfg), jax.random.PRNGKey(1))
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        for i in range(nreqs):
            prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
            eng.submit(Request(i, prompt, max_new_tokens=4, temperature=0.0))
        while eng.queue:
            eng._prefill(eng.queue.pop(0))
        base = eng.cache.queue.stats["launches"]
        eng._decode_round()
        return eng.cache.queue.stats["launches"] - base

    def test_decode_round_launches_independent_of_layers_and_batch(self, rng):
        a = self._decode_round_launches(1, 1, rng)
        b = self._decode_round_launches(2, 3, rng)
        assert a == b, (a, b)
        # the fused round is ONE dispatch (forward + scatter + sampling
        # in a single jit); a CoW flush would add two more when forking
        assert b <= 2

    def test_full_prefix_hit_writes_nothing(self):
        # a prompt fully covered by a shared prefix enqueues an empty KV
        # batch -> no launch, no flush, counters stay truthful
        cfg, cache = self._cache(2)
        seq0 = cache.create(0, 8)
        k = jnp.ones((cache.n_layers, 8, cfg.num_kv_heads,
                      cfg.resolved_head_dim))
        cache.write_prompt_kv(seq0, k, k)
        cache.create(1, 8, share_with=0, shared_len=8)
        base = dict(cache.queue.stats)
        cache.write_prompt_kv(cache.seqs[1], k[:, 8:], k[:, 8:], start=8)
        assert cache.queue.stats == base

    def test_queue_coalesces_ops(self):
        _, cache = self._cache(2)
        cache.create(0, 26)           # 7 pages
        cache.free(0)
        q = cache.queue.stats
        assert q["ops_enqueued"] == 7                 # 7 page inits...
        assert cache.queue.launches_by_kind["page_init"] == 2  # ...2 launches

    @staticmethod
    def _fused_prefill_launches(layers, nreqs, prompt_len, rng):
        cfg = reduced(ARCHS["granite-3-8b"], num_layers=layers)
        params = init_params(T.model_defs(cfg), jax.random.PRNGKey(1))
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        for i in range(nreqs):
            prompt = rng.integers(0, cfg.vocab_size, prompt_len)
            eng.submit(Request(i, prompt.astype(np.int32), max_new_tokens=1,
                               temperature=0.0))
        base = eng.cache.queue.stats["launches"]
        eng._prefill_round()
        assert eng.cache.queue.launches_by_kind["fused_prefill"] == 1
        return eng.cache.queue.stats["launches"] - base

    def test_fused_prefill_launches_independent_of_layers_and_batch(self, rng):
        """A same-bucket prefill batch is ONE dispatch (forward + KV
        scatter + sampling in a single jit, accounted as the
        ``fused_prefill`` kind) no matter how many layers the model has,
        how many requests stack into the batch, or how long the prompts
        are."""
        counts = [self._fused_prefill_launches(layers, nreqs, plen, rng)
                  for layers, nreqs, plen in
                  ((1, 1, 7), (2, 3, 7), (4, 2, 14))]
        assert set(counts) == {1}, counts

    def test_chunked_prefill_chunks_account_as_fused_prefill(self, rng):
        """Every chunk batch is ONE dispatch accounted under the same
        ``fused_prefill`` kind as monolithic batches — a 3-chunk prompt
        shows 3 fused_prefill launches and nothing else, independent of
        layer count."""
        for layers in (1, 2):
            cfg = reduced(ARCHS["granite-3-8b"], num_layers=layers)
            params = init_params(T.model_defs(cfg), jax.random.PRNGKey(1))
            eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                              max_prefill_chunk=8)
            prompt = rng.integers(0, cfg.vocab_size, 22).astype(np.int32)
            eng.submit(Request(0, prompt, max_new_tokens=1, temperature=0.0))
            base = eng.cache.queue.stats["launches"]
            base_kind = eng.cache.queue.launches_by_kind.get("fused_prefill", 0)
            while eng.queue or eng._chunk_q:
                eng._prefill_tick()
            assert (eng.cache.queue.launches_by_kind["fused_prefill"]
                    - base_kind == 3)
            assert eng.cache.queue.stats["launches"] - base == 3
            assert eng.stats["prefill_chunks"] == 3

    def test_k_block_decode_under_one_dispatch_per_token(self, rng):
        """Dispatches-per-token regression for the persistent decode
        loop: after warmup, a 32-round pure-decode workload at K=8 folds
        every 8 rounds into ONE ``fused_decode_block`` launch — 4
        dispatches for 64 tokens, well under 1 per token."""
        cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
        params = init_params(T.model_defs(cfg), jax.random.PRNGKey(1))
        eng = PagedEngine(cfg, params, page_size=4, num_pages=128,
                          decode_block_rounds=8)
        nreqs = 2
        for i in range(nreqs):
            prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
            eng.submit(Request(i, prompt, max_new_tokens=64,
                               temperature=0.0))
        eng.run(max_rounds=9)           # warmup: prefill + first block
        assert len(eng.active) == nreqs
        before = eng.cache.queue.snapshot()
        base_tokens = eng.stats["tokens_out"]
        eng.run(max_rounds=32)          # pure decode, nothing queued
        delta = eng.cache.queue.delta(before)
        tokens = eng.stats["tokens_out"] - base_tokens
        assert delta == {"fused_decode_block": 4}, delta
        assert tokens == 32 * nreqs
        dispatches_per_token = sum(delta.values()) / tokens
        assert dispatches_per_token < 1.0
        assert eng.stats["multi_round_blocks"] >= 5

    def test_mixed_round_is_exactly_one_dispatch(self, rng):
        """A round running a chunk batch AND the decode round costs
        exactly ONE launch (the ``fused_mixed`` kind) — and the chunked
        scheduler keeps ``decode_stall_rounds`` at 0 throughout."""
        cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
        params = init_params(T.model_defs(cfg), jax.random.PRNGKey(1))
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                          max_prefill_chunk=8)
        prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
        eng.submit(Request(0, prompt, max_new_tokens=32, temperature=0.0))
        eng.run(max_rounds=2)           # request 0 is now mid-decode
        assert sorted(eng.active) == [0]
        # a long prompt arrives: its chunk rides the decode dispatch
        long_prompt = rng.integers(0, cfg.vocab_size, 22).astype(np.int32)
        eng.submit(Request(1, long_prompt, max_new_tokens=4,
                           temperature=0.0))
        before = eng.cache.queue.snapshot()
        base_mixed = eng.stats["mixed_dispatches"]
        eng.run(max_rounds=1)
        delta = eng.cache.queue.delta(before)
        assert delta == {"fused_mixed": 1}, delta
        assert eng.stats["mixed_dispatches"] == base_mixed + 1
        assert eng.stats["decode_stall_rounds"] == 0

    @staticmethod
    def _state_engine(arch, rng, *, nreqs=2, budget=4, **kw):
        over = {k: kw.pop(k) for k in ("num_layers", "attn_every")
                if k in kw}
        cfg = reduced(ARCHS[arch], **over)
        params = init_params(T.model_defs(cfg), jax.random.PRNGKey(1))
        eng = PagedEngine(cfg, params, page_size=4, num_pages=128, **kw)
        for i in range(nreqs):
            prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
            eng.submit(Request(i, prompt, max_new_tokens=budget,
                               temperature=0.0))
        return eng

    def test_hybrid_decode_round_is_one_dispatch(self, rng):
        """A hybrid decode round stays ONE dispatch: the in-scan state
        scatter and in-jit MoE routing ride the fused step, so no
        ``ssm_state_write`` (or any other) launch appears next to the
        single ``fused_decode``."""
        for arch, kw in (("mamba2-1.3b", dict(num_layers=2)),
                         ("jamba-1.5-large-398b",
                          dict(num_layers=4, attn_every=4))):
            eng = self._state_engine(arch, rng, **kw)
            while eng.queue:
                eng._prefill(eng.queue.pop(0))
            eng.cache.flush_pending()
            before = eng.cache.queue.snapshot()
            eng._decode_round()
            delta = eng.cache.queue.delta(before)
            assert delta == {"fused_decode": 1}, (arch, delta)

    def test_eager_state_write_launches_constant_in_layers_and_batch(
            self, rng):
        """The eager oracle pays the ``SSM_STATE_WRITE`` opcode's real
        price — and that price is one coalesced flush per round (2
        launches: conv + ssm arena), independent of depth and batch."""
        counts = []
        for layers, nreqs in ((1, 1), (2, 3)):
            eng = self._state_engine("mamba2-1.3b", rng, nreqs=nreqs,
                                     num_layers=layers, fused=False,
                                     fused_prefill=False)
            while eng.queue:
                eng._prefill(eng.queue.pop(0))
            eng.cache.flush_pending()
            before = eng.cache.queue.snapshot()
            eng._decode_round()
            counts.append(eng.cache.queue.delta(before)["ssm_state_write"])
        assert set(counts) == {2}, counts

    def test_k_block_hybrid_decode_under_one_dispatch_per_token(self, rng):
        """The persistent decode loop holds its dispatches-per-token win
        on state-arena layouts: 16 pure-decode rounds at K=8 fold into 2
        ``fused_decode_block`` launches."""
        eng = self._state_engine("mamba2-1.3b", rng, num_layers=2,
                                 budget=48, decode_block_rounds=8)
        eng.run(max_rounds=9)           # warmup: prefills + first block
        assert len(eng.active) == 2
        before = eng.cache.queue.snapshot()
        base_tokens = eng.stats["tokens_out"]
        eng.run(max_rounds=16)          # pure decode, nothing queued
        delta = eng.cache.queue.delta(before)
        tokens = eng.stats["tokens_out"] - base_tokens
        assert delta == {"fused_decode_block": 2}, delta
        assert tokens == 16 * 2
        assert sum(delta.values()) / tokens < 1.0


class TestFusedDecode:
    """The fused single-dispatch decode round: jitted scan-over-layers
    with in-kernel self-token merge and in-jit scatter + sampling."""

    def test_fused_matches_eager_tokens(self, model, rng):
        cfg, params = model
        p1 = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
        p2 = rng.integers(0, cfg.vocab_size, 13).astype(np.int32)
        outs = []
        for fused in (True, False):
            eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                              fused=fused)
            eng.submit(Request(0, p1, max_new_tokens=4, temperature=0.0))
            eng.submit(Request(1, p2, max_new_tokens=4, temperature=0.0))
            res = eng.run()
            outs.append((tuple(res[0]), tuple(res[1])))
        assert outs[0] == outs[1]

    def test_scan_forward_matches_eager_logits(self, model, rng):
        from repro.serving import engine as E
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        for i, n in enumerate((9, 14)):
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            eng.submit(Request(i, prompt, max_new_tokens=4, temperature=0.0))
        while eng.queue:
            eng._prefill(eng.queue.pop(0))
        rids = sorted(eng.active)
        for r in rids:
            eng.cache.ensure_writable_tail(eng.cache.seqs[r])
        eng.cache.flush_pending()
        last = jnp.asarray([[eng.active[r].out_tokens[-1]] for r in rids],
                           jnp.int32)
        bt, lens = eng.cache.block_table(rids)
        args = (cfg, eng.pcfg, params, last, eng.cache.k_arena,
                eng.cache.v_arena, bt, lens)
        lg_s, k_s, v_s, _, _ = E._paged_decode_forward(
            *args, use_pallas=False, interpret=True)
        lg_e, k_e, v_e, _, _ = E._eager_decode_forward(
            *args, use_pallas=False, interpret=True)
        # fp32 logits over bf16 activations: scan vs unrolled loops may
        # fuse/round differently, so parity holds at bf16 resolution
        np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_e),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(k_s, np.float32),
                                   np.asarray(k_e, np.float32),
                                   rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(np.asarray(v_s, np.float32),
                                   np.asarray(v_e, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_recompilation_bounded_over_growing_rounds(self, model, rng):
        """20 decode rounds with growing sequences and a mid-flight
        arrival: block-table/batch bucketing keeps jit retraces at
        power-of-two boundaries, not one per round."""
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=128)
        p0 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
        eng.submit(Request(0, p0, max_new_tokens=30, temperature=0.0))
        while eng.queue:
            eng._prefill(eng.queue.pop(0))
        for _ in range(8):
            eng._decode_round()
        # a second request joins between rounds (batch grows / "forks")
        p1 = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
        eng.submit(Request(1, p1, max_new_tokens=30, temperature=0.0))
        while eng.queue:
            eng._prefill(eng.queue.pop(0))
        for _ in range(7):
            eng._decode_round()
        traces_mid = eng.stats["jit_traces"]
        for _ in range(5):
            eng._decode_round()
        assert eng.stats["decode_rounds"] == 20
        assert eng.stats["jit_traces"] <= 5, eng.stats
        # steady state: page/batch buckets stable -> no further retraces
        assert eng.stats["jit_traces"] == traces_mid

    def test_fused_round_is_one_dispatch_after_warmup(self, model, rng):
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        for i in range(2):
            prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
            eng.submit(Request(i, prompt, max_new_tokens=6, temperature=0.0))
        while eng.queue:
            eng._prefill(eng.queue.pop(0))
        eng._decode_round()                      # warmup (traces)
        base = eng.cache.queue.stats["launches"]
        eng._decode_round()
        assert eng.cache.queue.stats["launches"] - base == 1
        assert eng.cache.queue.launches_by_kind["fused_decode"] == 2


class TestSampling:
    def test_temperature_zero_deterministic(self, model, rng):
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        outs = []
        for _ in range(2):
            eng = PagedEngine(cfg, params, page_size=4, num_pages=32)
            eng.submit(Request(0, prompt, max_new_tokens=4, temperature=0.0))
            outs.append(tuple(eng.run()[0]))
        assert outs[0] == outs[1]

    def test_sampled_tokens_vary_with_seed(self, model, rng):
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        outs = set()
        for seed in range(3):
            eng = PagedEngine(cfg, params, page_size=4, num_pages=32, seed=seed)
            eng.submit(Request(0, prompt, max_new_tokens=6, temperature=2.0))
            outs.add(tuple(eng.run()[0]))
        assert len(outs) > 1

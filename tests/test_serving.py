"""Serving: paged engine vs dense decode, CoW forking, prefix sharing,
page lifecycle security (pim_init on free), allocator integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ParallelConfig, reduced
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request
from repro.serving.kv_cache import PagedKVCache

PCFG = ParallelConfig(attention_impl="naive", remat="none")


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def greedy_dense(cfg, params, prompt, new):
    toks = jnp.asarray(prompt)[None]
    n = len(prompt)
    cache = T.init_cache(cfg, 1, n + new + 1)
    lg, cache, _ = T.forward(cfg, PCFG, params, {"tokens": toks},
                             mode="prefill", cache=cache,
                             lengths=jnp.asarray([n], jnp.int32))
    out = [int(jnp.argmax(lg[0, 0]))]
    for t in range(new - 1):
        pos = n + t
        lg, cache = T.forward(cfg, PCFG, params,
                              {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
                              mode="decode", cache=cache,
                              write_pos=jnp.asarray(pos),
                              lengths=jnp.asarray([pos + 1], jnp.int32))
        out.append(int(jnp.argmax(lg[0, 0])))
    return out


class TestPagedEngine:
    def test_matches_dense_greedy(self, model, rng):
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
        ref = greedy_dense(cfg, params, prompt, 5)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        eng.submit(Request(0, prompt, max_new_tokens=5, temperature=0.0))
        assert eng.run()[0] == ref

    @pytest.mark.slow
    def test_batched_requests_isolated(self, model, rng):
        cfg, params = model
        p1 = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
        p2 = rng.integers(0, cfg.vocab_size, 14).astype(np.int32)
        ref1 = greedy_dense(cfg, params, p1, 4)
        ref2 = greedy_dense(cfg, params, p2, 4)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        eng.submit(Request(0, p1, max_new_tokens=4, temperature=0.0))
        eng.submit(Request(1, p2, max_new_tokens=4, temperature=0.0))
        res = eng.run()
        assert res[0] == ref1 and res[1] == ref2

    def test_prefix_sharing_and_page_accounting(self, model, rng):
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        eng.submit(Request(0, prompt, max_new_tokens=3, temperature=0.0))
        eng.submit(Request(1, prompt, max_new_tokens=3, temperature=0.0,
                           share_with=0, shared_len=12))
        res = eng.run()
        assert res[0] == res[1]
        assert eng.cache.stats["prefix_hits"] == 1
        assert eng.cache.pages_in_use == 0  # everything freed

    def test_pages_zeroed_on_free(self, model, rng):
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=16)
        eng.submit(Request(0, prompt, max_new_tokens=2, temperature=0.0))
        eng.run()
        assert eng.cache.stats["pages_zeroed"] > 0
        # the arena holds no residual data (security property)
        assert float(jnp.abs(eng.cache.k_arena).sum()) == 0.0
        assert float(jnp.abs(eng.cache.v_arena).sum()) == 0.0


class TestKVCacheUnit:
    def test_fork_cow_semantics(self, model):
        cfg, _ = model
        cache = PagedKVCache(cfg, num_pages=32, page_size=4)
        seq = cache.create(0, 10)  # 3 pages (2 full + 1 partial)
        k = jnp.ones((cache.n_layers, cfg.num_kv_heads, cfg.resolved_head_dim))
        forked = cache.fork(0, 1)
        assert cache.stats["cow_copies"] == 1     # partial tail copied
        assert forked.pages[:2] == cache.seqs[0].pages[:2]  # shared
        assert forked.pages[2] != cache.seqs[0].pages[2]    # CoW'd
        # appending to the original does not affect the fork
        cache.append_token_kv(cache.seqs[0], k, k)
        assert cache.seqs[1].length == 10

    def test_same_slab_preference(self, model):
        cfg, _ = model
        cache = PagedKVCache(cfg, num_pages=32, page_size=4, num_slabs=4)
        seq = cache.create(0, 16)
        groups = {cache.page_alloc[p].group for p in seq.pages}
        assert len(groups) == 1  # RowClone-constraint honoured

    def test_out_of_pages_raises(self, model):
        from repro.core.allocator import PimAllocError
        cfg, _ = model
        cache = PagedKVCache(cfg, num_pages=8, page_size=4)
        cache.create(0, 8 * 4)
        with pytest.raises(PimAllocError):
            cache.create(1, 8)


class TestDispatchCounts:
    """Regression: arena mutations cost a CONSTANT number of kernel
    launches, independent of num_layers and active-batch size (the
    batched PiM op scheduler's contract)."""

    @staticmethod
    def _cache(layers, **kw):
        cfg = reduced(ARCHS["granite-3-8b"], num_layers=layers)
        return cfg, PagedKVCache(cfg, num_pages=32, page_size=4, **kw)

    def test_cow_fork_launches_independent_of_layers(self):
        counts = []
        for layers in (1, 2, 4):
            _, cache = self._cache(layers)
            cache.create(0, 10)       # 2 full pages + partial tail
            base = cache.queue.stats["launches"]
            cache.fork(0, 1)
            counts.append(cache.queue.stats["launches"] - base)
        assert len(set(counts)) == 1, counts
        assert counts[0] == 2         # one batched copy per arena (k, v)

    def test_page_free_launches_independent_of_layers_and_size(self):
        counts = []
        for layers, prompt_len in ((1, 6), (2, 6), (4, 6), (2, 26)):
            _, cache = self._cache(layers)
            cache.create(0, prompt_len)
            base = cache.queue.stats["launches"]
            cache.free(0)
            counts.append(cache.queue.stats["launches"] - base)
        # 1..7 dead pages, 1..4 layers -> always one batched init per arena
        assert set(counts) == {2}, counts

    def test_prompt_write_launches_independent_of_length_and_layers(self):
        counts = []
        for layers, n in ((1, 3), (2, 9), (4, 14)):
            cfg, cache = self._cache(layers)
            seq = cache.create(0, n)
            k = jnp.ones((cache.n_layers, n, cfg.num_kv_heads,
                          cfg.resolved_head_dim))
            base = cache.queue.stats["launches"]
            cache.write_prompt_kv(seq, k, k)
            counts.append(cache.queue.stats["launches"] - base)
        assert set(counts) == {2}, counts   # one KV scatter per arena

    @staticmethod
    def _decode_round_launches(layers, nreqs, rng):
        cfg = reduced(ARCHS["granite-3-8b"], num_layers=layers)
        params = init_params(T.model_defs(cfg), jax.random.PRNGKey(1))
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64)
        for i in range(nreqs):
            prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
            eng.submit(Request(i, prompt, max_new_tokens=4, temperature=0.0))
        while eng.queue:
            eng._prefill(eng.queue.pop(0))
        base = eng.cache.queue.stats["launches"]
        eng._decode_round()
        return eng.cache.queue.stats["launches"] - base

    def test_decode_round_launches_independent_of_layers_and_batch(self, rng):
        a = self._decode_round_launches(1, 1, rng)
        b = self._decode_round_launches(2, 3, rng)
        assert a == b, (a, b)
        # at most: CoW-copy flush + KV-scatter flush, two arenas each
        assert b <= 4

    def test_full_prefix_hit_writes_nothing(self):
        # a prompt fully covered by a shared prefix enqueues an empty KV
        # batch -> no launch, no flush, counters stay truthful
        cfg, cache = self._cache(2)
        seq0 = cache.create(0, 8)
        k = jnp.ones((cache.n_layers, 8, cfg.num_kv_heads,
                      cfg.resolved_head_dim))
        cache.write_prompt_kv(seq0, k, k)
        cache.create(1, 8, share_with=0, shared_len=8)
        base = dict(cache.queue.stats)
        cache.write_prompt_kv(cache.seqs[1], k[:, 8:], k[:, 8:], start=8)
        assert cache.queue.stats == base

    def test_queue_coalesces_ops(self):
        _, cache = self._cache(2)
        cache.create(0, 26)           # 7 pages
        cache.free(0)
        q = cache.queue.stats
        assert q["ops_enqueued"] == 7                 # 7 page inits...
        assert cache.queue.launches_by_kind["page_init"] == 2  # ...2 launches


class TestSampling:
    def test_temperature_zero_deterministic(self, model, rng):
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        outs = []
        for _ in range(2):
            eng = PagedEngine(cfg, params, page_size=4, num_pages=32)
            eng.submit(Request(0, prompt, max_new_tokens=4, temperature=0.0))
            outs.append(tuple(eng.run()[0]))
        assert outs[0] == outs[1]

    def test_sampled_tokens_vary_with_seed(self, model, rng):
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        outs = set()
        for seed in range(3):
            eng = PagedEngine(cfg, params, page_size=4, num_pages=32, seed=seed)
            eng.submit(Request(0, prompt, max_new_tokens=6, temperature=2.0))
            outs.add(tuple(eng.run()[0]))
        assert len(outs) > 1

"""Training substrate: optimizers, schedules, microbatching, loss descent,
fused LM head."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _compat import given, settings, st

from repro.configs import ARCHS, OptimizerConfig, ParallelConfig, reduced
from repro.models import transformer as T
from repro.models.lm_head import fused_xent, IGNORE
from repro.models.params import init_params
from repro.training import optimizer as O
from repro.training.train_step import make_train_step, make_loss_fn


class TestOptimizers:
    def test_adamw_first_step_matches_reference(self):
        ocfg = OptimizerConfig(lr=1e-2, warmup_steps=1, total_steps=100,
                               weight_decay=0.0)
        p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
        g = {"w": jnp.asarray([0.1, -0.2, 0.3])}
        opt = O.adamw_init(p)
        newp, newopt = O.adamw_update(p, g, opt, ocfg)
        # step1: m_hat = g, v_hat = g^2 -> update = g/(|g|+eps) = sign(g)
        lr1 = float(O.lr_schedule(ocfg)(jnp.asarray(1)))
        np.testing.assert_allclose(
            np.asarray(newp["w"]),
            np.asarray(p["w"]) - lr1 * np.sign(np.asarray(g["w"])), rtol=1e-4)

    def test_adamw_converges_quadratic(self):
        ocfg = OptimizerConfig(lr=0.05, warmup_steps=5, total_steps=400,
                               weight_decay=0.0)
        p = {"w": jnp.asarray([5.0, -3.0])}
        opt = O.adamw_init(p)
        for _ in range(400):
            g = {"w": 2 * p["w"]}
            p, opt = O.adamw_update(p, g, opt, ocfg)
        assert float(jnp.abs(p["w"]).max()) < 0.05

    def test_adafactor_converges_quadratic(self):
        ocfg = OptimizerConfig(name="adafactor", lr=0.05, warmup_steps=5,
                               total_steps=400, weight_decay=0.0)
        p = {"w": jnp.ones((4, 3)) * 3.0}
        opt = O.adafactor_init(p)
        for _ in range(300):
            g = {"w": 2 * p["w"]}
            p, opt = O.adafactor_update(p, g, opt, ocfg)
        assert float(jnp.abs(p["w"]).max()) < 0.1

    def test_bf16_state_dtype(self):
        p = {"w": jnp.ones((8,))}
        opt = O.adamw_init(p, state_dtype=jnp.bfloat16)
        assert opt["m"]["w"].dtype == jnp.bfloat16

    @settings(max_examples=10, deadline=None)
    @given(norm=st.floats(0.1, 100.0))
    def test_clip_by_global_norm(self, norm):
        g = {"a": jnp.ones((7,)) * norm}
        clipped, gn = O.clip_by_global_norm(g, 1.0)
        out_norm = float(O.global_norm(clipped))
        assert out_norm <= 1.0 + 1e-4

    def test_lr_schedule_shape(self):
        ocfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
        f = O.lr_schedule(ocfg)
        assert float(f(jnp.asarray(0))) == 0.0
        assert abs(float(f(jnp.asarray(10))) - 1.0) < 1e-5
        assert float(f(jnp.asarray(100))) < 0.11


class TestFusedHead:
    @settings(max_examples=8, deadline=None)
    @given(b=st.integers(1, 3), s=st.integers(3, 40), v=st.integers(7, 99),
           chunk=st.sampled_from([4, 8, 512]))
    def test_matches_naive(self, b, s, v, chunk):
        rng = np.random.default_rng(s * 7 + v)
        d = 8
        x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
        W = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, v, (b, s)).astype(np.int32))
        labels = labels.at[0, 0].set(IGNORE)

        def naive(x, W):
            logits = jnp.einsum("bsd,vd->bsv", x, W)
            mask = labels != IGNORE
            safe = jnp.where(mask, labels, 0)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, safe[..., None], -1)[..., 0]
            return jnp.sum((logz - gold) * mask)

        f = lambda x, W: fused_xent(x, W, labels, chunk)[0]
        np.testing.assert_allclose(f(x, W), naive(x, W), rtol=2e-5)
        gf = jax.grad(f, (0, 1))(x, W)
        gn = jax.grad(naive, (0, 1))(x, W)
        np.testing.assert_allclose(gf[0], gn[0], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gf[1], gn[1], rtol=1e-4, atol=1e-5)


class TestTrainStep:
    def test_microbatching_equivalent(self, key):
        r = reduced(ARCHS["stablelm-3b"])
        params = init_params(T.model_defs(r), key)
        batch = {"tokens": jax.random.randint(key, (4, 16), 0, r.vocab_size)}
        batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
        o = OptimizerConfig(warmup_steps=1, total_steps=10)
        outs = {}
        for mb in (1, 2):
            pcfg = ParallelConfig(remat="none", attention_impl="naive",
                                  microbatches=mb)
            init_state, step = make_train_step(r, pcfg, o)
            st_, m = jax.jit(step)(init_state(params), batch)
            outs[mb] = (st_, float(m["loss"]))
        assert abs(outs[1][1] - outs[2][1]) < 1e-3
        l1 = jax.tree.leaves(outs[1][0]["params"])
        l2 = jax.tree.leaves(outs[2][0]["params"])
        for a, b in zip(l1, l2):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-3)

    def test_loss_decreases_on_learnable_stream(self, key):
        from repro.configs import ShapeConfig
        from repro.data.pipeline import PipelineConfig, SyntheticLM
        r = reduced(ARCHS["stablelm-3b"], num_layers=2, d_model=64,
                    d_ff=128, vocab_size=256)
        shape = ShapeConfig("t", 64, 8, "train")
        data = SyntheticLM(r, shape, PipelineConfig(seed=3))
        pcfg = ParallelConfig(remat="none", attention_impl="chunked",
                              attention_chunk=32)
        init_state, step = make_train_step(
            r, pcfg, OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=60))
        state = init_state(init_params(T.model_defs(r), key))
        jstep = jax.jit(step, donate_argnums=(0,))
        losses = []
        for i in range(60):
            b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            state, m = jstep(state, b)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5, losses[::10]

"""Ambit in-DRAM bitwise ops on the cycle-accurate timing face, plus the
memctrl timing-model bugfix pins.

Covers: spec-path bank-state timing (tRAS before PRE, tRC between ACTs),
periodic refresh accrual (tREFI/tRFC), Ambit TRA sequence timing and
semantics on the model face (majority-of-three, same-subarray rejection),
cross-face AND/OR/NOT parity through the PimLib protocol, Pallas-vs-ref
kernel parity, the serving zero-compare consumer, replay pricing of the
new trace kinds, and the satellite bugfixes (non-aliasing device
defaults, frozen CellPhysics, SequenceResult.ok normalization, public
unregister_pim_op)."""

import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (Blocking, CellPhysics, DRAMGeometry, DeviceLib,
                        MemoryController, Opcode, PimOpsController,
                        SimulatedDRAM, TpuLib, allocator_from_subarray_map,
                        discover_subarrays, make_tpu_arena)
from repro.core import op_registry
from repro.core.memctrl import Cmd, SequenceResult

ROW_BYTES = 64


def _mc(num_subarrays=2, rows=8):
    return MemoryController(SimulatedDRAM(DRAMGeometry(
        num_subarrays=num_subarrays, rows_per_subarray=rows,
        row_bytes=ROW_BYTES)))


def _same_sub_rows(mc, n):
    """n rows sharing one physical subarray (the device shuffles its
    row->subarray map, so hardcoded row ids are not same-subarray)."""
    sub = mc.device._row_to_subarray
    for sa in range(mc.device.geometry.num_subarrays):
        rows = [r for r in range(len(sub)) if sub[r] == sa]
        if len(rows) >= n:
            return rows[:n]
    raise AssertionError("no subarray large enough")


def _cross_sub_pair(mc):
    sub = mc.device._row_to_subarray
    for r in range(1, len(sub)):
        if sub[r] != sub[0]:
            return 0, r
    raise AssertionError("single-subarray device")


def _device_lib() -> DeviceLib:
    mc = _mc()
    smap = discover_subarrays(mc, max_rows=16)
    return DeviceLib(PimOpsController(mc), allocator_from_subarray_map(smap))


def _jax_lib() -> TpuLib:
    # uint8 pages so device rows and arena pages hold identical bytes
    return TpuLib(make_tpu_arena(num_slabs=2, pages_per_slab=8,
                                 page_elems=ROW_BYTES, dtype=jnp.uint8))


class TestSpecPathTiming:
    """Satellite 1: the spec path must respect tRAS and tRC — the old
    model precharged immediately after ACT (a DRAM protocol violation
    outside the deliberate PiM sequences)."""

    def test_act_to_pre_is_tras_plus_trp(self):
        mc = _mc()
        t0 = mc.now_ns
        mc.activate(0)
        mc.precharge()
        # ACT must hold the row open tRAS before PRE; PRE costs tRP:
        # the corrected ACT->PRE round trip is exactly tRC = 48.75 ns
        assert mc.now_ns - t0 == pytest.approx(mc.t.tRAS + mc.t.tRP)
        assert mc.t.tRAS + mc.t.tRP == pytest.approx(48.75)

    def test_act_to_act_respects_trc(self):
        mc = _mc()
        mc.activate(0)
        t_act0 = next(c.at_ns for c in mc.trace if c.cmd is Cmd.ACT)
        mc.activate(1)   # same bank: implicit PRE, then tRC from ACT 0
        t_act1 = [c.at_ns for c in mc.trace if c.cmd is Cmd.ACT][-1]
        assert t_act1 - t_act0 >= mc.t.tRAS + mc.t.tRP - 1e-9

    def test_fresh_read_burst_total_unchanged(self):
        # tRCD + tCL + tBL on a fresh activate: the paper-pinned read
        # path must not shift under the bank-state rework
        mc = _mc()
        t0 = mc.now_ns
        mc.read_burst(0)
        assert mc.now_ns - t0 == pytest.approx(
            mc.t.tRCD + mc.t.tCL + mc.t.tBL)

    def test_pim_sequence_times_pinned(self):
        # violated-timing sequences are the paper's contribution: pin
        # rowclone (2 AAP-ish phases) and the Ambit TRA sequences
        def seq_ns(name):
            mc = _mc()
            r0, r1 = _same_sub_rows(mc, 2)
            res = mc.run_sequence(name, r0, r1)
            assert res.ok
            return res.elapsed_ns
        assert seq_ns("rowclone_copy") == pytest.approx(53.75)
        assert seq_ns("ambit_and") == pytest.approx(263.75)
        assert seq_ns("ambit_or") == pytest.approx(263.75)
        assert seq_ns("ambit_not") == pytest.approx(107.5)


class TestRefresh:
    """Satellite 2: periodic REF is part of the bank-state clock — a
    span of N*tREFI must accrue N refreshes of tRFC busy time."""

    def test_refresh_catchup_accrues_n_trfc(self):
        mc = _mc()
        n = 3
        mc.now_ns = n * mc.t.tREFI + 1.0
        r0, r1 = _same_sub_rows(mc, 2)
        res = mc.run_sequence("rowclone_copy", r0, r1)
        assert mc.stats["refreshes"] == n
        refs = [c for c in mc.trace if c.cmd is Cmd.REF]
        assert len(refs) == n
        # each REF holds the device busy tRFC
        gaps = [b.at_ns - a.at_ns for a, b in zip(refs, refs[1:])]
        assert all(g == pytest.approx(mc.t.tRFC) for g in gaps)
        # the PiM sequence itself still runs at its pinned time after
        # the catch-up (refreshes land before the sequence dispatches)
        assert res.ok

    def test_no_refresh_inside_short_window(self):
        mc = _mc()
        r0, r1 = _same_sub_rows(mc, 2)
        mc.run_sequence("rowclone_copy", r0, r1)
        assert mc.stats["refreshes"] == 0

    def test_batch_crossing_trefi_includes_ref_in_trace_window(self):
        mc = _mc()
        mc.now_ns = mc.t.tREFI - 10.0    # next pair crosses the boundary
        rows = _same_sub_rows(mc, 4)
        res = mc.run_sequence_batch(
            "rowclone_copy", [(rows[0], rows[1]), (rows[2], rows[3])])
        assert mc.stats["refreshes"] >= 1
        assert res.ok and isinstance(res.ok, bool)
        assert any(c.cmd is Cmd.REF for c in res.commands)


class TestAmbitModelFace:
    def test_and_or_not_semantics(self):
        rng = np.random.default_rng(7)
        a = rng.integers(0, 256, ROW_BYTES).astype(np.uint8)
        b = rng.integers(0, 256, ROW_BYTES).astype(np.uint8)
        for op, want in (("ambit_and", a & b), ("ambit_or", a | b),
                         ("ambit_not", ~a)):
            mc = _mc()
            r0, r1 = _same_sub_rows(mc, 2)
            mc.device.write_row(r0, a)
            mc.device.write_row(r1, b)
            res = mc.run_sequence(op, r0, r1)
            assert res.ok
            np.testing.assert_array_equal(mc.device.read_row(r1), want)
            np.testing.assert_array_equal(mc.device.read_row(r0), a)

    def test_cross_subarray_tra_rejected(self):
        # operands in different subarrays cannot share B-group rows:
        # the sequence reports ok=False and dst is untouched
        mc = _mc(num_subarrays=2, rows=8)
        src, dst = _cross_sub_pair(mc)
        a = np.full(ROW_BYTES, 0xAA, np.uint8)
        b = np.full(ROW_BYTES, 0x55, np.uint8)
        mc.device.write_row(src, a)
        mc.device.write_row(dst, b)
        for op in ("ambit_and", "ambit_or", "ambit_not"):
            res = mc.run_sequence(op, src, dst)
            assert res.ok is False
            np.testing.assert_array_equal(mc.device.read_row(dst), b)

    def test_majority_of_three_is_the_primitive(self):
        # AND/OR are MAJ with a control row: check MAJ directly through
        # the device hook (charge-sharing truth table on bytes)
        dev = SimulatedDRAM(DRAMGeometry(1, 4, 4))
        a = np.array([0b1100, 0b1010, 0, 255], np.uint8)
        b = np.array([0b1010, 0b1100, 255, 255], np.uint8)
        dev.write_row(0, a)
        dev.write_row(1, b)
        assert dev.ambit_bitwise(0, 1, "and")
        np.testing.assert_array_equal(dev.read_row(1), a & b)

    def test_device_lib_bitwise_receipts_and_baseline(self):
        lib = _device_lib()
        g = lib.allocator.group_ids()[0]
        src = lib.allocator.alloc(2, group=g)
        dst = lib.allocator.alloc(2, group=g)
        rng = np.random.default_rng(0)
        va = rng.integers(0, 256, (2, ROW_BYTES)).astype(np.uint8)
        vb = rng.integers(0, 256, (2, ROW_BYTES)).astype(np.uint8)
        lib.write(src, va)
        lib.write(dst, vb)
        rec = lib.bitwise("and", src, dst, blocking=Blocking.FIN)
        assert rec.ok and rec.op == "ambit_and" and rec.n_ops == 2
        assert rec.latency_ns > 0
        np.testing.assert_array_equal(lib.read(dst), va & vb)
        assert lib.stats["bitwises"] == 2
        # in-DRAM TRA beats the CPU read-modify-write loop end to end
        cpu = lib.cpu_bitwise("and", src, dst)
        assert cpu.latency_ns > 10 * rec.latency_ns
        # allocation-level cross-group pairs are rejected up front
        g2 = lib.allocator.group_ids()[1]
        far = lib.allocator.alloc(2, group=g2)
        with pytest.raises(ValueError):
            lib.bitwise("or", src, far)
        with pytest.raises(ValueError):
            lib.bitwise("xor", src, dst)


class TestCrossFaceParity:
    def test_bitwise_parity_on_identical_traces(self):
        rng = np.random.default_rng(11)
        va = rng.integers(0, 256, (2, ROW_BYTES)).astype(np.uint8)
        vb = rng.integers(0, 256, (2, ROW_BYTES)).astype(np.uint8)
        for op, want_dst in (("and", va & vb), ("or", va | vb),
                             ("not", (~va).astype(np.uint8))):
            results = {}
            for lib in (_device_lib(), _jax_lib()):
                g = lib.allocator.group_ids()[0]
                src = lib.allocator.alloc(2, group=g)
                dst = lib.allocator.alloc(2, group=g)
                lib.write(src, va)
                lib.write(dst, vb)
                rec = lib.bitwise(op, src, dst, blocking=Blocking.FIN)
                assert rec.ok and rec.op == f"ambit_{op}"
                results[lib.face] = (np.asarray(lib.read(src), np.uint8),
                                     np.asarray(lib.read(dst), np.uint8))
            for face, (got_src, got_dst) in results.items():
                np.testing.assert_array_equal(got_dst, want_dst,
                                              err_msg=f"{op} dst on {face}")
                np.testing.assert_array_equal(got_src, va,
                                              err_msg=f"{op} src on {face}")

    def test_jax_face_coalesces_one_launch_per_kind(self):
        lib = _jax_lib()
        g = lib.allocator.group_ids()[0]
        src = lib.allocator.alloc(3, group=g)
        dst = lib.allocator.alloc(3, group=g)
        rec = lib.bitwise("or", src, dst, blocking=Blocking.FIN)
        assert rec.launches == 1
        assert lib.queue.launches_by_kind["page_or"] == 1

    def test_capability_flags(self):
        dev, tpu = _device_lib(), _jax_lib()
        for opc in (Opcode.AMB_AND, Opcode.AMB_OR, Opcode.AMB_NOT):
            assert dev.supports(opc) and tpu.supports(opc)


class TestAmbitKernels:
    """Pallas (interpret-mode on CPU) vs pure-jnp reference parity."""

    def test_bitwise_pallas_matches_ref(self):
        # the arena arg is donated: pass a fresh copy per call and keep
        # the reference values on the host
        from repro.kernels.ambit import ops as amb_ops
        rng = np.random.default_rng(3)
        base = rng.integers(0, 256, (2, 8, 128)).astype(np.uint8)
        src = jnp.asarray([0, 2, 5], jnp.int32)
        dst = jnp.asarray([1, 3, 6], jnp.int32)
        for op in ("and", "or", "not"):
            ref = amb_ops.pim_page_bitwise_batched(
                jnp.asarray(base), src, dst, op=op, use_pallas=False)
            pal = amb_ops.pim_page_bitwise_batched(
                jnp.asarray(base), src, dst, op=op, use_pallas=True,
                interpret=True)
            np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))

    def test_bitwise_float_arena_bit_exact(self):
        from repro.kernels.ambit import ops as amb_ops
        rng = np.random.default_rng(4)
        base = rng.normal(size=(2, 8, 32)).astype(np.float32)
        src = jnp.asarray([0], jnp.int32)
        dst = jnp.asarray([1], jnp.int32)
        out = amb_ops.pim_page_bitwise_batched(jnp.asarray(base), src, dst,
                                               op="and", use_pallas=False)
        want = base[:, 0].view(np.uint32) & base[:, 1].view(np.uint32)
        np.testing.assert_array_equal(
            np.asarray(out[:, 1]).view(np.uint32), want)

    def test_zero_scan_pallas_matches_ref(self):
        from repro.kernels.ambit import ops as amb_ops
        arena = jnp.zeros((2, 8, 64), jnp.uint8)
        arena = arena.at[1, 3, 17].set(1)        # one nonzero byte deep in
        pages = jnp.asarray([0, 3, 5], jnp.int32)
        ref = amb_ops.pim_page_zero_scan(arena, pages, use_pallas=False)
        pal = amb_ops.pim_page_zero_scan(arena, pages, use_pallas=True,
                                         interpret=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
        np.testing.assert_array_equal(np.asarray(ref),
                                      np.array([True, False, True]))

    def test_zero_scan_bf16_arena(self):
        from repro.kernels.ambit import ops as amb_ops
        arena = jnp.zeros((1, 4, 16), jnp.bfloat16)
        arena = arena.at[0, 2].set(0.5)
        flags = amb_ops.pim_page_zero_scan(arena, jnp.asarray([1, 2]))
        np.testing.assert_array_equal(np.asarray(flags),
                                      np.array([True, False]))


class TestSatelliteBugfixes:
    def test_simulated_dram_defaults_do_not_alias(self):
        """Satellite 3: dataclass instances used as shared mutable
        defaults — every no-arg construction must get fresh objects."""
        a, b = SimulatedDRAM(), SimulatedDRAM()
        assert a.geometry is not b.geometry
        assert a.physics is not b.physics

    def test_cell_physics_frozen(self):
        phys = SimulatedDRAM().physics
        with pytest.raises(dataclasses.FrozenInstanceError):
            phys.retention_weak_fraction = 0.5

    def test_sequence_result_ok_is_python_bool(self):
        """Satellite 4: numpy array comparisons leak numpy.bool_ into
        SequenceResult.ok; downstream `is True` checks and JSON dumps
        need a Python bool."""
        res = SequenceResult(1.0, [], ok=np.bool_(True))
        assert type(res.ok) is bool
        mc = _mc()
        rows = _same_sub_rows(mc, 4)
        batch = mc.run_sequence_batch(
            "ambit_and", [(rows[0], rows[1]), (rows[2], rows[3])])
        assert type(batch.ok) is bool and batch.ok
        bad = mc.run_sequence_batch("ambit_and", [_cross_sub_pair(mc)])
        assert type(bad.ok) is bool and not bad.ok

    def test_unregister_pim_op_roundtrip(self):
        """Satellite 5: registry teardown is public API now — register,
        use, unregister, and the opcode is clean for re-registration."""
        opcode = Opcode.NOP
        assert op_registry.get_op(opcode) is None

        def _flush(q, arenas, ops):
            q._count_launch("tmp_kind", len(arenas))
            return arenas
        spec = op_registry.PimOpSpec(opcode=opcode, name="tmp",
                                     jax_kind="tmp_kind", jax_flush=_flush)
        op_registry.register_pim_op(spec)
        assert op_registry.supports(opcode, op_registry.FACE_JAX)
        assert op_registry.unregister_pim_op(opcode) is spec
        assert op_registry.get_op(opcode) is None
        assert not op_registry.supports(opcode, op_registry.FACE_JAX)
        # idempotent: a second unregister returns None, no raise
        assert op_registry.unregister_pim_op(opcode) is None
        # the opcode is immediately re-registrable
        op_registry.register_pim_op(spec)
        assert op_registry.unregister_pim_op(opcode) is spec


class TestServingZeroScan:
    @pytest.fixture(scope="class")
    def model(self):
        from repro.configs import ARCHS, reduced
        return reduced(ARCHS["granite-3-8b"], num_layers=2)

    def test_scan_counts_and_skip_init_on_unwritten_pages(self, model):
        from repro.serving.kv_cache import PagedKVCache
        cache = PagedKVCache(model, num_pages=32, page_size=4,
                             zero_scan=True)
        seq = cache.create(0, 2)           # one partial page
        k = jnp.ones((cache.n_layers, 2, model.num_kv_heads,
                      model.resolved_head_dim))
        cache.write_prompt_kv(seq, k, k)
        # reserve a block the sequence never writes: those pages stay
        # all-zero and their init-on-free must be skipped by the scan
        cache.reserve_tokens(cache.seqs[0], 9)
        n_pages = len(cache.seqs[0].pages)
        assert n_pages == 3                # 1 written + 2 reserved-zero
        cache.free(0)
        assert cache.stats["init_skips_zero"] == 2
        assert cache.stats["pages_zeroed"] == n_pages
        assert cache.queue.saved_by_kind.get("page_init") == 2
        # ONE scan covered the whole free: one launch per arena (k, v)
        assert cache.queue.launches_by_kind["page_zero_scan"] == 2
        # the skipped pages really were zero; the written page zeroed
        assert float(jnp.abs(cache.k_arena).sum()) == 0.0
        assert cache.pages_in_use == 0

    def test_default_off_no_scan_launches(self, model):
        from repro.serving.kv_cache import PagedKVCache
        cache = PagedKVCache(model, num_pages=32, page_size=4)
        seq = cache.create(0, 6)
        cache.free(0)
        assert cache.queue.launches_by_kind.get("page_zero_scan", 0) == 0
        assert cache.stats["init_skips_zero"] == 0

    def test_clear_prefix_zero_leak_audit(self, model):
        from repro.serving.kv_cache import PagedKVCache
        cache = PagedKVCache(model, num_pages=32, page_size=4,
                             prefix_cache=True, zero_scan=True)
        tokens = list(range(8))
        seq = cache.create(0, 8, tokens=tokens)
        k = jnp.ones((cache.n_layers, 8, model.num_kv_heads,
                      model.resolved_head_dim))
        cache.write_prompt_kv(seq, k, k)
        cache.commit_prefix(0, tokens)
        cache.free(0)                      # tree still holds the pages
        assert cache.pages_in_use > 0
        cache.clear_prefix()
        assert cache.stats["zero_audit_pages"] > 0
        assert cache.stats["zero_audit_failures"] == 0
        assert cache.pages_in_use == 0

    def test_scan_records_trace_and_replay_prices_it(self, model):
        from repro.serving.kv_cache import PagedKVCache
        from repro.serving.trace import replay_on_device
        cache = PagedKVCache(model, num_pages=16, page_size=4, num_slabs=2,
                             record_trace=True, zero_scan=True)
        seq = cache.create(0, 6)
        k = jnp.ones((cache.n_layers, 6, model.num_kv_heads,
                      model.resolved_head_dim))
        cache.write_prompt_kv(seq, k, k)
        cache.free(0)
        counts = cache.trace.counts()
        assert counts["page_zero_scan"] == 2   # both pages scanned
        rep = replay_on_device(cache.trace)
        assert rep["pim_ns"]["zero_scan_ambit"] > 0
        assert rep["speedup"]["zero_scan"] > 1
        # the replay rode the timed face: device stats are surfaced
        assert "refreshes" in rep["device_stats"]


class TestTraceReplayBitwise:
    def test_bitwise_events_price_as_tra_sequences(self):
        from repro.serving.trace import PimTrace, replay_on_device
        tr = PimTrace(num_pages=16, num_slabs=2, page_size=4)
        tr.record_from_queue("page_and", [(0, 1), (2, 3)])
        tr.record_from_queue("page_not", [(4, 5)])
        rep = replay_on_device(tr)
        assert rep["counts"] == {"page_and": 2, "page_not": 1}
        assert rep["pim_ns"]["ambit_bitwise"] > 0
        assert rep["speedup"]["bitwise"] > 10
        assert all(r.ok for r in rep["receipts"])

    def test_cross_slab_bitwise_falls_back_to_cpu(self):
        from repro.serving.trace import PimTrace, replay_on_device
        tr = PimTrace(num_pages=16, num_slabs=2, page_size=4)
        tr.record_from_queue("page_or", [(0, 8)])   # slab 0 -> slab 1
        rep = replay_on_device(tr)
        assert rep["pim_ns"]["cpu_fallback_bitwise"] > 0
        assert rep["pim_ns"]["ambit_bitwise"] == 0
        # fallback latency stays in the denominator: speedup is 1x here
        assert rep["speedup"]["bitwise"] == pytest.approx(1.0)

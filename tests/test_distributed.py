"""Distribution layer: sharding-rule resolution, gradient compression
(multi-device via subprocess), pimolib TPU arena, data pipeline."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _compat import given, settings, st

from repro.distributed import sharding as sh
from repro.launch.mesh import make_local_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestShardingRules:
    def test_divisibility_fallback(self):
        mesh = make_local_mesh(1, 1)
        with sh.sharding_env(mesh):
            # axis size 1 -> everything replicated (no constraint effect)
            spec = sh.resolve_spec((8, 16), ("batch", "ff"))
            assert tuple(spec) == (None, None)

    def test_resolve_spec_with_fake_mesh(self):
        # abstract mesh via AbstractMesh is overkill; emulate by checking
        # the rule logic with a 1-device mesh and the rule table itself
        rules = sh.default_rules(multi_pod=True)
        assert rules["batch"] == ("pod", "data")
        assert rules["experts"] == ("model",)

    def test_shard_noop_without_mesh(self):
        x = jnp.ones((4, 4))
        assert sh.shard(x, "batch", None) is x


class TestGradCompression:
    def test_quantize_roundtrip_error_bound(self, rng):
        from repro.distributed.compression import _quantize, _dequantize
        x = jnp.asarray(rng.normal(size=(3, 1000)).astype(np.float32)) * 5
        codes, scale = _quantize(x)
        back = _dequantize(codes, scale, 1000)
        err = np.abs(np.asarray(back - x))
        bound = np.asarray(scale).max() * 0.5 + 1e-6
        assert err.max() <= bound

    @settings(max_examples=5, deadline=None)
    @given(n=st.integers(10, 3000))
    def test_quantize_shapes(self, n):
        from repro.distributed.compression import _quantize, _dequantize
        x = jnp.linspace(-1, 1, n)[None]
        codes, scale = _quantize(x)
        assert _dequantize(codes, scale, n).shape == (1, n)

    @pytest.mark.slow
    def test_compressed_psum_close_to_exact_8dev(self):
        """Run in a subprocess with 8 host devices (4 on sub-8-core
        boxes).  XLA host collectives spin-wait, so device threads far
        beyond the core count deadlock rather than just slowing down —
        below 4 cores there is no reliable configuration (2-device host
        meshes deadlock outright in this jax version), so skip."""
        cores = os.cpu_count() or 1
        if cores < 4:
            pytest.skip("host-mesh collectives deadlock with <4 cores")
        world = 8 if cores >= 8 else 4
        prog = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={world}"
            world = {world}
            import jax, jax.numpy as jnp, numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.distributed.compression import psum_compressed
            mesh = jax.make_mesh((world,), ("data",))
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.normal(size=(world, 257)).astype(np.float32))
            exact = shard_map(lambda v: jax.lax.psum(v, "data"), mesh=mesh,
                              in_specs=P("data"), out_specs=P(None),
                              check_rep=False)(x)
            comp = shard_map(lambda v: psum_compressed(v[0], "data")[None],
                             mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                             check_rep=False)(x)
            err = np.abs(np.asarray(comp[0] - exact[0]))
            rel = err.max() / (np.abs(np.asarray(exact[0])).max() + 1e-9)
            assert rel < 0.02, rel
            print("OK", rel)
        """)
        env = dict(os.environ, PYTHONPATH=SRC)
        env.pop("JAX_PLATFORMS", None)
        out = subprocess.run([sys.executable, "-c", prog], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "OK" in out.stdout

    def test_quantize_zero_block_exact(self):
        """An all-zero block must round-trip to EXACT zeros (scale floor
        regression: an additive epsilon on the scale is harmless, but
        padding blocks that dequantize to non-zero garbage would be
        summed into real elements by psum_compressed)."""
        from repro.distributed.compression import BLOCK, _dequantize, _quantize
        x = jnp.zeros((2, 3 * BLOCK), jnp.float32)
        codes, scale = _quantize(x)
        assert int(jnp.abs(codes).max()) == 0
        assert bool(jnp.all(jnp.isfinite(scale)))
        back = _dequantize(codes, scale, 3 * BLOCK)
        assert np.asarray(back == 0.0).all()

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 1000), seed=st.integers(0, 2**16))
    def test_quantize_preserves_exact_zeros(self, n, seed):
        """Elementwise property: wherever x is exactly 0.0, the int8
        round trip returns exactly 0.0 — including the implicit padding
        _quantize appends to fill the last block, and including blocks
        that are entirely zero."""
        from repro.distributed.compression import _dequantize, _quantize
        rng = np.random.default_rng(seed)
        x = rng.normal(size=n).astype(np.float32)
        x[rng.random(n) < 0.3] = 0.0
        if n > 4:  # force one fully-zero span crossing block math
            x[: n // 2] = 0.0
        codes, scale = _quantize(jnp.asarray(x)[None])
        back = np.asarray(_dequantize(codes, scale, n))[0]
        assert (back[x == 0.0] == 0.0).all()

    def test_psum_compressed_zero_and_pad_exact_1dev(self):
        """mesh=1 in-process run of the full all_to_all/all_gather
        pipeline: a length-257 input (pads to a second 256-block) with
        exact-zero tail must come back with that tail EXACTLY zero, and
        the non-zero part within one quantization step."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import psum_compressed
        mesh = make_local_mesh(1, 1)
        x = np.zeros(257, np.float32)
        x[:100] = np.linspace(-3, 3, 100, dtype=np.float32)
        out = shard_map(lambda v: psum_compressed(v, "model"),
                        mesh=mesh, in_specs=P(), out_specs=P(),
                        check_rep=False)(jnp.asarray(x))
        out = np.asarray(out)
        assert (out[100:] == 0.0).all()
        assert np.abs(out[:100] - x[:100]).max() <= (6 / 127) * 1.01


class TestTpuPimolib:
    def test_arena_copy_init_rand(self):
        from repro.core import make_tpu_arena, TpuLib, Blocking, OpReceipt
        arena = make_tpu_arena(num_slabs=2, pages_per_slab=8, page_elems=64,
                               dtype=jnp.float32)
        lib = TpuLib(arena)
        src, dst = arena.allocator.alloc_copy_pair(2)
        vals = jnp.arange(2 * 64, dtype=jnp.float32).reshape(2, 64)
        rec = lib.write(src, vals)
        assert isinstance(rec, OpReceipt) and rec.ok and rec.face == "jax"
        rec = lib.copy(src, dst, blocking=Blocking.FIN)
        assert rec.op == "rowclone_copy" and rec.n_ops == 2 and rec.launches >= 1
        np.testing.assert_array_equal(np.asarray(lib.read(dst)), vals)
        rec = lib.init(dst, 0.0, blocking=Blocking.FIN)
        assert rec.op == "rowclone_init" and rec.launches >= 1
        assert float(jnp.abs(lib.read(dst)).sum()) == 0.0
        r = lib.rand_u32(jnp.asarray([1, 2], jnp.uint32), 4, 16)
        assert r.shape == (4, 16) and r.dtype == jnp.uint32
        bits, rec = lib.rand(48)
        assert bits.shape == (48,) and set(np.unique(bits)) <= {0, 1}
        assert rec.op == "drange_rand" and rec.n_ops == 48
        # logical bits, exactly as DeviceLib counts them (no rounding to
        # whole words); rand_u32 counts its raw words separately
        assert lib.stats["rand_bits"] == 4 * 16 * 32 + 48
        # logical-op stats stay consistent with the queue's accounting
        assert lib.stats["copies"] == 2 and lib.stats["inits"] == 2
        assert lib.stats["writes"] == 2 and lib.stats["reads"] == 4
        assert lib.queue.stats["ops_enqueued"] == lib.queue.stats["ops_coalesced"] == 4

    def test_v1_page_aliases_retired(self):
        # the *_pages deprecation cycle (PR 3) is over: the aliases are
        # gone, so stale v1 call sites fail loudly instead of warning
        from repro.core import make_tpu_arena, TpuLib
        arena = make_tpu_arena(num_slabs=1, pages_per_slab=4, page_elems=8,
                               dtype=jnp.float32)
        lib = TpuLib(arena)
        for alias in ("copy_pages", "init_pages", "read_pages",
                      "write_pages"):
            assert not hasattr(lib, alias), alias

    def test_same_slab_constraint_enforced(self):
        from repro.core import make_tpu_arena, TpuLib
        from repro.core.allocator import PimAllocError
        arena = make_tpu_arena(num_slabs=2, pages_per_slab=4, page_elems=16)
        lib = TpuLib(arena)
        a = arena.allocator.alloc(1, group=0)
        b = arena.allocator.alloc(1, group=1)
        with pytest.raises(ValueError):
            lib.copy(a, b)

    def test_deferred_ops_coalesce_to_one_launch(self):
        # TpuLib routes through the batched PiM op scheduler: deferred
        # mode folds N copy calls into ONE coalesced launch
        from repro.core import make_tpu_arena, TpuLib, Blocking
        arena = make_tpu_arena(num_slabs=2, pages_per_slab=8, page_elems=64,
                               dtype=jnp.float32)
        lib = TpuLib(arena, deferred=True)
        pairs = [arena.allocator.alloc_copy_pair(1) for _ in range(3)]
        for i, (src, _) in enumerate(pairs):
            lib.write(src, jnp.full((1, 64), float(i + 1)))
        for src, dst in pairs:
            rec = lib.copy(src, dst)
            assert rec.deferred and rec.launches == 0
        assert lib.queue.launches_by_kind["page_copy"] == 0  # still queued
        assert lib.stats["copies"] == 3
        rec = lib.flush(Blocking.FIN)
        assert lib.queue.launches_by_kind["page_copy"] == 1  # one launch
        assert rec.launches == 1
        for i, (_, dst) in enumerate(pairs):
            np.testing.assert_array_equal(
                np.asarray(lib.read(dst)),
                np.full((1, 64), i + 1, np.float32))


class TestDataPipeline:
    def test_deterministic_replay(self):
        from repro.configs import ARCHS, ShapeConfig, reduced
        from repro.data.pipeline import PipelineConfig, SyntheticLM
        r = reduced(ARCHS["gemma-2b"])
        d1 = SyntheticLM(r, ShapeConfig("t", 64, 4, "train"), PipelineConfig(seed=9))
        d2 = SyntheticLM(r, ShapeConfig("t", 64, 4, "train"), PipelineConfig(seed=9))
        b1, b2 = d1.batch(17), d2.batch(17)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_prefetcher(self):
        from repro.data.pipeline import Prefetcher
        it = Prefetcher(iter(range(10)), depth=3)
        assert list(it) == list(range(10))

    def test_labels_shifted(self):
        from repro.configs import ARCHS, ShapeConfig, reduced
        from repro.data.pipeline import PipelineConfig, SyntheticLM
        r = reduced(ARCHS["granite-3-8b"])
        d = SyntheticLM(r, ShapeConfig("t", 32, 2, "train"), PipelineConfig())
        b = d.batch(0)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


class TestAllocatorProperties:
    @settings(max_examples=20, deadline=None)
    @given(ops=st.lists(st.tuples(st.booleans(), st.integers(1, 4)),
                        min_size=1, max_size=30))
    def test_never_double_allocates(self, ops):
        from repro.core.allocator import (PimAllocError, SubarrayAllocator,
                                          arena_groups)
        alloc = SubarrayAllocator(arena_groups(2, 16))
        live = []
        seen = set()
        for is_alloc, n in ops:
            if is_alloc or not live:
                try:
                    a = alloc.alloc(n)
                except PimAllocError:
                    continue
                for r in a.rows:
                    assert r not in seen
                    seen.add(r)
                live.append(a)
            else:
                a = live.pop()
                for r in a.rows:
                    seen.discard(r)
                alloc.free(a)
        assert alloc.free_rows() == 32 - len(seen)

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 8))
    def test_copy_pair_same_group(self, n):
        from repro.core.allocator import SubarrayAllocator, arena_groups
        alloc = SubarrayAllocator(arena_groups(4, 16))
        src, dst = alloc.alloc_copy_pair(n)
        assert src.group == dst.group
        assert not set(src.rows) & set(dst.rows)

"""Hybrid serving: the paged SSM state arena and in-jit MoE routing.

Pins the hybrid-layout contract end to end: fused engines (single-round,
K-blocked, chunked) stay bit-identical to the eager per-layer oracle for
mamba2- and jamba-style layouts; the state arena's slot ledger matches a
brute-force refcount oracle; copy-on-fork isolates diverging sequences
and flushes any deferred ``SSM_STATE_WRITE`` racing the fork; prefix
sharing is declined entirely when a state arena exists (recurrent state
is position-dependent); and the ssm_scan kernel triple agrees with its
pure-jnp reference in Pallas interpret mode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ParallelConfig, reduced
from repro.core.allocator import PimAllocError
from repro.kernels.ssm_scan import ops as ssm_ops
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request
from repro.serving.kv_cache import PagedKVCache

PCFG = ParallelConfig(attention_impl="naive", remat="none")


def _chunk4(cfg):
    """SSD chunk size 4, so chunked prefill (multiples of 4) is legal."""
    return dataclasses.replace(
        cfg, ssm=dataclasses.replace(cfg.ssm, chunk_size=4))


@pytest.fixture(scope="module")
def ssm_model():
    cfg = _chunk4(reduced(ARCHS["mamba2-1.3b"], num_layers=2))
    return cfg, init_params(T.model_defs(cfg), jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def hybrid_model():
    cfg = _chunk4(reduced(ARCHS["jamba-1.5-large-398b"], num_layers=4,
                          attn_every=4))
    return cfg, init_params(T.model_defs(cfg), jax.random.PRNGKey(0))


def _engine(cfg, params, *, K=1, fused=True, chunk=None, **kw):
    return PagedEngine(cfg, params, pcfg=PCFG, page_size=4, num_pages=64,
                       fused=fused, fused_prefill=fused,
                       max_prefill_chunk=chunk,
                       decode_block_rounds=K if fused else 1, **kw)


def _submit(eng, cfg, seed, n_reqs, budget):
    rng = np.random.default_rng(seed)
    for i in range(n_reqs):
        plen = int(rng.integers(2, 12))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        eng.submit(Request(i, prompt, max_new_tokens=budget,
                           temperature=0.0))


def _f32(a):
    return np.asarray(jnp.asarray(a, jnp.float32))


class TestHybridParity:
    """Every fused path is bit-identical to the eager oracle, for both
    the pure-SSM and the attention/MoE-interleaved hybrid layout."""

    @pytest.mark.parametrize("family", ["ssm", "hybrid"])
    def test_fused_paths_match_eager_streams(self, family, ssm_model,
                                             hybrid_model):
        cfg, params = ssm_model if family == "ssm" else hybrid_model
        if family == "hybrid":   # pin the layout the fixture serves
            kinds = T.layer_groups(cfg)[0][1]
            assert "attn" in kinds and "moe" in kinds and "mamba" in kinds
        eager = _engine(cfg, params, fused=False)
        _submit(eager, cfg, seed=5, n_reqs=3, budget=6)
        ref = eager.run()
        assert eager.cache.state.rows_in_use == 0
        for name, eng in [("K1", _engine(cfg, params)),
                          ("K3", _engine(cfg, params, K=3)),
                          ("chunk4", _engine(cfg, params, chunk=4))]:
            _submit(eng, cfg, seed=5, n_reqs=3, budget=6)
            assert eng.run() == ref, (family, name)
            # zero leaked KV pages AND state slots once everything drains
            assert eng.cache.pages_in_use == 0, (family, name)
            assert eng.cache.state.rows_in_use == 0, (family, name)
            assert eng.cache.stats["state_pages"] == 0, (family, name)

    def test_state_arena_parity_mid_flight(self, ssm_model):
        """Stop every engine after the SAME number of rounds: the
        per-sequence state-arena rows must line up — K-variants
        bit-identical (masked write-back keeps dead-row scatters
        structural no-ops), fused vs eager at arena resolution."""
        cfg, params = ssm_model
        states = {}
        for name, eng in [("eager", _engine(cfg, params, fused=False)),
                          ("K1", _engine(cfg, params)),
                          ("K3", _engine(cfg, params, K=3)),
                          ("K8", _engine(cfg, params, K=8))]:
            _submit(eng, cfg, seed=7, n_reqs=2, budget=32)
            eng.run(max_rounds=7)
            rids = sorted(eng.active)
            assert rids == [0, 1], name
            conv, ssm = eng.cache.state.gather(rids)
            states[name] = (
                {r: list(eng.active[r].out_tokens) for r in rids},
                _f32(conv), _f32(ssm))
        toks1, conv1, ssm1 = states["K1"]
        for k in ("K3", "K8"):
            toksk, convk, ssmk = states[k]
            assert toksk == toks1, k
            np.testing.assert_array_equal(conv1, convk)
            np.testing.assert_array_equal(ssm1, ssmk)
        tokse, conve, ssme = states["eager"]
        assert tokse == toks1
        np.testing.assert_allclose(conve, conv1, rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(ssme, ssm1, rtol=2e-2, atol=2e-2)


class TestStateLedger:
    """The slot ledger vs a brute-force shadow oracle over random
    create/fork/free interleavings."""

    def test_ledger_matches_brute_force_oracle(self, ssm_model):
        cfg, _ = ssm_model
        cache = PagedKVCache(cfg, num_pages=64, page_size=4,
                             state_slots=16)
        st = cache.state
        rng = np.random.default_rng(0)
        ledger = {}                      # seq_id -> slot, the oracle
        next_id = 0
        for _ in range(120):
            op = rng.choice(["create", "fork", "free"]
                            if ledger else ["create"])
            if op == "create" and len(ledger) < st.num_slots:
                cache.create(next_id, int(rng.integers(1, 9)))
                ledger[next_id] = st.rows[next_id]
                next_id += 1
            elif op == "fork" and ledger and len(ledger) < st.num_slots:
                src = int(rng.choice(sorted(ledger)))
                cache.fork(src, next_id)
                ledger[next_id] = st.rows[next_id]
                next_id += 1
            elif op == "free":
                victim = int(rng.choice(sorted(ledger)))
                cache.free(victim)
                del ledger[victim]
            # invariants after EVERY op
            assert st.rows == ledger
            slots = list(ledger.values())
            assert len(set(slots)) == len(slots)       # no slot aliasing
            assert st.rows_in_use == len(ledger)
            assert st.rows_in_use + len(st._free) == st.num_slots
            assert cache.stats["state_pages"] == len(ledger)
        for sid in sorted(ledger):
            cache.free(sid)
        assert st.rows_in_use == 0
        assert sorted(st._free) == list(range(st.num_slots))
        assert cache.pages_in_use == 0

    def test_out_of_state_slots_raises(self, ssm_model):
        cfg, _ = ssm_model
        cache = PagedKVCache(cfg, num_pages=64, page_size=4, state_slots=2)
        cache.create(0, 4)
        cache.create(1, 4)
        with pytest.raises(PimAllocError):
            cache.create(2, 4)


class TestCopyOnFork:
    def _filled(self, cfg, *, flush=True):
        cache = PagedKVCache(cfg, num_pages=32, page_size=4, state_slots=8)
        st = cache.state
        cache.create(0, 4)
        st.write([0], self._state(st, 3.0)[0], self._state(st, 5.0)[1],
                 flush=flush)
        return cache, st

    @staticmethod
    def _state(st, value):
        conv = jnp.full((st.conv.shape[0], st.conv.shape[1], 1)
                        + st.conv.shape[3:], value, st.conv.dtype)
        ssm = jnp.full((st.ssm.shape[0], st.ssm.shape[1], 1)
                       + st.ssm.shape[3:], value, st.ssm.dtype)
        return conv, ssm

    def test_fork_isolates_state(self, ssm_model):
        """Copy-on-fork duplicates the WHOLE row at fork time: the
        source diverging afterwards must not bleed into the fork."""
        cfg, _ = ssm_model
        cache, st = self._filled(cfg)
        cache.fork(0, 1)
        assert cache.stats["state_forks"] == 1
        st.write([0], *self._state(st, 7.0))       # source diverges
        c0, s0 = st.gather([0])
        c1, s1 = st.gather([1])
        assert bool(jnp.all(c1 == 3.0)) and bool(jnp.all(s1 == 5.0))
        assert bool(jnp.all(c0 == 7.0)) and bool(jnp.all(s0 == 7.0))

    def test_fork_flushes_deferred_state_write(self, ssm_model):
        """Regression: a fork racing a DEFERRED ``ssm_state_write`` on
        the source slot must flush the write first (the copy's admit
        reads the slot) — else the RowClone copy replays stale zeros."""
        cfg, _ = ssm_model
        cache, st = self._filled(cfg, flush=False)   # write still queued
        q = cache.queue
        base = dict(q.launches_by_kind)
        cache.fork(0, 1)
        c1, s1 = st.gather([1])
        assert bool(jnp.all(c1 == 3.0)) and bool(jnp.all(s1 == 5.0))
        # program order: the hazard flush ran the write (2 launches, one
        # per arena) BEFORE the fork's state_copy (2 more)
        delta = {k: q.launches_by_kind.get(k, 0) - base.get(k, 0)
                 for k in ("ssm_state_write", "state_copy")}
        assert delta == {"ssm_state_write": 2, "state_copy": 2}

    def test_free_zeroes_state_row(self, ssm_model):
        """Init-on-free: a released slot is zero in the arena, so its
        next owner can never observe cross-request state."""
        cfg, _ = ssm_model
        cache, st = self._filled(cfg)
        slot = st.rows[0]
        cache.free(0)
        assert float(jnp.abs(st.conv[:, :, slot]).sum()) == 0.0
        assert float(jnp.abs(st.ssm[:, :, slot]).sum()) == 0.0
        cache.create(9, 4)                 # slot reuse starts from zero
        c9, s9 = st.gather([9])
        assert float(jnp.abs(c9).sum()) == 0.0
        assert float(jnp.abs(s9).sum()) == 0.0


class TestHybridPrefixCache:
    """Recurrent state is position-dependent: prefix sharing must be
    declined entirely on state-arena families, and stay untouched on
    dense ones."""

    def test_radix_match_declined_and_streams_still_agree(
            self, hybrid_model):
        cfg, params = hybrid_model
        eng = _engine(cfg, params, prefix_cache=True)
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
        eng.submit(Request(0, prompt, max_new_tokens=3, temperature=0.0))
        r0 = eng.run()
        eng.submit(Request(1, prompt, max_new_tokens=3, temperature=0.0))
        r1 = eng.run()
        # the identical prompt recomputed from scratch: same stream, no
        # hit, no spared writes, the decline accounted
        assert r1[1] == r0[0]
        assert eng.stats["prefix_hits"] == 0
        assert eng.stats["prefix_declined_ssm"] >= 1
        assert eng.cache.queue.saved_by_kind.get("kv_write", 0) == 0

    def test_commit_prefix_never_indexes_state_families(self, ssm_model):
        cfg, _ = ssm_model
        cache = PagedKVCache(cfg, num_pages=32, page_size=4,
                             prefix_cache=True)
        cache.create(0, 8, tokens=list(range(8)))
        assert cache.commit_prefix(0, list(range(8))) == 0
        assert cache.prefix.n_nodes == 0
        assert cache.stats["prefix_declined_ssm"] == 1

    def test_pairwise_share_declined_for_state_families(self, ssm_model):
        cfg, _ = ssm_model
        cache = PagedKVCache(cfg, num_pages=32, page_size=4)
        cache.create(0, 8)
        seq1 = cache.create(1, 8, share_with=0, shared_len=8)
        assert seq1.shared_prefix_pages == 0
        assert cache.stats["prefix_hits"] == 0
        assert cache.stats["prefix_declined_ssm"] == 1

    def test_dense_prefix_unaffected(self):
        cfg = reduced(ARCHS["granite-3-8b"], num_layers=1)
        cache = PagedKVCache(cfg, num_pages=32, page_size=4,
                             prefix_cache=True)
        assert cache.state is None
        seq = cache.create(0, 8)
        k = jnp.ones((cache.n_layers, 8, cfg.num_kv_heads,
                      cfg.resolved_head_dim))
        cache.write_prompt_kv(seq, k, k)
        assert cache.commit_prefix(0, list(range(8))) == 2
        assert cache.stats["prefix_declined_ssm"] == 0


class TestHybridGuards:
    """Capability flags: unsupported combinations refuse loudly at
    construction instead of serving silently wrong."""

    def test_chunk_must_align_to_ssd_chunk_size(self, ssm_model):
        cfg, params = ssm_model                     # chunk_size=4
        with pytest.raises(ValueError, match="chunk_size"):
            _engine(cfg, params, chunk=6)
        eng = _engine(cfg, params, chunk=8)
        with pytest.raises(ValueError, match="chunk_size"):
            eng.set_prefill_chunk(6)
        eng.set_prefill_chunk(12)                   # aligned retarget OK

    def test_mesh_serving_rejects_state_and_moe_families(
            self, ssm_model, hybrid_model):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("model",))
        for cfg, params in (ssm_model, hybrid_model):
            with pytest.raises(ValueError, match="dense-only"):
                _engine(cfg, params, mesh=mesh)


class TestStateKernelParity:
    """ssm_scan triple: pure-jnp reference vs the Pallas kernels in
    interpret mode, plus the empty-batch no-op contract."""

    def _arena(self, rng, dtype=jnp.float32):
        return jnp.asarray(rng.standard_normal((2, 2, 6, 4, 3)), dtype)

    def test_state_scatter_ref_vs_pallas(self, rng):
        a = self._arena(rng)
        rows = jnp.asarray([4, 1], jnp.int32)
        new = jnp.asarray(rng.standard_normal((2, 2, 2, 4, 3)),
                          jnp.float32)
        ref = ssm_ops.state_scatter_inline(a, rows, new, use_pallas=False)
        pl = ssm_ops.state_scatter_inline(a, rows, new, use_pallas=True,
                                          interpret=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(pl))
        # scattered rows hold the new values, others untouched
        np.testing.assert_array_equal(np.asarray(ref[:, :, 4]),
                                      np.asarray(new[:, :, 0]))
        np.testing.assert_array_equal(np.asarray(ref[:, :, 0]),
                                      np.asarray(a[:, :, 0]))

    def test_state_copy_ref_vs_pallas(self, rng):
        a = self._arena(rng)
        src = jnp.asarray([0, 2], jnp.int32)
        dst = jnp.asarray([5, 3], jnp.int32)
        ref = ssm_ops.pim_state_copy(a + 0, src, dst, use_pallas=False)
        pl = ssm_ops.pim_state_copy(a + 0, src, dst, use_pallas=True,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(pl))
        np.testing.assert_array_equal(np.asarray(ref[:, :, 5]),
                                      np.asarray(a[:, :, 0]))

    def test_state_init_ref_vs_pallas(self, rng):
        a = self._arena(rng)
        dst = jnp.asarray([1, 4], jnp.int32)
        ref = ssm_ops.pim_state_init(a + 0, dst, 0.0, use_pallas=False)
        pl = ssm_ops.pim_state_init(a + 0, dst, 0.0, use_pallas=True,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(pl))
        assert float(jnp.abs(ref[:, :, 1]).sum()) == 0.0

    def test_empty_batch_is_noop(self, rng):
        a = self._arena(rng)
        empty = jnp.asarray([], jnp.int32)
        new = jnp.zeros((2, 2, 0, 4, 3), jnp.float32)
        out = ssm_ops.state_scatter_inline(a, empty, new, use_pallas=True,
                                           interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(a))

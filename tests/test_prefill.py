"""Fused bucketed prefill: one jitted dispatch per prefill batch.

The parity harness: ``fused_prefill=False`` keeps the eager
per-request prefill (un-jitted dense ``T.forward`` + host-side
``write_prompt_kv``) as the oracle, so the fused path is pinned by
fused-vs-eager **token**, **logit**, and **arena-content** parity —
across prompt lengths that straddle power-of-two bucket boundaries,
shared-prefix (``share_with``) requests, and mixed-length batches —
plus retrace regressions on ``stats["prefill_jit_traces"]``.
(The dispatch-count regressions live with the other launch-count pins
in ``tests/test_serving.py::TestDispatchCounts``.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ParallelConfig, reduced
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request
from repro.serving.kv_cache import _bucket_pow2

PCFG = ParallelConfig(attention_impl="naive", remat="none")


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _engine_pair(cfg, params, **kw):
    fused = PagedEngine(cfg, params, page_size=4, num_pages=128,
                        fused_prefill=True, **kw)
    eager = PagedEngine(cfg, params, page_size=4, num_pages=128,
                        fused_prefill=False, **kw)
    return fused, eager


def _submit_all(engines, reqs):
    """Submit fresh Request copies to every engine (Requests mutate)."""
    for eng in engines:
        for r in reqs:
            eng.submit(Request(r.req_id, r.prompt,
                               max_new_tokens=r.max_new_tokens,
                               temperature=r.temperature,
                               share_with=r.share_with,
                               shared_len=r.shared_len))


def _arenas_equal(a, b):
    # both paths compute K/V in bf16; scan-vs-dense fusion may round
    # intermediates differently, so parity holds at bf16 resolution
    np.testing.assert_allclose(
        np.asarray(a.cache.k_arena, np.float32),
        np.asarray(b.cache.k_arena, np.float32), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(
        np.asarray(a.cache.v_arena, np.float32),
        np.asarray(b.cache.v_arena, np.float32), rtol=2e-2, atol=2e-2)


class TestPrefillParity:
    def test_bucket_boundary_lengths(self, model, rng):
        """7/8/9 and 15/16/17 straddle the 8- and 16-buckets: each
        prompt prefills as its own batch (separate rounds) and must
        match the eager oracle token-for-token, with identical arena
        contents after the prefill writes."""
        cfg, params = model
        fused, eager = _engine_pair(cfg, params)
        for i, n in enumerate((7, 8, 9, 15, 16, 17)):
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            req = Request(i, prompt, max_new_tokens=2, temperature=0.0)
            _submit_all((fused, eager), [req])
            fused._prefill_round()
            eager._prefill_round()
            _arenas_equal(fused, eager)   # prompt KV written identically
            f = fused.active[i].out_tokens
            e = eager.active[i].out_tokens
            assert f == e, (n, f, e)
        # and the decode rounds that follow agree too
        assert fused.run() == eager.run()

    def test_mixed_length_batch_parity(self, model, rng):
        """One submission spanning three buckets: the fused path stacks
        per-bucket batches (2, 3, and 1 requests) and must match the
        eager oracle exactly."""
        cfg, params = model
        fused, eager = _engine_pair(cfg, params)
        reqs = [Request(i, rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                        max_new_tokens=3, temperature=0.0)
                for i, n in enumerate((7, 8, 9, 15, 16, 17))]
        _submit_all((fused, eager), reqs)
        res_f, res_e = fused.run(), eager.run()
        assert res_f == res_e
        assert fused.stats["prefills"] == 6
        # 3 distinct (length-bucket, batch-bucket) pairs -> 3 traces
        assert fused.stats["prefill_jit_traces"] == 3

    def test_shared_prefix_parity(self, model, rng):
        """`share_with` requests skip the shared pages in the scatter
        plan; fused and eager must agree on tokens, arena contents, and
        prefix accounting — including a sharer whose prompt is FULLY
        covered by the prefix (the all-no-op scatter batch)."""
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        fused, eager = _engine_pair(cfg, params)
        reqs = [Request(0, prompt, max_new_tokens=3, temperature=0.0),
                Request(1, prompt, max_new_tokens=3, temperature=0.0,
                        share_with=0, shared_len=12)]
        _submit_all((fused, eager), reqs)
        fused._prefill_round()
        eager._prefill_round()
        _arenas_equal(fused, eager)
        # a fully-covered sharer arrives next round: nothing to write,
        # and the no-op scatter must leave the arena untouched
        before = np.asarray(fused.cache.k_arena, np.float32).copy()
        _submit_all((fused, eager),
                    [Request(2, prompt, max_new_tokens=3, temperature=0.0,
                             share_with=0, shared_len=16)])
        fused._prefill_round()
        eager._prefill_round()
        np.testing.assert_array_equal(
            before, np.asarray(fused.cache.k_arena, np.float32))
        _arenas_equal(fused, eager)
        res_f, res_e = fused.run(), eager.run()
        assert res_f == res_e
        assert res_f[0] == res_f[1] == res_f[2]
        assert fused.cache.stats["prefix_hits"] == 2
        assert (fused.cache.stats["prefix_hits"]
                == eager.cache.stats["prefix_hits"])

    def test_prefill_forward_matches_eager_logits(self, model, rng):
        """Logit-level parity of the scan/masked forward against the
        dense ``T.forward`` oracle, at bf16 resolution, for a padded
        (bucketed) and an exact-fit prompt — plus the stacked K/V the
        scatter plan sources."""
        from repro.serving import engine as E
        cfg, params = model
        for n in (5, 8):
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            sp = _bucket_pow2(n)
            toks = np.zeros((1, sp), np.int32)
            toks[0, :n] = prompt
            lg_f, k_all, v_all, _, _ = E._prefill_forward(
                cfg, PCFG, params, jnp.asarray(toks),
                jnp.asarray([n], jnp.int32), use_pallas=False,
                interpret=True)
            cache = T.init_cache(cfg, 1, n)
            lg_e, dense, _ = T.forward(
                cfg, PCFG, params, {"tokens": jnp.asarray(prompt)[None]},
                mode="prefill", cache=cache,
                lengths=jnp.asarray([n], jnp.int32))
            np.testing.assert_allclose(np.asarray(lg_f[0]),
                                       np.asarray(lg_e[0, 0]),
                                       rtol=2e-2, atol=2e-2)
            k_e, v_e = dense["group0"]["0_attn"]   # (L, 1, n, kvh, hd)
            np.testing.assert_allclose(
                np.asarray(k_all[:, 0, :n], np.float32),
                np.asarray(k_e[:, 0], np.float32), rtol=2e-2, atol=2e-2)
            np.testing.assert_allclose(
                np.asarray(v_all[:, 0, :n], np.float32),
                np.asarray(v_e[:, 0], np.float32), rtol=2e-2, atol=2e-2)

    def test_pallas_path_matches_reference(self, model, rng):
        """The length-masked Pallas flash kernel drives the same fused
        prefill to the same tokens as the jnp reference path."""
        cfg, params = model
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (5, 7)]
        outs = []
        for use_pallas in (False, True):
            eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                              use_pallas=use_pallas, interpret=True)
            for i, p in enumerate(prompts):
                eng.submit(Request(i, p, max_new_tokens=2, temperature=0.0))
            outs.append(eng.run())
        assert outs[0] == outs[1]


class TestChunkedPrefill:
    """Chunked prefill with decode-interleaved scheduling
    (``max_prefill_chunk``): prompts longer than one chunk stream across
    rounds through the prefix-KV flash path, pinned against the same
    eager oracle as the monolithic fused prefill."""

    CHUNK = 8

    def _pair(self, cfg, params, **kw):
        chunked = PagedEngine(cfg, params, page_size=4, num_pages=128,
                              max_prefill_chunk=self.CHUNK, **kw)
        eager = PagedEngine(cfg, params, page_size=4, num_pages=128,
                            fused_prefill=False, **kw)
        return chunked, eager

    @staticmethod
    def _drain_prefill(eng):
        """Run prefill ticks (no decode) until nothing is mid-prefill."""
        while eng.queue or eng._chunk_q:
            eng._prefill_tick()

    def test_chunk_straddling_lengths_match_eager(self, model, rng):
        """7/9/17/23/32 with an 8-token chunk cover: single sub-chunk
        prompts, chunk-exact prompts, and 2-4 chunk prompts with ragged
        tails.  Token AND arena parity against the eager oracle after
        every prompt's prefill, then decode-round parity."""
        cfg, params = model
        chunked, eager = self._pair(cfg, params)
        for i, n in enumerate((7, 9, 17, 23, 32)):
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            req = Request(i, prompt, max_new_tokens=2, temperature=0.0)
            _submit_all((chunked, eager), [req])
            self._drain_prefill(chunked)
            eager._prefill_round()
            _arenas_equal(chunked, eager)   # chunk KV committed identically
            assert (chunked.active[i].out_tokens
                    == eager.active[i].out_tokens), n
        assert chunked.run() == eager.run()
        # 5 prompts, chunk cover of ceil(n/8) each: 1+2+3+3+4
        assert chunked.stats["prefill_chunks"] == 13
        assert chunked.stats["decode_stall_rounds"] == 0

    def test_shared_prefix_composes_with_chunking(self, model, rng):
        """A chunked source plus a partially-covered and a fully-covered
        sharer: the sharers' chunk/first-token work is gated until the
        source commits the shared pages, and results match the eager
        oracle (which prefills everything before any decode)."""
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        chunked, eager = self._pair(cfg, params)
        reqs = [Request(0, prompt, max_new_tokens=3, temperature=0.0),
                Request(1, prompt, max_new_tokens=3, temperature=0.0,
                        share_with=0, shared_len=12),
                Request(2, prompt, max_new_tokens=3, temperature=0.0,
                        share_with=0, shared_len=16)]
        _submit_all((chunked, eager), reqs)
        res_c, res_e = chunked.run(), eager.run()
        assert res_c == res_e
        assert res_c[0] == res_c[1] == res_c[2]
        assert (chunked.cache.stats["prefix_hits"]
                == eager.cache.stats["prefix_hits"] == 2)
        # a fully-covered sharer is ONE no-write chunk — even arriving
        # while its source decodes, it never busts the round budget
        # (the whole-prompt forward a covered sharer used to trigger
        # would stall every in-flight decode behind it)
        assert chunked.stats["decode_stall_rounds"] == 0
        assert chunked.stats["prefill_chunks"] == 2 + 1 + 1  # 16tok,4tok,1tok

    def test_chunk_forward_matches_dense_logits(self, model, rng):
        """Logit-level parity of the prefix-KV chunk forward against the
        dense full-prompt oracle: after chunk 1 commits, chunk 2's
        last-token logits must match ``T.forward`` over the whole prompt
        at that position (bf16 resolution), and its fresh K/V must match
        the dense cache slice the scatter plan would write."""
        from repro.serving import engine as E
        cfg, params = model
        n, c = 12, self.CHUNK
        prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                          max_prefill_chunk=c)
        eng.submit(Request(0, prompt, max_new_tokens=1, temperature=0.0))
        eng._prefill_tick()              # chunk 1: positions [0, 8)
        seq = eng.cache.seqs[0]
        clen = n - c                     # chunk 2: positions [8, 12)
        toks = np.zeros((1, clen), np.int32)
        toks[0] = prompt[c:]
        bt, plens = eng.cache.block_table([0], lengths=[c])
        lg_c, k_all, v_all, _, _ = E._chunk_prefill_forward(
            cfg, PCFG, params, jnp.asarray(toks),
            jnp.asarray([clen], jnp.int32), jnp.asarray([c], jnp.int32),
            eng.cache.k_arena, eng.cache.v_arena, bt, plens,
            use_pallas=False, interpret=True)
        cache = T.init_cache(cfg, 1, n)
        lg_e, dense, _ = T.forward(
            cfg, PCFG, params, {"tokens": jnp.asarray(prompt)[None]},
            mode="prefill", cache=cache, lengths=jnp.asarray([n], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg_c[0]),
                                   np.asarray(lg_e[0, -1]),
                                   rtol=2e-2, atol=2e-2)
        k_e, v_e = dense["group0"]["0_attn"]   # (L, 1, n, kvh, hd)
        np.testing.assert_allclose(
            np.asarray(k_all[:, 0], np.float32),
            np.asarray(k_e[:, 0, c:], np.float32), rtol=2e-2, atol=2e-2)
        np.testing.assert_allclose(
            np.asarray(v_all[:, 0], np.float32),
            np.asarray(v_e[:, 0, c:], np.float32), rtol=2e-2, atol=2e-2)

    def test_decode_emits_every_round_during_long_prefill(self, model, rng):
        """The starvation regression: with a decode in flight, a 4-chunk
        prompt streams across rounds and the decode request still emits
        exactly one token per round; ``decode_stall_rounds`` stays 0.
        The eager oracle fed the same workload (whole-prompt prefill)
        records the stall the chunked scheduler removes."""
        cfg, params = model
        short = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
        long = rng.integers(0, cfg.vocab_size, 4 * self.CHUNK).astype(np.int32)

        def feed(eng):
            eng.submit(Request(0, short.copy(), max_new_tokens=12,
                               temperature=0.0))
            eng.run(max_rounds=2)        # prefill + first decode round
            eng.submit(Request(1, long.copy(), max_new_tokens=2,
                               temperature=0.0))

        chunked = PagedEngine(cfg, params, page_size=4, num_pages=256,
                              max_prefill_chunk=self.CHUNK)
        feed(chunked)
        base_chunks = chunked.stats["prefill_chunks"]   # the short prompt
        deltas = []
        while chunked.queue or chunked.active or chunked._chunk_q:
            before = (len(chunked.active[0].out_tokens)
                      if 0 in chunked.active else None)
            chunked.run(max_rounds=1)
            if before is not None and 0 in chunked.active:
                deltas.append(len(chunked.active[0].out_tokens) - before)
        assert deltas and all(d == 1 for d in deltas), deltas
        assert chunked.stats["prefill_chunks"] - base_chunks == 4
        assert chunked.stats["decode_stall_rounds"] == 0
        # same workload, un-chunked prefill: the decode stalled behind it
        eager = PagedEngine(cfg, params, page_size=4, num_pages=256,
                            fused_prefill=False,
                            max_prefill_chunk=self.CHUNK)
        feed(eager)
        eager.run()
        assert eager.stats["decode_stall_rounds"] >= 1

    def test_no_new_trace_per_chunk_count(self, model, rng):
        """Chunk batches retrace per distinct (chunk-bucket, batch-bucket,
        table-width) triple, never per chunk count: a 17-token prompt
        (3 chunks) and a 25-token prompt (4 chunks) share every bucket,
        so the second compiles NOTHING new — and neither does a rerun."""
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=256,
                          max_prefill_chunk=self.CHUNK)
        traces = []
        for i, n in enumerate((17, 25, 17)):
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            eng.submit(Request(i, prompt, max_new_tokens=1, temperature=0.0))
            TestChunkedPrefill._drain_prefill(eng)
            traces.append(eng.stats["prefill_jit_traces"])
        # full chunks (bucket 8) + ragged tail (bucket 1) compile once;
        # more chunks of the same shape never compile again
        assert traces[0] == traces[1] == traces[2], traces
        eng.run()      # drain so the arena frees cleanly

    def test_pallas_path_matches_reference(self, model, rng):
        """The Pallas prefix-KV flash kernel drives chunked prefill to
        the same tokens as the jnp reference path."""
        cfg, params = model
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (7, 19)]
        outs = []
        for use_pallas in (False, True):
            eng = PagedEngine(cfg, params, page_size=4, num_pages=128,
                              max_prefill_chunk=self.CHUNK,
                              use_pallas=use_pallas, interpret=True)
            for i, p in enumerate(prompts):
                eng.submit(Request(i, p, max_new_tokens=2, temperature=0.0))
            outs.append(eng.run())
        assert outs[0] == outs[1]


class TestPrefillRetrace:
    def test_traces_bounded_by_distinct_buckets(self, model, rng):
        """N prompts of varied lengths compile at most one trace per
        distinct (length-bucket, batch-bucket) pair — and resubmitting
        the same pattern compiles nothing new."""
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=128)

        def burst(base):
            # lengths 5..8 share the 8-bucket (batch of 4); 9 and 12
            # share the 16-bucket (batch of 2, padded to 2)
            for j, n in enumerate((5, 6, 7, 8, 9, 12)):
                prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                eng.submit(Request(base + j, prompt, max_new_tokens=1,
                                   temperature=0.0))
            eng._prefill_round()

        burst(0)
        assert eng.stats["prefill_jit_traces"] == 2
        burst(10)      # identical bucket pattern -> trace cache hits only
        assert eng.stats["prefill_jit_traces"] == 2
        assert eng.stats["fused_prefill_dispatches"] == 4
        eng.run()      # drain so the arena frees cleanly

    def test_single_request_growth_retraces_at_boundaries(self, model, rng):
        """Submitting lengths 7, 8 (same bucket) then 9 (next bucket)
        one at a time: only the bucket crossing retraces."""
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=128)
        traces = []
        for i, n in enumerate((7, 8, 9)):
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            eng.submit(Request(i, prompt, max_new_tokens=1, temperature=0.0))
            eng._prefill_round()
            traces.append(eng.stats["prefill_jit_traces"])
        assert traces == [1, 1, 2], traces
        eng.run()

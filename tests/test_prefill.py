"""Fused bucketed prefill: one jitted dispatch per prefill batch.

The parity harness: ``fused_prefill=False`` keeps the eager
per-request prefill (un-jitted dense ``T.forward`` + host-side
``write_prompt_kv``) as the oracle, so the fused path is pinned by
fused-vs-eager **token**, **logit**, and **arena-content** parity —
across prompt lengths that straddle power-of-two bucket boundaries,
shared-prefix (``share_with``) requests, and mixed-length batches —
plus retrace regressions on ``stats["prefill_jit_traces"]``.
(The dispatch-count regressions live with the other launch-count pins
in ``tests/test_serving.py::TestDispatchCounts``.)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ParallelConfig, reduced
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request
from repro.serving.kv_cache import _bucket_pow2

PCFG = ParallelConfig(attention_impl="naive", remat="none")


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _engine_pair(cfg, params, **kw):
    fused = PagedEngine(cfg, params, page_size=4, num_pages=128,
                        fused_prefill=True, **kw)
    eager = PagedEngine(cfg, params, page_size=4, num_pages=128,
                        fused_prefill=False, **kw)
    return fused, eager


def _submit_all(engines, reqs):
    """Submit fresh Request copies to every engine (Requests mutate)."""
    for eng in engines:
        for r in reqs:
            eng.submit(Request(r.req_id, r.prompt,
                               max_new_tokens=r.max_new_tokens,
                               temperature=r.temperature,
                               share_with=r.share_with,
                               shared_len=r.shared_len))


def _arenas_equal(a, b):
    # both paths compute K/V in bf16; scan-vs-dense fusion may round
    # intermediates differently, so parity holds at bf16 resolution
    np.testing.assert_allclose(
        np.asarray(a.cache.k_arena, np.float32),
        np.asarray(b.cache.k_arena, np.float32), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(
        np.asarray(a.cache.v_arena, np.float32),
        np.asarray(b.cache.v_arena, np.float32), rtol=2e-2, atol=2e-2)


class TestPrefillParity:
    def test_bucket_boundary_lengths(self, model, rng):
        """7/8/9 and 15/16/17 straddle the 8- and 16-buckets: each
        prompt prefills as its own batch (separate rounds) and must
        match the eager oracle token-for-token, with identical arena
        contents after the prefill writes."""
        cfg, params = model
        fused, eager = _engine_pair(cfg, params)
        for i, n in enumerate((7, 8, 9, 15, 16, 17)):
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            req = Request(i, prompt, max_new_tokens=2, temperature=0.0)
            _submit_all((fused, eager), [req])
            fused._prefill_round()
            eager._prefill_round()
            _arenas_equal(fused, eager)   # prompt KV written identically
            f = fused.active[i].out_tokens
            e = eager.active[i].out_tokens
            assert f == e, (n, f, e)
        # and the decode rounds that follow agree too
        assert fused.run() == eager.run()

    def test_mixed_length_batch_parity(self, model, rng):
        """One submission spanning three buckets: the fused path stacks
        per-bucket batches (2, 3, and 1 requests) and must match the
        eager oracle exactly."""
        cfg, params = model
        fused, eager = _engine_pair(cfg, params)
        reqs = [Request(i, rng.integers(0, cfg.vocab_size, n).astype(np.int32),
                        max_new_tokens=3, temperature=0.0)
                for i, n in enumerate((7, 8, 9, 15, 16, 17))]
        _submit_all((fused, eager), reqs)
        res_f, res_e = fused.run(), eager.run()
        assert res_f == res_e
        assert fused.stats["prefills"] == 6
        # 3 distinct (length-bucket, batch-bucket) pairs -> 3 traces
        assert fused.stats["prefill_jit_traces"] == 3

    def test_shared_prefix_parity(self, model, rng):
        """`share_with` requests skip the shared pages in the scatter
        plan; fused and eager must agree on tokens, arena contents, and
        prefix accounting — including a sharer whose prompt is FULLY
        covered by the prefix (the all-no-op scatter batch)."""
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        fused, eager = _engine_pair(cfg, params)
        reqs = [Request(0, prompt, max_new_tokens=3, temperature=0.0),
                Request(1, prompt, max_new_tokens=3, temperature=0.0,
                        share_with=0, shared_len=12)]
        _submit_all((fused, eager), reqs)
        fused._prefill_round()
        eager._prefill_round()
        _arenas_equal(fused, eager)
        # a fully-covered sharer arrives next round: nothing to write,
        # and the no-op scatter must leave the arena untouched
        before = np.asarray(fused.cache.k_arena, np.float32).copy()
        _submit_all((fused, eager),
                    [Request(2, prompt, max_new_tokens=3, temperature=0.0,
                             share_with=0, shared_len=16)])
        fused._prefill_round()
        eager._prefill_round()
        np.testing.assert_array_equal(
            before, np.asarray(fused.cache.k_arena, np.float32))
        _arenas_equal(fused, eager)
        res_f, res_e = fused.run(), eager.run()
        assert res_f == res_e
        assert res_f[0] == res_f[1] == res_f[2]
        assert fused.cache.stats["prefix_hits"] == 2
        assert (fused.cache.stats["prefix_hits"]
                == eager.cache.stats["prefix_hits"])

    def test_prefill_forward_matches_eager_logits(self, model, rng):
        """Logit-level parity of the scan/masked forward against the
        dense ``T.forward`` oracle, at bf16 resolution, for a padded
        (bucketed) and an exact-fit prompt — plus the stacked K/V the
        scatter plan sources."""
        from repro.serving import engine as E
        cfg, params = model
        for n in (5, 8):
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            sp = _bucket_pow2(n)
            toks = np.zeros((1, sp), np.int32)
            toks[0, :n] = prompt
            lg_f, k_all, v_all = E._prefill_forward(
                cfg, PCFG, params, jnp.asarray(toks),
                jnp.asarray([n], jnp.int32), use_pallas=False,
                interpret=True)
            cache = T.init_cache(cfg, 1, n)
            lg_e, dense, _ = T.forward(
                cfg, PCFG, params, {"tokens": jnp.asarray(prompt)[None]},
                mode="prefill", cache=cache,
                lengths=jnp.asarray([n], jnp.int32))
            np.testing.assert_allclose(np.asarray(lg_f[0]),
                                       np.asarray(lg_e[0, 0]),
                                       rtol=2e-2, atol=2e-2)
            k_e, v_e = dense["group0"]["0_attn"]   # (L, 1, n, kvh, hd)
            np.testing.assert_allclose(
                np.asarray(k_all[:, 0, :n], np.float32),
                np.asarray(k_e[:, 0], np.float32), rtol=2e-2, atol=2e-2)
            np.testing.assert_allclose(
                np.asarray(v_all[:, 0, :n], np.float32),
                np.asarray(v_e[:, 0], np.float32), rtol=2e-2, atol=2e-2)

    def test_pallas_path_matches_reference(self, model, rng):
        """The length-masked Pallas flash kernel drives the same fused
        prefill to the same tokens as the jnp reference path."""
        cfg, params = model
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (5, 7)]
        outs = []
        for use_pallas in (False, True):
            eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                              use_pallas=use_pallas, interpret=True)
            for i, p in enumerate(prompts):
                eng.submit(Request(i, p, max_new_tokens=2, temperature=0.0))
            outs.append(eng.run())
        assert outs[0] == outs[1]


class TestPrefillRetrace:
    def test_traces_bounded_by_distinct_buckets(self, model, rng):
        """N prompts of varied lengths compile at most one trace per
        distinct (length-bucket, batch-bucket) pair — and resubmitting
        the same pattern compiles nothing new."""
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=128)

        def burst(base):
            # lengths 5..8 share the 8-bucket (batch of 4); 9 and 12
            # share the 16-bucket (batch of 2, padded to 2)
            for j, n in enumerate((5, 6, 7, 8, 9, 12)):
                prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                eng.submit(Request(base + j, prompt, max_new_tokens=1,
                                   temperature=0.0))
            eng._prefill_round()

        burst(0)
        assert eng.stats["prefill_jit_traces"] == 2
        burst(10)      # identical bucket pattern -> trace cache hits only
        assert eng.stats["prefill_jit_traces"] == 2
        assert eng.stats["fused_prefill_dispatches"] == 4
        eng.run()      # drain so the arena frees cleanly

    def test_single_request_growth_retraces_at_boundaries(self, model, rng):
        """Submitting lengths 7, 8 (same bucket) then 9 (next bucket)
        one at a time: only the bucket crossing retraces."""
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=128)
        traces = []
        for i, n in enumerate((7, 8, 9)):
            prompt = rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            eng.submit(Request(i, prompt, max_new_tokens=1, temperature=0.0))
            eng._prefill_round()
            traces.append(eng.stats["prefill_jit_traces"])
        assert traces == [1, 1, 2], traces
        eng.run()

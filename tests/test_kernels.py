"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
with hypothesis shape/dtype sweeps (fixed-example sweeps when
hypothesis is not installed; see tests/_compat.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _compat import given, settings, st

from repro.kernels.rowclone import ref as rc_ref, rowclone as rc
from repro.kernels.drange import ref as dr_ref, drange as dr
from repro.kernels.flash_attention import ref as fa_ref, flash_attention as fa
from repro.kernels.paged_attention import ref as pa_ref, paged_attention as pa

SETTINGS = dict(max_examples=10, deadline=None)


class TestRowClone:
    @settings(**SETTINGS)
    @given(rows=st.integers(4, 96), cols=st.integers(8, 300),
           dtype=st.sampled_from([jnp.float32, jnp.bfloat16, jnp.int32]))
    def test_copy_matches_ref(self, rows, cols, dtype):
        x = jnp.arange(rows * cols).reshape(rows, cols).astype(dtype)
        out = rc.copy_2d(x, block_rows=16, block_cols=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(out, np.float32),
                                      np.asarray(rc_ref.copy_2d(x), np.float32))

    @settings(**SETTINGS)
    @given(rows=st.integers(4, 64), cols=st.integers(8, 200),
           value=st.floats(-10, 10, allow_nan=False))
    def test_init_matches_ref(self, rows, cols, value):
        out = rc.init_2d((rows, cols), value, jnp.float32,
                         block_rows=16, block_cols=64, interpret=True)
        np.testing.assert_allclose(out, rc_ref.init_2d((rows, cols), value),
                                   rtol=1e-6)

    @settings(**SETTINGS)
    @given(n_pages=st.integers(4, 24), elems=st.integers(16, 256),
           n_copies=st.integers(1, 6), seed=st.integers(0, 99))
    def test_page_copy_matches_ref(self, n_pages, elems, n_copies, seed):
        n_copies = min(n_copies, n_pages // 2)  # need disjoint src/dst sets
        rng = np.random.default_rng(seed)
        arena = jnp.asarray(rng.normal(size=(n_pages, elems)).astype(np.float32))
        pages = rng.permutation(n_pages)
        src = jnp.asarray(pages[:n_copies].astype(np.int32))
        dst = jnp.asarray(pages[n_copies:2 * n_copies].astype(np.int32))
        out = rc.page_copy(arena, src, dst, block_cols=64, interpret=True)
        np.testing.assert_array_equal(out, rc_ref.page_copy(arena, src, dst))

    def test_page_init_matches_ref(self):
        arena = jnp.ones((8, 128), jnp.float32)
        dst = jnp.asarray([1, 5], jnp.int32)
        out = rc.page_init(arena, dst, 0.0, block_cols=64, interpret=True)
        np.testing.assert_array_equal(out, rc_ref.page_init(arena, dst, 0.0))


class TestRowCloneBatched:
    """Layer-batched page ops + KV scatter: one launch, all layers."""

    @settings(**SETTINGS)
    @given(layers=st.integers(1, 4), n_pages=st.integers(4, 16),
           elems=st.integers(16, 200), n_copies=st.integers(1, 5),
           seed=st.integers(0, 99))
    def test_page_copy_batched_matches_ref(self, layers, n_pages, elems,
                                           n_copies, seed):
        n_copies = min(n_copies, n_pages // 2)
        rng = np.random.default_rng(seed)
        arena = jnp.asarray(
            rng.normal(size=(layers, n_pages, elems)).astype(np.float32))
        pages = rng.permutation(n_pages)
        src = jnp.asarray(pages[:n_copies].astype(np.int32))
        dst = jnp.asarray(pages[n_copies:2 * n_copies].astype(np.int32))
        out = rc.page_copy_batched(arena, src, dst, block_cols=64,
                                   interpret=True)
        np.testing.assert_array_equal(
            out, rc_ref.page_copy_batched(arena, src, dst))

    @settings(**SETTINGS)
    @given(layers=st.integers(1, 4), n_init=st.integers(1, 6),
           value=st.floats(-5, 5, allow_nan=False))
    def test_page_init_batched_matches_ref(self, layers, n_init, value):
        arena = jnp.ones((layers, 12, 96), jnp.float32)
        dst = jnp.asarray(
            np.random.default_rng(n_init).permutation(12)[:n_init].astype(np.int32))
        out = rc.page_init_batched(arena, dst, value, block_cols=64,
                                   interpret=True)
        np.testing.assert_allclose(
            out, rc_ref.page_init_batched(arena, dst, value), rtol=1e-6)

    @settings(**SETTINGS)
    @given(layers=st.integers(1, 4), batch=st.integers(1, 6),
           ps=st.sampled_from([4, 8, 16]), elems=st.sampled_from([16, 48, 64]),
           seed=st.integers(0, 99))
    def test_kv_scatter_matches_ref(self, layers, batch, ps, elems, seed):
        rng = np.random.default_rng(seed)
        arena = jnp.asarray(
            rng.normal(size=(layers, 8, ps, elems)).astype(np.float32))
        # unique (page, slot) pairs — duplicate pairs are undefined
        flat = rng.permutation(8 * ps)[:batch]
        pages = jnp.asarray((flat // ps).astype(np.int32))
        slots = jnp.asarray((flat % ps).astype(np.int32))
        new = jnp.asarray(
            rng.normal(size=(layers, batch, elems)).astype(np.float32))
        out = rc.kv_scatter(arena, pages, slots, new, interpret=True)
        np.testing.assert_array_equal(
            out, rc_ref.kv_scatter(arena, pages, slots, new))

    def test_single_layer(self):
        arena = jnp.arange(2 * 64, dtype=jnp.float32).reshape(1, 2, 64)
        out = rc.page_copy_batched(arena, jnp.asarray([0], jnp.int32),
                                   jnp.asarray([1], jnp.int32),
                                   block_cols=64, interpret=True)
        np.testing.assert_array_equal(out[0, 1], arena[0, 0])

    def test_non_aligned_page_elems(self):
        # page_elems not a multiple of block_cols (or the VMEM lane width):
        # interpret mode masks the ragged final column block
        arena = jnp.asarray(np.random.default_rng(3).normal(
            size=(2, 6, 100)).astype(np.float32))
        src = jnp.asarray([0, 2], jnp.int32)
        dst = jnp.asarray([1, 3], jnp.int32)
        out = rc.page_copy_batched(arena, src, dst, block_cols=64,
                                   interpret=True)
        np.testing.assert_array_equal(
            out, rc_ref.page_copy_batched(arena, src, dst))

    def test_duplicate_destination_pages_init(self):
        # duplicate destinations are well-defined for init (same fill)
        arena = jnp.ones((2, 8, 32), jnp.float32)
        dst = jnp.asarray([3, 3, 5], jnp.int32)
        out = rc.page_init_batched(arena, dst, 0.0, block_cols=32,
                                   interpret=True)
        assert float(jnp.abs(out[:, [3, 5]]).sum()) == 0.0
        assert float(jnp.abs(out[:, [0, 1, 2, 4, 6, 7]] - 1.0).sum()) == 0.0

    def test_empty_op_batch_is_noop(self):
        from repro.kernels.rowclone import ops as rc_ops
        arena = jnp.ones((2, 4, 3, 16), jnp.float32)
        empty = jnp.asarray([], jnp.int32)
        out = rc_ops.pim_page_copy_batched(arena, empty, empty)
        np.testing.assert_array_equal(out, jnp.ones((2, 4, 3, 16)))
        out = rc_ops.pim_page_init_batched(out, empty, 0.0)
        np.testing.assert_array_equal(out, jnp.ones((2, 4, 3, 16)))
        out = rc_ops.pim_kv_scatter(out, empty, empty,
                                    jnp.zeros((2, 0, 16), jnp.float32))
        np.testing.assert_array_equal(out, jnp.ones((2, 4, 3, 16)))

    def test_wrapper_pallas_matches_jnp_path(self):
        from repro.kernels.rowclone import ops as rc_ops
        rng = np.random.default_rng(11)
        arena = jnp.asarray(rng.normal(size=(3, 10, 4, 2, 8)).astype(np.float32))
        pages = jnp.asarray([1, 4, 7], jnp.int32)
        slots = jnp.asarray([0, 3, 2], jnp.int32)
        new = jnp.asarray(rng.normal(size=(3, 3, 2, 8)).astype(np.float32))
        a = rc_ops.pim_kv_scatter(arena.copy(), pages, slots, new,
                                  use_pallas=True, interpret=True)
        b = rc_ops.pim_kv_scatter(arena.copy(), pages, slots, new,
                                  use_pallas=False)
        np.testing.assert_array_equal(a, b)


class TestDRange:
    @settings(**SETTINGS)
    @given(rows=st.integers(1, 60), cols=st.sampled_from([16, 64, 128]),
           s0=st.integers(0, 2**32 - 1), s1=st.integers(0, 2**32 - 1))
    def test_kernel_bitexact_vs_ref(self, rows, cols, s0, s1):
        seed = jnp.asarray([s0, s1], jnp.uint32)
        out = dr.random_u32(seed, rows, cols, block_rows=16, interpret=True)
        expect = dr_ref.random_u32(seed, rows, cols)
        assert (np.asarray(out) == np.asarray(expect)).all()

    def test_statistical_quality(self):
        seed = jnp.asarray([7, 9], jnp.uint32)
        out = np.asarray(dr.random_u32(seed, 256, 64, interpret=True))
        bits = np.unpackbits(out.view(np.uint8))
        assert abs(bits.mean() - 0.5) < 0.01
        # chi-square-lite on bytes
        counts = np.bincount(out.view(np.uint8).ravel(), minlength=256)
        assert counts.std() / counts.mean() < 0.1

    def test_distinct_seeds_distinct_streams(self):
        a = dr.random_u32(jnp.asarray([1, 2], jnp.uint32), 16, 16, interpret=True)
        b = dr.random_u32(jnp.asarray([1, 3], jnp.uint32), 16, 16, interpret=True)
        assert (np.asarray(a) != np.asarray(b)).any()


class TestFlashAttention:
    @settings(**SETTINGS)
    @given(b=st.integers(1, 3), h=st.sampled_from([2, 4]),
           kvh=st.sampled_from([1, 2]), sq=st.integers(8, 130),
           sk=st.integers(8, 130), d=st.sampled_from([16, 32]),
           causal=st.booleans())
    def test_matches_naive(self, b, h, kvh, sq, sk, d, causal):
        if h % kvh:
            h = kvh * (h // kvh or 1)
        rng = np.random.default_rng(b * 1000 + sq)
        q = jnp.asarray(rng.normal(size=(b, h, sq, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, kvh, sk, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, kvh, sk, d)).astype(np.float32))
        out = fa.flash_attention(q, k, v, causal=causal, block_q=32,
                                 block_k=32, interpret=True)
        expect = fa_ref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(out, expect, rtol=3e-5, atol=3e-5)

    def test_bf16(self):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(2, 4, 64, 32))).astype(jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(2, 2, 64, 32))).astype(jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(2, 2, 64, 32))).astype(jnp.bfloat16)
        out = fa.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                                 interpret=True)
        expect = fa_ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_lengths_mask_matches_ref(self):
        # per-sequence valid-length masking (the fused bucketed-prefill
        # contract): kernel vs jnp oracle, and the masked rows must
        # equal an unpadded run of the same prompts
        rng = np.random.default_rng(3)
        b, h, kvh, s, d = 3, 4, 2, 40, 16
        q = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, kvh, s, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, kvh, s, d)).astype(np.float32))
        lens = jnp.asarray([40, 23, 9], jnp.int32)
        out = fa.flash_attention(q, k, v, causal=True, block_q=16,
                                 block_k=16, lengths=lens, interpret=True)
        expect = fa_ref.attention(q, k, v, causal=True, lengths=lens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=3e-5, atol=3e-5)
        # row 2's valid prefix matches the unpadded single-sequence run
        n = 9
        solo = fa_ref.attention(q[2:3, :, :n], k[2:3, :, :n], v[2:3, :, :n],
                                causal=True)
        np.testing.assert_allclose(np.asarray(out[2, :, :n]),
                                   np.asarray(solo[0]),
                                   rtol=3e-5, atol=3e-5)


class TestFlashPrefixKV:
    """The chunked-prefill prefix-KV path: chunk queries attend causally
    over the chunk plus non-causally over already-committed prefix KV
    with its own per-row length mask."""

    @staticmethod
    def _qkv(rng, b, h, kvh, s, d):
        q = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, kvh, s, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, kvh, s, d)).astype(np.float32))
        return q, k, v

    def test_composes_with_full_sequence_oracle(self):
        # split a full causal attention at s0: prefix KV + chunk queries
        # through the prefix path must equal the full run's chunk rows —
        # the prefix and chunk masks compose into plain causal attention
        rng = np.random.default_rng(7)
        b, h, kvh, s, s0, d = 2, 4, 2, 24, 10, 16
        q, k, v = self._qkv(rng, b, h, kvh, s, d)
        lens = jnp.asarray([24, 17], jnp.int32)
        full = fa_ref.attention(q, k, v, causal=True, lengths=lens)
        qc, kc, vc = q[:, :, s0:], k[:, :, s0:], v[:, :, s0:]
        kp, vp = k[:, :, :s0], v[:, :, :s0]
        plens = jnp.asarray([s0, s0], jnp.int32)
        clens = lens - s0
        for impl, kw in ((fa_ref.attention, {}),
                         (fa.flash_attention,
                          dict(block_q=8, block_k=8, interpret=True))):
            out = impl(qc, kc, vc, causal=True, lengths=clens,
                       k_prefix=kp, v_prefix=vp, prefix_lengths=plens, **kw)
            for bi in range(b):
                n = int(clens[bi])       # rows past lens are undefined
                np.testing.assert_allclose(
                    np.asarray(out)[bi, :, :n],
                    np.asarray(full)[bi, :, s0:s0 + n],
                    rtol=3e-5, atol=3e-5)

    def test_empty_prefix_degenerates_to_plain_path(self):
        # prefix_lengths == 0 must reproduce the prefix-less kernel
        # exactly (the PR 4 fused-prefill behavior)
        rng = np.random.default_rng(8)
        b, h, kvh, s, sp, d = 2, 4, 2, 16, 12, 16
        q, k, v = self._qkv(rng, b, h, kvh, s, d)
        kp = jnp.asarray(rng.normal(size=(b, kvh, sp, d)).astype(np.float32))
        vp = jnp.asarray(rng.normal(size=(b, kvh, sp, d)).astype(np.float32))
        lens = jnp.asarray([16, 11], jnp.int32)
        zero = jnp.zeros((b,), jnp.int32)
        plain = fa.flash_attention(q, k, v, causal=True, block_q=8,
                                   block_k=8, lengths=lens, interpret=True)
        with_pref = fa.flash_attention(q, k, v, causal=True, block_q=8,
                                       block_k=8, lengths=lens, k_prefix=kp,
                                       v_prefix=vp, prefix_lengths=zero,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(with_pref), np.asarray(plain),
                                   rtol=1e-6, atol=1e-6)

    def test_kernel_matches_ref_ragged(self):
        # per-row ragged prefix AND chunk lengths, tile sizes that force
        # kv blocks to straddle the prefix/chunk boundary: kernel vs ref
        rng = np.random.default_rng(9)
        b, h, kvh, sc, sp, d = 3, 4, 2, 20, 24, 16
        q, kc, vc = self._qkv(rng, b, h, kvh, sc, d)
        kp = jnp.asarray(rng.normal(size=(b, kvh, sp, d)).astype(np.float32))
        vp = jnp.asarray(rng.normal(size=(b, kvh, sp, d)).astype(np.float32))
        plens = jnp.asarray([0, 13, 24], jnp.int32)
        lens = jnp.asarray([20, 7, 1], jnp.int32)
        out = fa.flash_attention(q, kc, vc, causal=True, block_q=8,
                                 block_k=16, lengths=lens, k_prefix=kp,
                                 v_prefix=vp, prefix_lengths=plens,
                                 interpret=True)
        expect = fa_ref.attention(q, kc, vc, causal=True, lengths=lens,
                                  k_prefix=kp, v_prefix=vp,
                                  prefix_lengths=plens)
        for bi in range(b):              # rows past lens are undefined
            n = int(lens[bi])
            np.testing.assert_allclose(np.asarray(out)[bi, :, :n],
                                       np.asarray(expect)[bi, :, :n],
                                       rtol=3e-5, atol=3e-5)


class TestPagedAttention:
    @settings(**SETTINGS)
    @given(b=st.integers(1, 3), kvh=st.sampled_from([1, 2, 4]),
           g=st.sampled_from([1, 2, 4]), ps=st.sampled_from([8, 16]),
           npages=st.integers(2, 6), seed=st.integers(0, 50))
    def test_matches_ref(self, b, kvh, g, ps, npages, seed):
        h = kvh * g
        d = 32
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
        total = npages * b + 1
        ka = jnp.asarray(rng.normal(size=(total, ps, kvh, d)).astype(np.float32))
        va = jnp.asarray(rng.normal(size=(total, ps, kvh, d)).astype(np.float32))
        bt = jnp.asarray(rng.permutation(npages * b).reshape(b, npages).astype(np.int32))
        lengths = jnp.asarray(rng.integers(1, npages * ps + 1, b).astype(np.int32))
        out = pa.paged_attention(q, ka, va, bt, lengths, interpret=True)
        expect = pa_ref.paged_attention(q, ka, va, bt, lengths)
        np.testing.assert_allclose(out, expect, rtol=3e-5, atol=3e-5)


class TestPagedAttentionFusion:
    """The decode-fusion hooks: LSE-returning variant and the in-kernel
    current-token (self) merge, vs the jnp oracle and a dense oracle."""

    @staticmethod
    def _setup(seed=0, b=3, kvh=2, g=2, ps=8, npages=4, d=32):
        h = kvh * g
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
        total = npages * b + 1
        ka = jnp.asarray(rng.normal(size=(total, ps, kvh, d)).astype(np.float32))
        va = jnp.asarray(rng.normal(size=(total, ps, kvh, d)).astype(np.float32))
        bt = jnp.asarray(rng.permutation(npages * b).reshape(b, npages).astype(np.int32))
        lens = jnp.asarray(rng.integers(1, npages * ps, b).astype(np.int32))
        ks = jnp.asarray(rng.normal(size=(b, kvh, d)).astype(np.float32))
        vs = jnp.asarray(rng.normal(size=(b, kvh, d)).astype(np.float32))
        return q, ka, va, bt, lens, ks, vs

    def test_lse_variant_matches_ref(self):
        for seed in range(3):
            q, ka, va, bt, lens, _, _ = self._setup(seed)
            o1, m1, l1 = pa.paged_attention(q, ka, va, bt, lens,
                                            interpret=True, return_lse=True)
            o2, m2, l2 = pa_ref.paged_attention(q, ka, va, bt, lens,
                                                return_lse=True)
            np.testing.assert_allclose(o1, o2, rtol=3e-5, atol=3e-5)
            np.testing.assert_allclose(m1, m2, rtol=3e-5, atol=3e-5)
            np.testing.assert_allclose(l1, l2, rtol=3e-5, atol=3e-5)

    def test_self_token_merge_matches_ref(self):
        for seed in range(3):
            q, ka, va, bt, lens, ks, vs = self._setup(seed)
            out = pa.paged_attention(q, ka, va, bt, lens, interpret=True,
                                     k_self=ks, v_self=vs)
            expect = pa_ref.paged_attention(q, ka, va, bt, lens,
                                            k_self=ks, v_self=vs)
            np.testing.assert_allclose(out, expect, rtol=3e-5, atol=3e-5)

    def test_self_token_merge_matches_dense_oracle(self):
        # independent oracle: gather history + append self token, then a
        # plain (non-streaming) softmax per sequence
        b, kvh, g, d = 2, 2, 2, 32
        h = kvh * g
        q, ka, va, bt, lens, ks, vs = self._setup(7, b=b, kvh=kvh, g=g, d=d)
        out = np.asarray(pa.paged_attention(q, ka, va, bt, lens,
                                            interpret=True,
                                            k_self=ks, v_self=vs))
        scale = d ** -0.5
        for i in range(b):
            L = int(lens[i])
            kk = np.asarray(ka[bt[i]]).reshape(-1, kvh, d)[:L]
            vv = np.asarray(va[bt[i]]).reshape(-1, kvh, d)[:L]
            kk = np.concatenate([kk, np.asarray(ks[i])[None]], 0)
            vv = np.concatenate([vv, np.asarray(vs[i])[None]], 0)
            qi = np.asarray(q[i]).reshape(kvh, g, d)
            s = np.einsum("kgd,skd->kgs", qi, kk) * scale
            p = np.exp(s - s.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            oo = np.einsum("kgs,skd->kgd", p, vv).reshape(h, d)
            np.testing.assert_allclose(out[i], oo, rtol=3e-5, atol=3e-5)

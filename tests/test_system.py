"""End-to-end behaviour of the PiDRAM core: paper-number reproduction,
subarray discovery, allocator constraints, POC protocol, RowClone and
D-RaNGe case studies on the simulated prototype."""

import numpy as np
import pytest

from repro.core import (Blocking, CoherencePolicy, DeviceLib, DRAMGeometry,
                        DRangeTRNG, EndToEndCosts, Instruction,
                        MemoryController, Opcode, PimOpsController,
                        SimulatedDRAM, allocator_from_subarray_map,
                        characterize, discover_subarrays)

PAPER = {
    "copy_no_coherence": 118.5,
    "init_no_coherence": 88.7,
    "copy_coherence": 14.6,
    "init_coherence": 12.6,
}


@pytest.fixture(scope="module")
def proto():
    dev = SimulatedDRAM(DRAMGeometry(num_subarrays=8, rows_per_subarray=32))
    mc = MemoryController(dev)
    return dev, mc


class TestPaperNumbers:
    def test_rowclone_speedups_match_paper(self, proto):
        _, mc = proto
        sp = EndToEndCosts(mc).speedups()
        for k, target in PAPER.items():
            assert abs(sp[k] - target) / target < 0.10, (k, sp[k], target)

    def test_drange_latency_throughput_match_paper(self, proto):
        _, mc = proto
        costs = EndToEndCosts(mc)
        assert abs(costs.drange_latency_ns() - 220.0) / 220.0 < 0.10
        assert abs(costs.drange_throughput_mbps() - 8.30) / 8.30 < 0.10

    def test_rowclone_sequence_violates_timings(self, proto):
        _, mc = proto
        res = mc.run_sequence("rowclone_copy", 0, 0)
        gaps = [c.at_ns for c in res.commands]
        # ACT->PRE and PRE->ACT gaps are far below tRAS/tRP
        assert gaps[1] - gaps[0] < mc.t.tRAS / 4
        assert gaps[2] - gaps[1] < mc.t.tRP / 4


class TestSubarrayDiscovery:
    def test_discovered_groups_match_hidden_map(self, proto):
        dev, mc = proto
        smap = discover_subarrays(mc, max_rows=64)
        # groups are internally consistent with the device's hidden map
        for g, rows in smap.members.items():
            true = {dev._true_subarray_of(r) for r in rows}
            assert len(true) == 1, f"group {g} spans subarrays {true}"

    def test_rowclone_fails_across_subarrays(self, proto):
        dev, mc = proto
        smap = discover_subarrays(mc, max_rows=32)
        g0 = smap.members[0][0]
        other = next(r for r in range(32) if not smap.same_subarray(g0, r))
        pattern = np.full(dev.geometry.row_bytes, 0xAB, np.uint8)
        dev.write_row(g0, pattern)
        dev.write_row(other, ~pattern)
        res = mc.run_sequence("rowclone_copy", g0, other)
        assert not res.ok
        assert (dev.read_row(other) == ~pattern).all()  # unchanged


class TestEndToEndWorkflow:
    def test_copy_init_workflow(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        smap = discover_subarrays(mc, max_rows=32)
        alloc = allocator_from_subarray_map(smap)
        poc = PimOpsController(mc)
        lib = DeviceLib(poc, alloc)
        src, dst = alloc.alloc_copy_pair(2)
        pat = np.random.default_rng(1).integers(
            0, 256, dev.geometry.row_bytes, dtype=np.uint8)
        dev.write_row(src.rows[0], pat)
        rec = lib.copy(src, dst, blocking=Blocking.FIN)
        assert rec.ok
        assert (dev.read_row(dst.rows[0]) == pat).all()
        rec = lib.init(dst)
        assert rec.ok
        assert (dev.read_row(dst.rows[0]) == 0).all()
        # PiM path is far faster than the CPU path
        cpu = lib.cpu_copy(src, dst)
        assert cpu.latency_ns > 50 * rec.latency_ns

    def test_coherence_costs_charged_when_dirty(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        smap = discover_subarrays(mc, max_rows=16)
        alloc = allocator_from_subarray_map(smap)
        lib = DeviceLib(PimOpsController(mc), alloc,
                        coherence=CoherencePolicy.PRECISE)
        src, dst = alloc.alloc_copy_pair(1)
        clean = lib.copy(src, dst).latency_ns
        alloc.touch_cpu_write(src)     # CPU dirtied the source
        dirty = lib.copy(src, dst).latency_ns
        assert dirty > clean + 1000    # CLFLUSH cost appears


class TestPOCProtocol:
    def test_isa_roundtrip(self):
        insn = Instruction(Opcode.RC_COPY, 123, 456)
        assert Instruction.decode(insn.encode()) == insn

    def test_flag_handshake(self, proto):
        _, mc = proto
        poc = PimOpsController(mc)
        poc.store_instruction(Instruction(Opcode.RC_COPY, 0, 0).encode())
        poc.store_start()
        flags = poc.load_flags()
        assert flags.ack and flags.fin and not flags.start


class TestMemCtrlInvariants:
    """Scheduler invariants under batched command sequences."""

    @staticmethod
    def _mc():
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        return MemoryController(dev)

    def test_now_ns_monotonic_across_batches(self):
        mc = self._mc()
        stamps = [mc.now_ns]
        for pairs in ([(0, 1)], [(0, 1), (2, 3), (4, 5)], [(1, 2)] * 5):
            mc.run_sequence_batch("rowclone_copy", pairs)
            stamps.append(mc.now_ns)
        assert all(b >= a for a, b in zip(stamps, stamps[1:]))
        trace_ts = [c.at_ns for c in mc.trace]
        assert trace_ts == sorted(trace_ts)

    def test_trace_and_stats_consistent_for_batches(self):
        mc = self._mc()
        rows = discover_subarrays(mc, max_rows=32).members[0][:6]
        mc.trace.clear()
        mc.stats["commands"] = mc.stats["pim_ops"] = 0
        t0 = mc.now_ns
        res = mc.run_sequence_batch("rowclone_copy",
                                    list(zip(rows[0::2], rows[1::2])))
        assert res.ok
        assert mc.stats["commands"] == len(mc.trace)
        assert res.commands == mc.trace          # whole trace is this batch
        assert mc.stats["pim_ops"] == 3
        assert mc.stats["pim_batches"] == 1
        assert abs(res.elapsed_ns - (mc.now_ns - t0)) < 1e-9
        # a second batch appends, never rewrites
        before = list(mc.trace)
        mc.run_sequence_batch("rowclone_copy", [(6, 7)])
        assert mc.trace[:len(before)] == before
        assert mc.stats["pim_batches"] == 2

    def test_batch_elapsed_equals_sum_of_singles(self):
        a, b = self._mc(), self._mc()
        singles = sum(a.run_sequence("rowclone_copy", 0, 1).elapsed_ns
                      for _ in range(4))
        batched = b.run_sequence_batch("rowclone_copy", [(0, 1)] * 4).elapsed_ns
        # command timing doesn't amortize — only the POC handshake does
        assert abs(batched - singles) < 1e-9

    def test_batch_ok_is_conjunction(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        smap = discover_subarrays(mc, max_rows=32)
        same_a, same_b = smap.members[0][:2]
        other = next(r for r in range(32) if not smap.same_subarray(same_a, r))
        # second pair crosses subarrays -> that RowClone fails, batch ok=False
        res = mc.run_sequence_batch("rowclone_copy",
                                    [(same_a, same_b), (same_a, other)])
        assert not res.ok

    def test_batched_speedups_within_paper_ranges(self, proto):
        _, mc = proto
        costs = EndToEndCosts(mc)
        sp = costs.speedups()
        sp1 = costs.batched_speedups(1)
        for k in PAPER:
            assert abs(sp1[k] - sp[k]) / sp[k] < 1e-9   # n=1 degenerates
        prev = sp1
        for n in (2, 4, 16, 64):
            b = costs.batched_speedups(n)
            for k in PAPER:
                assert b[k] >= prev[k] - 1e-9           # monotone in n
            # coherent speedups stay in the paper's ballpark: the cache
            # maintenance cost is per-row and does not amortize
            assert PAPER["copy_coherence"] <= b["copy_coherence"] \
                <= 1.2 * PAPER["copy_coherence"]
            assert PAPER["init_coherence"] <= b["init_coherence"] \
                <= 1.2 * PAPER["init_coherence"]
            prev = b

    def test_batched_handshake_cheaper_than_looped(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        smap = discover_subarrays(mc, max_rows=32)
        alloc = allocator_from_subarray_map(smap)
        lib = DeviceLib(PimOpsController(mc), alloc)
        src, dst = alloc.alloc_copy_pair(4)
        looped = lib.copy(src, dst, batch=False).latency_ns
        batched = lib.copy(src, dst, batch=True).latency_ns
        saved = 3 * mc.poc_handshake_ns()   # 4 handshakes -> 1
        assert abs((looped - batched) - saved) / saved < 0.05

    def test_poc_batch_single_handshake_flags(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        poc = PimOpsController(mc)
        rows = discover_subarrays(mc, max_rows=32).members[0][:4]
        mc.stats["pim_batches"] = 0
        words = [Instruction(Opcode.RC_COPY, rows[0], rows[1]).encode(),
                 Instruction(Opcode.RC_COPY, rows[2], rows[3]).encode()]
        poc.store_instruction_buffer(words)
        poc.store_start()
        flags = poc.load_flags()
        assert flags.ack and flags.fin and not flags.start
        assert poc.last_ok
        assert mc.stats["pim_batches"] == 1
        assert poc.stats.executed["RC_COPY"] == 2

    def test_poc_empty_batch_is_noop(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        poc = PimOpsController(mc)
        # leave a stale word in the instruction register...
        poc.store_instruction(Instruction(Opcode.RC_COPY, 0, 1).encode())
        poc.store_start()
        executed_before = dict(poc.stats.executed)
        t_before = mc.now_ns
        # ...then an EMPTY staged batch must not re-execute it
        poc.store_instruction_buffer([])
        poc.store_start()
        assert poc.load_flags().fin and poc.last_ok
        assert dict(poc.stats.executed) == executed_before
        assert mc.now_ns == t_before


class TestDRaNGe:
    def test_trng_end_to_end(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        poc = PimOpsController(mc)
        cmap = characterize(mc, rows=list(range(16)), n_bits=1024, samples=80)
        assert cmap.total_cells > 0
        trng = DRangeTRNG(poc, cmap)
        bits = trng.random_bits(1024)
        assert bits.shape == (1024,)
        frac = bits.mean()
        assert 0.30 < frac < 0.70          # metastable cells near 0.5
        from repro.core.drange import runs_count, serial_correlation
        assert abs(serial_correlation(bits)) < 0.2
        r = runs_count(bits)
        assert 0.3 * len(bits) < r < 0.7 * len(bits)

    def test_trng_streams_differ(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        poc = PimOpsController(mc)
        cmap = characterize(mc, rows=list(range(16)), n_bits=1024, samples=80)
        trng = DRangeTRNG(poc, cmap)
        a = trng.random_bits(256)
        b = trng.random_bits(256)
        assert (a != b).any()

"""End-to-end behaviour of the PiDRAM core: paper-number reproduction,
subarray discovery, allocator constraints, POC protocol, RowClone and
D-RaNGe case studies on the simulated prototype."""

import numpy as np
import pytest

from repro.core import (Blocking, CoherencePolicy, DeviceLib, DRAMGeometry,
                        DRangeTRNG, EndToEndCosts, Instruction,
                        MemoryController, Opcode, PimOpsController,
                        SimulatedDRAM, allocator_from_subarray_map,
                        characterize, discover_subarrays)

PAPER = {
    "copy_no_coherence": 118.5,
    "init_no_coherence": 88.7,
    "copy_coherence": 14.6,
    "init_coherence": 12.6,
}


@pytest.fixture(scope="module")
def proto():
    dev = SimulatedDRAM(DRAMGeometry(num_subarrays=8, rows_per_subarray=32))
    mc = MemoryController(dev)
    return dev, mc


class TestPaperNumbers:
    def test_rowclone_speedups_match_paper(self, proto):
        _, mc = proto
        sp = EndToEndCosts(mc).speedups()
        for k, target in PAPER.items():
            assert abs(sp[k] - target) / target < 0.10, (k, sp[k], target)

    def test_drange_latency_throughput_match_paper(self, proto):
        _, mc = proto
        costs = EndToEndCosts(mc)
        assert abs(costs.drange_latency_ns() - 220.0) / 220.0 < 0.10
        assert abs(costs.drange_throughput_mbps() - 8.30) / 8.30 < 0.10

    def test_rowclone_sequence_violates_timings(self, proto):
        _, mc = proto
        res = mc.run_sequence("rowclone_copy", 0, 0)
        gaps = [c.at_ns for c in res.commands]
        # ACT->PRE and PRE->ACT gaps are far below tRAS/tRP
        assert gaps[1] - gaps[0] < mc.t.tRAS / 4
        assert gaps[2] - gaps[1] < mc.t.tRP / 4


class TestSubarrayDiscovery:
    def test_discovered_groups_match_hidden_map(self, proto):
        dev, mc = proto
        smap = discover_subarrays(mc, max_rows=64)
        # groups are internally consistent with the device's hidden map
        for g, rows in smap.members.items():
            true = {dev._true_subarray_of(r) for r in rows}
            assert len(true) == 1, f"group {g} spans subarrays {true}"

    def test_rowclone_fails_across_subarrays(self, proto):
        dev, mc = proto
        smap = discover_subarrays(mc, max_rows=32)
        g0 = smap.members[0][0]
        other = next(r for r in range(32) if not smap.same_subarray(g0, r))
        pattern = np.full(dev.geometry.row_bytes, 0xAB, np.uint8)
        dev.write_row(g0, pattern)
        dev.write_row(other, ~pattern)
        res = mc.run_sequence("rowclone_copy", g0, other)
        assert not res.ok
        assert (dev.read_row(other) == ~pattern).all()  # unchanged


class TestEndToEndWorkflow:
    def test_copy_init_workflow(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        smap = discover_subarrays(mc, max_rows=32)
        alloc = allocator_from_subarray_map(smap)
        poc = PimOpsController(mc)
        lib = DeviceLib(poc, alloc)
        src, dst = alloc.alloc_copy_pair(2)
        pat = np.random.default_rng(1).integers(
            0, 256, dev.geometry.row_bytes, dtype=np.uint8)
        dev.write_row(src.rows[0], pat)
        rec = lib.copy(src, dst, blocking=Blocking.FIN)
        assert rec.ok
        assert (dev.read_row(dst.rows[0]) == pat).all()
        rec = lib.init(dst)
        assert rec.ok
        assert (dev.read_row(dst.rows[0]) == 0).all()
        # PiM path is far faster than the CPU path
        cpu = lib.cpu_copy(src, dst)
        assert cpu.latency_ns > 50 * rec.latency_ns

    def test_coherence_costs_charged_when_dirty(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        smap = discover_subarrays(mc, max_rows=16)
        alloc = allocator_from_subarray_map(smap)
        lib = DeviceLib(PimOpsController(mc), alloc,
                        coherence=CoherencePolicy.PRECISE)
        src, dst = alloc.alloc_copy_pair(1)
        clean = lib.copy(src, dst).latency_ns
        alloc.touch_cpu_write(src)     # CPU dirtied the source
        dirty = lib.copy(src, dst).latency_ns
        assert dirty > clean + 1000    # CLFLUSH cost appears


class TestPOCProtocol:
    def test_isa_roundtrip(self):
        insn = Instruction(Opcode.RC_COPY, 123, 456)
        assert Instruction.decode(insn.encode()) == insn

    def test_flag_handshake(self, proto):
        _, mc = proto
        poc = PimOpsController(mc)
        poc.store_instruction(Instruction(Opcode.RC_COPY, 0, 0).encode())
        poc.store_start()
        flags = poc.load_flags()
        assert flags.ack and flags.fin and not flags.start


class TestDRaNGe:
    def test_trng_end_to_end(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        poc = PimOpsController(mc)
        cmap = characterize(mc, rows=list(range(16)), n_bits=1024, samples=80)
        assert cmap.total_cells > 0
        trng = DRangeTRNG(poc, cmap)
        bits = trng.random_bits(1024)
        assert bits.shape == (1024,)
        frac = bits.mean()
        assert 0.30 < frac < 0.70          # metastable cells near 0.5
        from repro.core.drange import runs_count, serial_correlation
        assert abs(serial_correlation(bits)) < 0.2
        r = runs_count(bits)
        assert 0.3 * len(bits) < r < 0.7 * len(bits)

    def test_trng_streams_differ(self):
        dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
        mc = MemoryController(dev)
        poc = PimOpsController(mc)
        cmap = characterize(mc, rows=list(range(16)), n_bits=1024, samples=80)
        trng = DRangeTRNG(poc, cmap)
        a = trng.random_bits(256)
        b = trng.random_bits(256)
        assert (a != b).any()

"""Radix-tree prefix cache: trie semantics (insert/match/evict/LRU,
refcount bridge) as property tests, radix-vs-pairwise sharing parity on
the engine, the 100-request shared-system-prompt dedupe, and LRU
eviction under arena pressure."""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover
    from _compat import given, settings, st

from repro.configs import ARCHS, reduced
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request
from repro.serving.prefix_cache import RadixPrefixCache

PS = 4            # page size for the pure-trie tests


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _mk_tree():
    """A tree over fake pages with an observable refcount ledger."""
    rc = {}

    def retain(p):
        rc[p] = rc.get(p, 0) + 1

    def release(p):
        rc[p] -= 1

    return RadixPrefixCache(PS, retain=retain, release=release), rc


class TestRadixTree:
    def test_insert_then_match_returns_full_page_prefix(self):
        tree, rc = _mk_tree()
        toks = [1, 2, 3, 4, 5, 6, 7, 8, 9]       # 2 full pages + tail
        assert tree.match(toks) == []
        assert tree.insert(toks, [10, 11, 12]) == 2   # tail page ignored
        assert tree.match(toks) == [10, 11]
        assert tree.match([1, 2, 3, 4, 99, 0, 0, 0]) == [10]
        assert tree.match([9, 9, 9, 9]) == []
        assert rc == {10: 1, 11: 1}

    def test_duplicate_insert_keeps_first_committers_pages(self):
        tree, rc = _mk_tree()
        tree.insert([1, 2, 3, 4], [10])
        assert tree.insert([1, 2, 3, 4, 5, 6, 7, 8], [20, 21]) == 1
        # the shared first page stays node 10; page 20 took no tree ref
        assert tree.match([1, 2, 3, 4, 5, 6, 7, 8]) == [10, 21]
        assert rc == {10: 1, 21: 1}

    def test_lru_eviction_leaves_first(self):
        tree, rc = _mk_tree()
        tree.insert([1, 2, 3, 4, 5, 6, 7, 8], [10, 11])   # chain A -> B
        tree.insert([9, 9, 9, 9], [12])                   # C
        tree.match([1, 2, 3, 4, 5, 6, 7, 8])              # touch the chain
        # C is the coldest leaf; then the chain drains deepest-first
        assert tree.evict_lru(1) == 1
        assert sorted(tree.pages_indexed()) == [10, 11]
        assert tree.evict_lru(1) == 1
        assert tree.pages_indexed() == [10]               # leaf 11 first
        assert tree.evict_all() == 1
        assert all(v == 0 for v in rc.values())
        assert tree.n_nodes == 0

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=10**6),
           n_prompts=st.integers(min_value=1, max_value=10))
    def test_match_is_longest_committed_prefix(self, seed, n_prompts):
        """Property: against a brute-force oracle, match() returns
        exactly the longest full-page prefix shared with any committed
        prompt, its pages carry the right token content, and the
        refcount ledger always holds one tree ref per node — all of it
        releasing on evict_all."""
        rng = np.random.default_rng(seed)
        tree, rc = _mk_tree()
        committed = []               # token lists inserted so far
        content = {}                 # page -> the token tuple it holds
        next_page = 0
        for _ in range(n_prompts):
            if committed and rng.random() < 0.6:
                # extend/diverge from a committed prompt: forces shared
                # paths and branch points in the trie
                base = list(committed[int(rng.integers(len(committed)))])
                keep = int(rng.integers(0, len(base) + 1))
                toks = base[:keep] + [int(t) for t in
                                      rng.integers(0, 4,
                                                   int(rng.integers(0, 10)))]
            else:
                toks = [int(t) for t in
                        rng.integers(0, 4, int(rng.integers(1, 14)))]
            if not toks:
                continue
            exp = 0                  # oracle: longest common full-page prefix
            for c in committed:
                m = 0
                while ((m + 1) * PS <= min(len(c), len(toks))
                       and c[m * PS:(m + 1) * PS]
                       == toks[m * PS:(m + 1) * PS]):
                    m += 1
                exp = max(exp, m)
            got = tree.match(toks)
            assert len(got) == exp, (toks, committed)
            for j, page in enumerate(got):
                assert content[page] == tuple(toks[j * PS:(j + 1) * PS])
            # commit, engine-style: matched pages reused, fresh pages
            # for the rest
            n_full = len(toks) // PS
            pages = got + list(range(next_page, next_page + n_full - exp))
            next_page += n_full - exp
            for j in range(exp, n_full):
                content[pages[j]] = tuple(toks[j * PS:(j + 1) * PS])
            tree.insert(toks, pages)
            committed.append(toks)
            # exactly one tree ref per indexed page
            live = tree.pages_indexed()
            assert len(live) == tree.n_nodes
            assert all(rc[p] == 1 for p in live)
        tree.evict_all()
        assert all(v == 0 for v in rc.values())
        assert tree.stats["evictions"] == tree.stats["inserts"]


class TestEnginePrefixCache:
    def test_radix_matches_pairwise_oracle(self, model, rng):
        """Radix-matched sharing vs the pairwise share_with oracle on
        the same workload (two prompts sharing 12 of 16 tokens):
        identical token streams, identical hit accounting, zero leaked
        pages, and arenas both scrubbed to zero at the end."""
        cfg, params = model
        p0 = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
        p1 = p0.copy()
        p1[-4:] = rng.integers(0, cfg.vocab_size, 4)

        pair = PagedEngine(cfg, params, page_size=4, num_pages=64)
        pair.submit(Request(0, p0, max_new_tokens=3, temperature=0.0))
        pair.submit(Request(1, p1, max_new_tokens=3, temperature=0.0,
                            share_with=0, shared_len=12))
        res_pair = pair.run()

        radix = PagedEngine(cfg, params, page_size=4, num_pages=64,
                            prefix_cache=True)
        radix.submit(Request(0, p0, max_new_tokens=3, temperature=0.0))
        res_radix = radix.run()                 # commits p0's full pages
        radix.submit(Request(1, p1, max_new_tokens=3, temperature=0.0))
        res_radix.update(radix.run())

        assert res_radix == res_pair
        assert radix.stats["prefix_hits"] == 1
        assert radix.stats["prefix_hit_tokens"] == 12   # 3 full pages
        assert radix.cache.queue.saved_by_kind["kv_write"] == 12
        # zero leaked pages: the pairwise engine frees everything with
        # its sequences; the radix engine's survivors are exactly the
        # tree-held prefix pages, released by clear_prefix
        assert pair.cache.pages_in_use == 0
        assert radix.cache.pages_in_use == radix.cache.prefix.n_nodes
        radix.cache.clear_prefix()
        assert radix.cache.pages_in_use == 0
        # init-on-free scrubbed both arenas identically (all zeros)
        for eng in (pair, radix):
            assert not np.asarray(eng.cache.k_arena).any()
            assert not np.asarray(eng.cache.v_arena).any()

    def test_pairwise_api_warns_deprecation_with_prefix_cache(self, model,
                                                              rng):
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                          prefix_cache=True)
        prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        eng.submit(Request(0, prompt, max_new_tokens=2, temperature=0.0))
        eng.submit(Request(1, prompt, max_new_tokens=2, temperature=0.0,
                           share_with=0, shared_len=8))
        with pytest.warns(DeprecationWarning, match="pairwise"):
            res = eng.run()
        assert res[0] == res[1]

    def test_hundred_request_shared_system_prompt_dedupe(self, model, rng):
        """The acceptance trace: 100 sequential requests with one
        shared system prompt dedupe at > 0.9 token hit-rate, every page
        accounted (no leaks), and the replayed trace prices the hits as
        RowClone savings."""
        from repro.serving.trace import replay_on_device
        cfg, params = model
        sys_prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                          prefix_cache=True, record_trace=True)
        total = 0
        for i in range(100):
            eng.submit(Request(i, sys_prompt, max_new_tokens=1,
                               temperature=0.0))
            eng.run()
            total += len(sys_prompt)
        hit_rate = eng.stats["prefix_hit_tokens"] / total
        assert hit_rate > 0.9, hit_rate
        assert eng.stats["prefix_hits"] == 99
        # zero leaked pages: live pages == tree-held pages, then none
        assert eng.cache.pages_in_use == eng.cache.prefix.n_nodes == 2
        eng.cache.clear_prefix()
        assert eng.cache.pages_in_use == 0
        rep = replay_on_device(eng.cache.trace)
        assert rep["counts"]["prefix_hit"] == 99 * 2
        assert rep["speedup"]["prefix"] > 5
        assert rep["pim_ns"]["total"] < rep["cpu_ns"]["total"]

    def test_lru_eviction_under_arena_pressure(self, model, rng):
        """With the arena sized to the working set, cold committed
        prefixes evict (LRU) instead of the allocator raising — and the
        evicted pages zero through init-on-free before reuse."""
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=16,
                          prefix_cache=True)
        prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
                   for _ in range(12)]
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=1, temperature=0.0))
            eng.run()                # each commits 2 pages into the tree
        # 12 distinct 2-page prompts through a 16-page arena: the tree
        # must have shed cold entries to keep allocating
        assert eng.stats["prefix_evictions"] > 0
        assert eng.cache.pages_in_use <= 16
        assert eng.cache.pages_in_use == eng.cache.prefix.n_nodes
        eng.cache.clear_prefix()
        assert eng.cache.pages_in_use == 0
        assert not np.asarray(eng.cache.k_arena).any()

    def test_chunked_prefill_commits_and_hits(self, model, rng):
        """Prefix flow under the chunked scheduler: a long prompt
        committed chunk-by-chunk indexes on its LAST chunk, and a
        later duplicate attaches every full page (the covered-sharer
        no-write chunk path)."""
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                          max_prefill_chunk=8, prefix_cache=True)
        eng.submit(Request(0, prompt, max_new_tokens=2, temperature=0.0))
        res = eng.run()
        assert eng.stats["prefix_hits"] == 0
        eng.submit(Request(1, prompt, max_new_tokens=2, temperature=0.0))
        res.update(eng.run())
        assert res[0] == res[1]
        assert eng.stats["prefix_hits"] == 1
        assert eng.stats["prefix_hit_tokens"] == 20     # fully covered
        assert eng.stats["decode_stall_rounds"] == 0

"""Tensor-parallel sharded serving.

mesh=1 runs the full shard_map lowering in-process (the program is the
real SPMD program, just with one shard) and must be BIT-identical to the
host-local engine — vocab-parallel embed/logits psum exact zeros, so
only the attn-wo / mlp-down psums reorder float sums, and at world 1
even those are identity.  mesh {2,4} run in subprocesses with
``--xla_force_host_platform_device_count`` and are gated on core count:
XLA host collectives spin-wait, so host meshes deadlock below 4 cores
(same guard as ``test_distributed.py``).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _gen(cfg, params, prompts, mesh=None, new=6, **kw):
    eng = PagedEngine(cfg, params, page_size=4, num_pages=64, mesh=mesh, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=new, temperature=0.0))
    return eng.run(), eng


def _prompts(cfg, lens=(12, 7, 9), seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32) for n in lens]


class TestMeshOneParity:
    def test_tokens_bit_identical_to_host_local(self, model):
        cfg, params = model
        prompts = _prompts(cfg)
        host, host_eng = _gen(cfg, params, prompts)
        sharded, sh_eng = _gen(cfg, params, prompts,
                               mesh=make_local_mesh(model=1))
        assert sharded == host
        # arenas went through identical writes -> identical contents
        np.testing.assert_array_equal(np.asarray(sh_eng.cache.k_arena),
                                      np.asarray(host_eng.cache.k_arena))
        np.testing.assert_array_equal(np.asarray(sh_eng.cache.v_arena),
                                      np.asarray(host_eng.cache.v_arena))

    def test_compressed_collectives_same_tokens(self, model):
        """world=1 psum_compressed is one int8 quantization of the
        logits; with this fixed seed no argmax flips (deterministic —
        the pin cannot flake)."""
        cfg, params = model
        prompts = _prompts(cfg)
        host, _ = _gen(cfg, params, prompts)
        comp, _ = _gen(cfg, params, prompts, mesh=make_local_mesh(model=1),
                       compressed_collectives=True)
        assert comp == host

    def test_compressed_requires_mesh(self, model):
        cfg, params = model
        with pytest.raises(ValueError, match="mesh"):
            PagedEngine(cfg, params, page_size=4, num_pages=16,
                        compressed_collectives=True)

    def test_shard_views_single(self, model):
        cfg, params = model
        _, eng = _gen(cfg, params, _prompts(cfg, lens=(8,)), new=2,
                      mesh=make_local_mesh(model=1))
        views = eng.cache.lib.shard_views(0)
        assert len(views) == 1
        np.testing.assert_array_equal(views[0], np.asarray(eng.cache.k_arena))

    def test_owner_breakdown_mesh1(self, model):
        """At one shard the kv lib's tag is plain ``kv`` and the
        per-owner breakdown reconciles with the global kind counters."""
        cfg, params = model
        _, eng = _gen(cfg, params, _prompts(cfg), mesh=make_local_mesh(model=1))
        q = eng.cache.queue
        snap = q.snapshot(by_owner=True)
        assert "kv" in snap
        for kind, n in snap["kv"].items():
            assert n == q.launches_by_kind[kind], (kind, snap)

    def test_decode_round_is_one_dispatch(self, model):
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                          mesh=make_local_mesh(model=1))
        for i, p in enumerate(_prompts(cfg)):
            eng.submit(Request(i, p, max_new_tokens=8, temperature=0.0))
        while eng.queue:
            eng._prefill(eng.queue.pop(0))
        before = eng.cache.queue.snapshot()
        eng._decode_round()
        assert eng.cache.queue.delta(before) == {"fused_decode": 1}

    def test_fused_prefill_is_one_dispatch(self, model):
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                          mesh=make_local_mesh(model=1))
        for i, p in enumerate(_prompts(cfg, lens=(7, 7))):
            eng.submit(Request(i, p, max_new_tokens=1, temperature=0.0))
        before = eng.cache.queue.snapshot()
        eng._prefill_round()
        assert eng.cache.queue.delta(before) == {"fused_prefill": 1}

    def test_block_decode_under_one_dispatch_per_token(self, model):
        cfg, params = model
        eng = PagedEngine(cfg, params, page_size=4, num_pages=128,
                          decode_block_rounds=8, mesh=make_local_mesh(model=1))
        rng = np.random.default_rng(3)
        for i in range(2):
            prompt = rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
            eng.submit(Request(i, prompt, max_new_tokens=64, temperature=0.0))
        eng.run(max_rounds=9)
        before = eng.cache.queue.snapshot()
        base_tokens = eng.stats["tokens_out"]
        eng.run(max_rounds=32)
        delta = eng.cache.queue.delta(before)
        tokens = eng.stats["tokens_out"] - base_tokens
        assert delta == {"fused_decode_block": 4}, delta
        assert sum(delta.values()) / tokens < 1.0


def _run_sub(prog, timeout=420):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(prog)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout


MULTI_PROG = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={world}"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import ARCHS, reduced
    from repro.launch.mesh import make_local_mesh
    from repro.models import transformer as T
    from repro.models.params import init_params
    from repro.serving.engine import PagedEngine, Request

    world = {world}
    assert jax.device_count() == world
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (12, 7, 9)]

    def gen(mesh=None, **kw):
        eng = PagedEngine(cfg, params, page_size=4, num_pages=64,
                          mesh=mesh, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=6, temperature=0.0))
        return eng.run(), eng

    host, host_eng = gen()
    mesh = make_local_mesh(model=world)
    sharded, eng = gen(mesh=mesh)
    # greedy tokens bit-identical: vocab-parallel embed/logits psums add
    # exact zeros; attn-wo/mlp-down psums only reorder float sums
    assert sharded == host, (sharded, host)

    # per-shard arena slices == host arena KV-head slices
    kvh = cfg.num_kv_heads // world
    for views, ref in ((eng.cache.lib.shard_views(0), host_eng.cache.k_arena),
                       (eng.cache.lib.shard_views(1), host_eng.cache.v_arena)):
        assert len(views) == world
        ref = np.asarray(ref)
        for i, v in enumerate(views):
            np.testing.assert_array_equal(
                v, ref[..., i * kvh:(i + 1) * kvh, :])

    # one dispatch per decode round at mesh {world} + per-shard owners
    eng2 = PagedEngine(cfg, params, page_size=4, num_pages=64, mesh=mesh)
    for i, p in enumerate(prompts):
        eng2.submit(Request(i, p, max_new_tokens=8, temperature=0.0))
    while eng2.queue:
        eng2._prefill(eng2.queue.pop(0))
    base = eng2.cache.queue.snapshot()
    eng2._decode_round()
    assert eng2.cache.queue.delta(base) == {{"fused_decode": 1}}
    owners = eng2.cache.queue.snapshot(by_owner=True)
    want = ({{"kv"}} if world == 1
            else {{"kv/shard%d" % i for i in range(world)}})
    assert want <= set(owners), owners
    for o in want:
        assert owners[o].get("fused_decode", 0) >= 1, owners

    # compressed logit collective: same greedy tokens at int8 tolerance
    comp, _ = gen(mesh=mesh, compressed_collectives=True)
    assert comp == host, (comp, host)

    # non-divisible head counts must raise, not silently replicate
    if world > 1:
        bad = reduced(ARCHS["granite-3-8b"], num_layers=1, num_kv_heads=3,
                      num_heads=3)
        bad_params = init_params(T.model_defs(bad), jax.random.PRNGKey(0))
        try:
            PagedEngine(bad, bad_params, page_size=4, num_pages=16, mesh=mesh)
        except ValueError as e:
            assert "divisible" in str(e) or "num_heads" in str(e)
        else:
            raise AssertionError("non-divisible dims must raise")
    print("OK world=%d" % world)
"""


@pytest.mark.slow
class TestShardedSubprocess:
    """Real multi-shard runs.  Skipped below 4 cores — XLA host
    collectives spin-wait and deadlock there (see test_distributed)."""

    @pytest.mark.parametrize("world", [2, 4])
    def test_sharded_parity_dispatch_owners(self, world):
        cores = os.cpu_count() or 1
        if cores < 4:
            pytest.skip("host-mesh collectives deadlock with <4 cores")
        out = _run_sub(MULTI_PROG.format(world=world))
        assert f"OK world={world}" in out

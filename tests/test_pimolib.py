"""pimolib v2: one PimLib protocol over both faces.

Cross-face parity (the same trace through DeviceLib and TpuLib yields
identical page contents and unified OpReceipts), the opcode-keyed op
registry (capability flags, one-entry extensibility), the hazard-aware
deferred path now living in PimOpQueue, caller-supplied libs on the
serving cache, and model-face replay of a recorded serving trace."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Blocking, DRAMGeometry, DeviceLib, MemoryController,
                        Opcode, OpReceipt, PimLib, PimOpQueue,
                        PimOpsController, SimulatedDRAM, TpuLib,
                        allocator_from_subarray_map, discover_subarrays,
                        make_tpu_arena)
from repro.core import op_registry

ROW_BYTES = 64   # small device rows so the parity payload is exact in fp32


def _device_lib() -> DeviceLib:
    dev = SimulatedDRAM(DRAMGeometry(num_subarrays=2, rows_per_subarray=8,
                                     row_bytes=ROW_BYTES))
    mc = MemoryController(dev)
    smap = discover_subarrays(mc, max_rows=16)
    return DeviceLib(PimOpsController(mc), allocator_from_subarray_map(smap))


def _jax_lib() -> TpuLib:
    arena = make_tpu_arena(num_slabs=2, pages_per_slab=8,
                           page_elems=ROW_BYTES, dtype=jnp.float32)
    return TpuLib(arena)


def _drive(lib: PimLib, payload: np.ndarray):
    """The shared trace: alloc, write, copy, re-init the source, read.
    Pure PimLib protocol — no face-specific calls."""
    src, dst = lib.allocator.alloc_copy_pair(2)
    receipts = [
        lib.write(src, payload),
        lib.copy(src, dst, blocking=Blocking.FIN),
        lib.init(src, 0.0, blocking=Blocking.FIN),
    ]
    receipts.append(lib.flush(Blocking.FIN))
    dst_vals = np.asarray(lib.read(dst), np.float32)
    src_vals = np.asarray(lib.read(src), np.float32)
    return dst_vals, src_vals, receipts


def test_serving_pim_queue_shim_removed():
    """The PR 3 relocation's deprecation cycle is over: the
    ``repro.serving.pim_queue`` re-export shim is gone for good — this
    pin keeps it from silently coming back."""
    with pytest.raises(ModuleNotFoundError):
        import repro.serving.pim_queue  # noqa: F401


class TestCrossFaceParity:
    def test_same_trace_same_contents(self):
        payload = np.random.default_rng(3).integers(
            0, 256, (2, ROW_BYTES)).astype(np.uint8)
        d_dst, d_src, d_recs = _drive(_device_lib(), payload)
        j_dst, j_src, j_recs = _drive(_jax_lib(),
                                      payload.astype(np.float32))
        np.testing.assert_array_equal(d_dst.astype(np.float32), j_dst)
        np.testing.assert_array_equal(d_src, np.zeros_like(d_src))
        np.testing.assert_array_equal(j_src, np.zeros_like(j_src))

    def test_receipts_unified_across_faces(self):
        payload = np.ones((2, ROW_BYTES), np.uint8)
        _, _, d_recs = _drive(_device_lib(), payload)
        _, _, j_recs = _drive(_jax_lib(), payload.astype(np.float32))
        for d, j in zip(d_recs, j_recs):
            assert isinstance(d, OpReceipt) and isinstance(j, OpReceipt)
            assert d.ok and j.ok
            assert d.face == "device" and j.face == "jax"
            assert d.n_ops == j.n_ops
        # op names unify where the registry defines the op on both faces
        assert d_recs[1].op == j_recs[1].op == "rowclone_copy"
        assert d_recs[2].op == j_recs[2].op == "rowclone_init"
        # each face fills its own accounting column
        assert d_recs[1].latency_ns > 0 and d_recs[1].launches == 0
        assert j_recs[1].launches >= 1 and j_recs[1].latency_ns == 0.0
        # model-face RowClone beats the CPU baseline end to end
        dev = _device_lib()
        src, dst = dev.allocator.alloc_copy_pair(2)
        assert (dev.cpu_copy(src, dst).latency_ns
                > 10 * dev.copy(src, dst).latency_ns)

    def test_blocking_fin_synchronizes_both_faces(self):
        for lib in (_device_lib(), _jax_lib()):
            src, dst = lib.allocator.alloc_copy_pair(1)
            rec = lib.copy(src, dst, blocking=Blocking.FIN)
            assert rec.ok and not rec.deferred


class TestOpRegistry:
    def test_capability_flags(self):
        dev, tpu = _device_lib(), _jax_lib()
        assert dev.supports(Opcode.RC_COPY) and tpu.supports(Opcode.RC_COPY)
        assert dev.supports(Opcode.RC_INIT) and tpu.supports(Opcode.RC_INIT)
        # KV scatter has no DDR3 command sequence: model face says no
        assert not dev.supports(Opcode.KV_WRITE)
        assert tpu.supports(Opcode.KV_WRITE)
        # D-RaNGe: direct-dispatch kernel on the JAX face; the model
        # face needs a characterized TRNG attached first
        assert tpu.supports(Opcode.DR_GEN)
        assert not dev.supports(Opcode.DR_GEN)

    def test_queue_kinds_come_from_registry(self):
        q = PimOpQueue()
        kinds = [s.jax_kind for s in op_registry.ops_for_face(op_registry.FACE_JAX)
                 if s.jax_kind is not None]   # jax_direct ops have no kind
        assert kinds, "registry should contribute queue kinds"
        for kind in kinds:
            assert q.has_kind(kind)

    def test_register_new_op_reaches_new_queues(self):
        opcode = Opcode.NOP   # reuse a spare opcode for the test entry
        assert op_registry.get_op(opcode) is None

        def _flush_touch(q, arenas, ops):
            q._count_launch("touch", len(arenas))
            return arenas

        spec = op_registry.PimOpSpec(opcode=opcode, name="touch",
                                     jax_kind="touch",
                                     jax_flush=_flush_touch)
        op_registry.register_pim_op(spec)
        try:
            with pytest.raises(ValueError):
                op_registry.register_pim_op(spec)   # no silent override
            q = PimOpQueue()
            assert q.has_kind("touch")
            q.enqueue("touch", ("x",))
            (out,) = q.flush(jnp.zeros((1, 2, 2)))
            assert q.launches_by_kind["touch"] == 1
            # jax-face libs see the new op through the capability flag
            assert _jax_lib().supports(opcode)
            assert not _device_lib().supports(opcode)
        finally:
            assert op_registry.unregister_pim_op(opcode) is spec
        assert op_registry.get_op(opcode) is None

    def test_device_unsupported_op_raises(self):
        dev = _device_lib()
        with pytest.raises(NotImplementedError):
            dev.rand(8)    # no TRNG attached
        src, dst = dev.allocator.alloc_copy_pair(1)
        with pytest.raises(ValueError):
            dev.init(dst, 0.5)    # non-byte fill cannot match the JAX face
        with pytest.raises(ValueError):
            dev.write(src, np.full((1, ROW_BYTES), 300.0))  # no truncation
        with pytest.raises(TypeError):
            dev.init(dst, Blocking.FIN)   # v1 positional signature

    def test_nonzero_byte_fill_matches_across_faces(self):
        dev, tpu = _device_lib(), _jax_lib()
        for lib in (dev, tpu):
            dst = lib.allocator.alloc(2)
            rec = lib.init(dst, 7.0, blocking=Blocking.FIN)
            assert rec.ok
            np.testing.assert_array_equal(
                np.asarray(lib.read(dst), np.float32),
                np.full((2, ROW_BYTES), 7.0, np.float32))

    def test_multi_buffer_read_write_roundtrip(self):
        from repro.core import SubarrayAllocator
        from repro.core.allocator import arena_groups
        k = jnp.zeros((2, 8, 4), jnp.float32)   # (layers, pages, elems)
        v = jnp.zeros((2, 8, 4), jnp.float32)
        lib = TpuLib(buffers=[k, v], layered=True,
                     allocator=SubarrayAllocator(arena_groups(1, 8)))
        alloc = lib.allocator.alloc(2)
        vals = jnp.arange(2 * 2 * 4, dtype=jnp.float32).reshape(2, 2, 4)
        lib.write(alloc, vals, buffer=1)
        np.testing.assert_array_equal(np.asarray(lib.read(alloc, buffer=1)),
                                      np.asarray(vals))
        assert float(jnp.abs(lib.read(alloc, buffer=0)).sum()) == 0.0

    def test_poc_rejects_unregistered_opcode(self):
        from repro.core import Instruction
        dev = _device_lib()
        dev.poc.store_instruction(Instruction(Opcode.KV_WRITE, 0, 0).encode())
        with pytest.raises(ValueError):
            dev.poc.store_start()


class TestHazardAwareQueue:
    """The deferred-coalescing hazard logic now lives in PimOpQueue
    (dispatch-count regression for the admit() path)."""

    @staticmethod
    def _lib():
        return TpuLib(make_tpu_arena(1, 16, 8, dtype=jnp.float32),
                      deferred=True)

    def test_disjoint_same_kind_ops_coalesce(self):
        lib = self._lib()
        pairs = [lib.allocator.alloc_copy_pair(1) for _ in range(4)]
        for src, dst in pairs:
            lib.copy(src, dst)
        lib.flush()
        assert lib.queue.launches_by_kind["page_copy"] == 1
        assert lib.queue.stats["hazard_flushes"] == 0

    def test_shared_source_fanout_copies_still_coalesce(self):
        # reading the same source row twice is no hazard: batched copies
        # read the pre-flush arena state
        lib = self._lib()
        a = lib.allocator.alloc(1)
        b = lib.allocator.alloc(1, same_group_as=a)
        c = lib.allocator.alloc(1, same_group_as=a)
        lib.write(a, jnp.full((1, 8), 9.0))
        lib.copy(a, b)
        lib.copy(a, c)
        lib.flush(Blocking.FIN)
        assert lib.queue.stats["hazard_flushes"] == 0
        assert lib.queue.launches_by_kind["page_copy"] == 1
        assert float(np.asarray(lib.read(c))[0, 0]) == 9.0

    def test_row_reuse_flushes_backlog_and_chains(self):
        lib = self._lib()
        a = lib.allocator.alloc(1)
        b = lib.allocator.alloc(1, same_group_as=a)
        c = lib.allocator.alloc(1, same_group_as=a)
        lib.write(a, jnp.full((1, 8), 5.0))
        lib.copy(a, b)
        lib.copy(b, c)            # reads b -> hazard -> backlog flushes
        lib.flush(Blocking.FIN)
        assert float(np.asarray(lib.read(c))[0, 0]) == 5.0
        assert lib.queue.stats["hazard_flushes"] == 1
        assert lib.queue.launches_by_kind["page_copy"] == 2

    def test_flush_overlapped_dispatches_backlog_early(self):
        # the engine's pre-prefill overlap hook: a pending backlog is
        # dispatched immediately (device work runs behind upcoming host
        # work); an empty queue is a cheap no-op
        lib = self._lib()
        src, dst = lib.allocator.alloc_copy_pair(2)
        lib.write(src, jnp.full((2, 8), 4.0))
        lib.copy(src, dst)
        assert lib.queue.pending_ops > 0
        assert lib.queue.flush_overlapped(lib.flush)
        assert lib.queue.pending_ops == 0
        assert lib.queue.stats["overlap_flushes"] == 1
        assert not lib.queue.flush_overlapped(lib.flush)   # nothing pending
        assert lib.queue.stats["overlap_flushes"] == 1
        np.testing.assert_array_equal(np.asarray(lib.read(dst)),
                                      np.full((2, 8), 4.0, np.float32))

    def test_default_seed_rand_advances_per_call(self):
        lib = self._lib()
        a, _ = lib.rand(128)
        b, _ = lib.rand(128)
        assert (a != b).any()          # fresh bits per call, like the POC
        c1, _ = lib.rand(128, seed=jnp.asarray([1, 2], jnp.uint32))
        c2, _ = lib.rand(128, seed=jnp.asarray([1, 2], jnp.uint32))
        np.testing.assert_array_equal(c1, c2)   # explicit seed reproduces

    def test_kind_mix_flushes_backlog(self):
        lib = self._lib()
        src, dst = lib.allocator.alloc_copy_pair(1)
        other = lib.allocator.alloc(1)
        lib.copy(src, dst)
        lib.init(other)           # different kind -> hazard flush
        lib.flush()
        assert lib.queue.stats["hazard_flushes"] == 1
        assert lib.queue.launches_by_kind["page_copy"] == 1
        assert lib.queue.launches_by_kind["page_init"] == 1


class TestServingIntegration:
    @pytest.fixture(scope="class")
    def model(self):
        from repro.configs import ARCHS, reduced
        from repro.models import transformer as T
        from repro.models.params import init_params
        cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
        params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
        return cfg, params

    def test_cache_runs_against_caller_supplied_lib(self, model):
        from repro.serving.kv_cache import PagedKVCache
        cfg, _ = model
        lib = TpuLib(deferred=True)
        cache = PagedKVCache(cfg, num_pages=32, page_size=4, lib=lib)
        assert cache.lib is lib and cache.queue is lib.queue
        cache.create(0, 10)
        base = lib.queue.stats["launches"]
        cache.fork(0, 1)
        assert lib.queue.stats["launches"] - base == 2   # 1/arena (k, v)
        cache.free(0)
        cache.free(1)
        assert float(jnp.abs(cache.k_arena).sum()) == 0.0

    def test_cache_rejects_model_face_lib(self, model):
        from repro.serving.kv_cache import PagedKVCache
        cfg, _ = model
        with pytest.raises(ValueError):
            PagedKVCache(cfg, num_pages=32, page_size=4, lib=_device_lib())

    def test_external_deferred_backlog_flushes_before_cache_copy(self, model):
        """A shared deferred lib's pending init on a page must land
        before the cache RowClone-copies that page (KIND_ORDER would
        otherwise replay the copy first)."""
        from repro.serving.kv_cache import PagedKVCache
        cfg, _ = model
        lib = TpuLib(deferred=True)
        cache = PagedKVCache(cfg, num_pages=32, page_size=4, lib=lib)
        seq = cache.create(0, 6)       # pages[1] is a partial tail
        k = jnp.ones((cache.n_layers, 6, cfg.num_kv_heads,
                      cfg.resolved_head_dim))
        cache.write_prompt_kv(seq, k, k)
        tail = seq.pages[-1]
        # an external client defers a zeroing init of the tail page
        lib.init(cache.page_alloc[tail])
        assert lib.queue.pending_ops == 1
        # forking CoW-copies the partial tail: the init must land first
        forked = cache.fork(0, 1)
        assert lib.queue.stats["hazard_flushes"] >= 1
        page = np.asarray(cache.k_arena[:, forked.pages[-1]], np.float32)
        assert float(np.abs(page).sum()) == 0.0   # copied the zeroed page

    def test_lib_refuses_second_arena_owner(self, model):
        # rebinding a bound lib would flush the first cache's page ids
        # against the second cache's arenas — refuse instead
        from repro.serving.kv_cache import PagedKVCache
        cfg, _ = model
        lib = TpuLib(deferred=True)
        PagedKVCache(cfg, num_pages=32, page_size=4, lib=lib)
        with pytest.raises(RuntimeError):
            PagedKVCache(cfg, num_pages=16, page_size=4, lib=lib)

    def test_queue_refuses_second_lib(self):
        # pending ops carry no owner, so two libs flushing one queue
        # would land each other's ops on the wrong arenas — refuse
        lib1 = TpuLib(deferred=True)
        with pytest.raises(ValueError):
            TpuLib(deferred=True, queue=lib1.queue)

    def test_engine_with_caller_supplied_lib_matches_default(self, model, rng):
        from repro.serving.engine import PagedEngine, Request
        cfg, params = model
        prompt = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
        outs = []
        libs = [None, TpuLib(deferred=True)]
        for lib in libs:
            eng = PagedEngine(cfg, params, page_size=4, num_pages=64, lib=lib)
            eng.submit(Request(0, prompt, max_new_tokens=3, temperature=0.0))
            outs.append(tuple(eng.run()[0]))
            assert eng.cache.queue.launches_by_kind["fused_decode"] >= 1
        assert outs[0] == outs[1]
        # the supplied lib shares the engine's dispatch accounting
        assert libs[1].queue.stats["launches"] > 0


class TestTraceReplay:
    @pytest.fixture(scope="class")
    def model(self):
        from repro.configs import ARCHS, reduced
        cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
        return cfg

    def test_cache_trace_records_coalesced_batches(self, model):
        from repro.serving.kv_cache import PagedKVCache
        cache = PagedKVCache(model, num_pages=32, page_size=4,
                             record_trace=True)
        seq = cache.create(0, 10)       # 2 full pages + partial tail
        k = jnp.ones((cache.n_layers, 10, model.num_kv_heads,
                      model.resolved_head_dim))
        cache.write_prompt_kv(seq, k, k)
        cache.fork(0, 1)                # 1 CoW copy
        cache.free(0)
        cache.free(1)
        counts = cache.trace.counts()
        assert counts["page_copy"] == 1
        assert counts["kv_write"] == 10
        assert counts["page_init"] == 4          # 3 + the CoW'd tail
        # one event per kind per flush: the free()s batch their inits
        kinds = [e.kind for e in cache.trace.events]
        assert kinds.count("page_copy") == 1

    def test_replay_on_device_yields_rowclone_vs_cpu_totals(self, model):
        from repro.serving.kv_cache import PagedKVCache
        from repro.serving.trace import replay_on_device
        cache = PagedKVCache(model, num_pages=16, page_size=4, num_slabs=2,
                             record_trace=True)
        seq = cache.create(0, 10)
        k = jnp.ones((cache.n_layers, 10, model.num_kv_heads,
                      model.resolved_head_dim))
        cache.write_prompt_kv(seq, k, k)
        cache.fork(0, 1)
        cache.free(0)
        cache.free(1)
        rep = replay_on_device(cache.trace)
        assert rep["events"] == len(cache.trace.events)
        assert all(r.ok for r in rep["receipts"])
        assert any(r.op == "rowclone_copy" for r in rep["receipts"])
        # paper-style accounting: RowClone beats the all-CPU baseline
        assert rep["pim_ns"]["rowclone_init"] > 0
        assert rep["speedup"]["init"] > 5
        assert rep["speedup"]["copy"] is None or rep["speedup"]["copy"] > 5
        assert rep["cpu_ns"]["total"] > rep["pim_ns"]["total"]

    @pytest.mark.slow
    def test_engine_trace_end_to_end(self, model, rng):
        from repro.models import transformer as T
        from repro.models.params import init_params
        from repro.serving.engine import PagedEngine, Request
        from repro.serving.trace import replay_on_device
        params = init_params(T.model_defs(model), jax.random.PRNGKey(0))
        eng = PagedEngine(model, params, page_size=4, num_pages=32,
                          record_trace=True)
        prompt = rng.integers(0, model.vocab_size, 9).astype(np.int32)
        eng.submit(Request(0, prompt, max_new_tokens=4, temperature=0.0))
        eng.run()
        counts = eng.cache.trace.counts()
        assert counts["kv_write"] > 0 and counts["page_init"] > 0
        rep = replay_on_device(eng.cache.trace)
        assert rep["speedup"]["init"] > 1
        assert rep["pim_ns"]["total"] < rep["cpu_ns"]["total"]

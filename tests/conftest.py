import os
import sys

# tests must see exactly 1 device (dry-run sets 512 in its own process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# so `from _compat import ...` (optional-hypothesis shim) resolves even
# when pytest is invoked from outside the repo root
sys.path.insert(0, os.path.dirname(__file__))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)

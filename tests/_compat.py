"""Optional-``hypothesis`` shim for the test suite.

When hypothesis is installed the test modules use it directly; when it
is not (tier-1 runs from a clean checkout), this module supplies a thin
fallback that turns ``@given(...)`` property sweeps into deterministic
fixed-example ``pytest.mark.parametrize`` sets.  Strategies are tiny
samplers over a seeded ``numpy`` generator — less adversarial than real
hypothesis shrinking, but the oracles still get exercised across a
spread of shapes/dtypes/values, and the suite collects and passes with
no extra dependencies.

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:                      # pragma: no cover
        from _compat import given, settings, st
"""

from __future__ import annotations

import zlib

import numpy as np
import pytest

#: fixed examples generated per @given when hypothesis is absent
N_EXAMPLES = 6


class _Strategy:
    """A draw function rng -> value, mirroring the hypothesis strategies
    the suite actually uses."""

    def __init__(self, draw):
        self.draw = draw


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


def lists(elem, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elem.draw(rng) for _ in range(n)]
    return _Strategy(draw)


def tuples(*elems):
    return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))


class _St:
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)
    tuples = staticmethod(tuples)


st = _St()


def settings(**_kw):
    """No-op stand-in: example count is fixed at N_EXAMPLES; deadline
    and max_examples are hypothesis concepts with no equivalent here."""
    def deco(fn):
        return fn
    return deco


def given(**kwargs):
    """Expand keyword strategies into N_EXAMPLES deterministic cases.

    The seed derives from the test name, so examples are stable across
    runs and machines (crc32, not ``hash``, which is salted per process).
    """
    names = list(kwargs)

    def deco(fn):
        rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
        cases = [tuple(kwargs[n].draw(rng) for n in names)
                 for _ in range(N_EXAMPLES)]
        if len(names) == 1:
            # single-parameter parametrize takes bare values, not 1-tuples
            cases = [c[0] for c in cases]
        ids = [f"ex{i}" for i in range(N_EXAMPLES)]
        return pytest.mark.parametrize(",".join(names), cases, ids=ids)(fn)
    return deco

"""Async serving front door: stream-vs-batch bit-identity (greedy and
sampled), the open-loop Poisson smoke, starvation/fairness under
mid-stream arrivals (extends the PR 5 ``decode_stall_rounds`` harness),
SLO admission shedding, and the chunk auto-tuner.

No pytest-asyncio: each test wraps its coroutine in ``asyncio.run``
with a hard ``wait_for`` bound so a wedged server loop fails fast
instead of hanging CI.
"""

import asyncio

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request
from repro.serving.server import AsyncServer, ChunkAutoTuner

TIMEOUT_S = 300        # generous: first test in the process pays compiles


def _run(coro):
    return asyncio.run(asyncio.wait_for(coro, timeout=TIMEOUT_S))


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def _engine(model, **kw):
    cfg, params = model
    kw.setdefault("page_size", 4)
    kw.setdefault("num_pages", 64)
    kw.setdefault("max_prefill_chunk", 8)
    return PagedEngine(cfg, params, **kw)


class TestStreamParity:
    def test_streams_bit_identical_to_batch_run(self, model, rng):
        """The determinism contract: for the same request set, the
        server's round-at-a-time loop streams exactly the tokens a
        closed-loop ``engine.run()`` produces — greedy AND sampled.
        Greedy is schedule-independent; sampled parity needs the
        engine's per-round dispatch schedule replayed exactly, so the
        backlog cap (which would defer one admission by a round) is
        lifted for this comparison."""
        cfg, _ = model
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (6, 18, 11, 6)]
        temps = [0.0, 0.0, 0.8, 0.8]

        ref = _engine(model, prefix_cache=True)
        for i, (p, t) in enumerate(zip(prompts, temps)):
            ref.submit(Request(i, p, max_new_tokens=6, temperature=t))
        expected = ref.run()

        async def go():
            srv = AsyncServer(_engine(model, prefix_cache=True),
                              admit_backlog_chunks=float("inf"))
            async with srv:
                streams = []
                for i, (p, t) in enumerate(zip(prompts, temps)):
                    streams.append(await srv.submit(
                        p, max_new_tokens=6, temperature=t, req_id=i))
                return [await s.drain() for s in streams], srv.stats

        outs, stats = _run(go())
        assert outs == [expected[i] for i in range(len(prompts))]
        assert stats["completed"] == len(prompts)
        assert stats["rejected"] == 0

    def test_poisson_open_loop_matches_batch_engine(self, model, rng):
        """Short Poisson trace (the CI smoke): whatever rounds the
        arrivals landed in, greedy streams are bit-identical to the
        batch engine on the same prompts, and every stream's timing
        marks are complete."""
        from repro.launch.serve_async import poisson_open_loop
        cfg, _ = model
        prompts = [rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
                   for _ in range(6)]

        async def go():
            srv = AsyncServer(_engine(model))
            async with srv:
                return await poisson_open_loop(srv, prompts, rate_rps=200.0,
                                               max_new_tokens=4)

        res = _run(go())
        assert res["completed"] == len(prompts) and res["rejected"] == 0

        ref = _engine(model)
        for i, p in enumerate(prompts):
            ref.submit(Request(i, p, max_new_tokens=4, temperature=0.0))
        expected = ref.run()
        for s in res["streams"]:
            assert s.tokens == expected[s.req_id]
            assert s.ttft_ms is not None and s.e2e_ms is not None
            assert len(s.token_ms) == len(s.tokens)
            assert all(g >= 0 for g in s.itl_ms())

    def test_stream_yields_incrementally(self, model, rng):
        """``async for`` observes tokens one round at a time — the
        stream ends exactly at the request budget."""
        cfg, _ = model

        async def go():
            srv = AsyncServer(_engine(model))
            async with srv:
                s = await srv.submit(
                    rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=5)
                seen = []
                async for tok in s:
                    seen.append(tok)
                    assert seen == s.tokens[:len(seen)]
                return seen, s

        seen, s = _run(go())
        assert seen == s.tokens and len(seen) == 5


class TestFairness:
    def test_open_loop_long_prefill_never_stalls_decode(self, model, rng):
        """PR 5's starvation harness, open-loop: a decoding request is
        mid-stream when a 4-chunk prompt arrives.  The chunked
        scheduler slices the newcomer's prefill across rounds, so the
        incumbent keeps emitting every round — ``decode_stall_rounds``
        stays 0 engine-side and ``max_round_gap`` stays 0 server-side.
        """
        cfg, _ = model
        eng = _engine(model, max_prefill_chunk=8)

        async def go():
            srv = AsyncServer(eng)
            async with srv:
                short = await srv.submit(
                    rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=12)
                got = 0
                async for _ in short:          # wait until it is decoding
                    got += 1
                    if got >= 2:
                        break
                long = await srv.submit(
                    rng.integers(0, cfg.vocab_size, 32).astype(np.int32),
                    max_new_tokens=4)
                return await short.drain(), await long.drain(), srv.stats

        short_toks, long_toks, stats = _run(go())
        assert len(short_toks) == 12 and len(long_toks) == 4
        assert eng.stats["decode_stall_rounds"] == 0
        assert stats["max_round_gap"] == 0


class TestAdmission:
    def test_infeasible_deadlines_shed_at_admission(self, model, rng):
        """Once a round-time EWMA exists, a request whose first-token
        or completion deadline cannot be met is rejected with an empty
        stream instead of burning chunk budget."""
        cfg, _ = model
        prompt = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)

        async def go():
            srv = AsyncServer(_engine(model))
            async with srv:
                warm = await srv.submit(prompt, max_new_tokens=2)
                await warm.drain()             # establishes round_ms_ewma
                assert srv.round_ms_ewma is not None
                tight = await srv.submit(prompt, max_new_tokens=2,
                                         ttft_slo_ms=0.0)
                slow = await srv.submit(prompt, max_new_tokens=512,
                                        deadline_ms=1e-3)
                ok = await srv.submit(prompt, max_new_tokens=2)
                return (await tight.drain(), tight, await slow.drain(),
                        slow, await ok.drain(), srv.stats)

        t_toks, tight, s_toks, slow, ok_toks, stats = _run(go())
        assert tight.rejected and tight.reject_reason == "ttft_slo"
        assert slow.rejected and slow.reject_reason == "deadline"
        assert t_toks == [] and s_toks == []
        assert len(ok_toks) == 2               # feasible traffic unaffected
        assert stats["rejected"] == 2 and stats["completed"] == 2


class TestChunkAutoTuner:
    def test_requires_chunked_engine(self, model):
        eng = _engine(model, max_prefill_chunk=None)
        with pytest.raises(ValueError):
            ChunkAutoTuner(eng, target_p99_ms=10.0)
        with pytest.raises(ValueError):
            eng.set_prefill_chunk(16)

    def test_halves_over_target_doubles_under_with_backlog(self, model):
        eng = _engine(model, max_prefill_chunk=64)
        tuner = ChunkAutoTuner(eng, target_p99_ms=10.0, window=4,
                               min_chunk=8, max_chunk=128)
        for _ in range(4):                    # p99 over target -> halve
            tuner.observe(100.0, decoded=True, backlog_tokens=0)
        assert eng.max_prefill_chunk == 32
        for _ in range(4):
            tuner.observe(100.0, decoded=True, backlog_tokens=0)
        assert eng.max_prefill_chunk == 16
        # fast rounds but NO backlog: spare headroom is not spent
        for _ in range(4):
            tuner.observe(1.0, decoded=True, backlog_tokens=0)
        assert eng.max_prefill_chunk == 16
        # fast rounds with prefill backlogged -> double back up
        for _ in range(4):
            tuner.observe(1.0, decoded=True, backlog_tokens=1000)
        assert eng.max_prefill_chunk == 32
        # floor: over-target moves never go below min_chunk
        for _ in range(12):
            tuner.observe(100.0, decoded=True, backlog_tokens=0)
        assert eng.max_prefill_chunk == 8
        # prefill-only rounds are not decode-latency samples
        before = len(tuner.history)
        for _ in range(8):
            tuner.observe(100.0, decoded=False, backlog_tokens=0)
        assert len(tuner.history) == before
        assert all(h["p99_ms"] > 0 for h in tuner.history)

"""Per-architecture smoke tests + model-math validation.

Every assigned arch: reduced config, one forward + one train step on CPU,
shape and finiteness asserts.  Plus: prefill/decode == full forward,
flash-vjp == naive autodiff, SSD == naive recurrence, MoE dispatch ==
dense oracle, fused LM head == naive xent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCHS, OptimizerConfig, ParallelConfig, reduced)
from repro.models import transformer as T
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.params import init_params, param_count
from repro.training.train_step import make_train_step

PCFG = ParallelConfig(remat="none", attention_impl="naive", moe_impl="dense")
PCFG_CHUNK = ParallelConfig(remat="full", attention_impl="chunked",
                            attention_chunk=16, moe_impl="dense")


def make_batch(r, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, r.vocab_size)}
    labels = jnp.roll(batch["tokens"], -1, axis=1)
    if r.family == "vlm":
        fd = r.frontend_dim or r.d_model
        batch["patch_embeds"] = jnp.ones((B, r.num_patch_tokens, fd), jnp.float32)
        batch["tokens"] = batch["tokens"][:, : S - r.num_patch_tokens]
        labels = jnp.concatenate(
            [jnp.full((B, r.num_patch_tokens), -100, jnp.int32),
             labels[:, : S - r.num_patch_tokens]], axis=1)
    if r.family == "encdec":
        fd = r.frontend_dim or r.d_model
        batch["frames"] = jnp.ones((B, S // 2, fd), jnp.float32)
        batch["tokens"] = batch["tokens"][:, : S // 2]
        labels = labels[:, : S // 2]
    batch["labels"] = labels
    return batch


# the two giant hybrid/MoE archs take 15-60s per case even reduced;
# keep them out of the default tier-1 run (CI runs them under -m slow)
_HEAVY_ARCHS = {"jamba-1.5-large-398b", "deepseek-v2-236b"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS
            else a for a in archs]


@pytest.mark.parametrize("arch", _arch_params(sorted(ARCHS)))
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch, key):
        r = reduced(ARCHS[arch])
        params = init_params(T.model_defs(r), key)
        batch = make_batch(r, key)
        logits, aux = T.forward(r, PCFG, params, batch, mode="train")
        assert logits.shape[0] == 2 and logits.shape[-1] == r.vocab_size
        assert bool(jnp.isfinite(logits).all())
        assert bool(jnp.isfinite(aux))

    def test_one_train_step(self, arch, key):
        r = reduced(ARCHS[arch])
        params = init_params(T.model_defs(r), key)
        init_state, step = make_train_step(
            r, PCFG_CHUNK, OptimizerConfig(warmup_steps=1, total_steps=4))
        state = init_state(params)
        batch = make_batch(r, key)
        state, metrics = jax.jit(step)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        # params actually changed
        before = jax.tree.leaves(params)[0]
        after = jax.tree.leaves(state["params"])[0]
        assert not np.allclose(np.asarray(before), np.asarray(after))

    def test_param_count_close_to_analytic(self, arch):
        cfg = ARCHS[arch]
        defs = T.model_defs(cfg)
        actual = param_count(defs)
        analytic = cfg.param_count()
        assert abs(actual - analytic) / analytic < 0.05, (actual, analytic)


@pytest.mark.parametrize("arch", _arch_params(
    ["granite-3-8b", "gemma-2b", "stablelm-3b", "minitron-8b", "mamba2-1.3b",
     "jamba-1.5-large-398b", "llama4-scout-17b-a16e", "seamless-m4t-medium"]))
def test_prefill_decode_matches_full_forward(arch, key):
    r = reduced(ARCHS[arch])
    params = init_params(T.model_defs(r), key)
    B, S, MAX = 2, 16, 32
    toks = jax.random.randint(key, (B, S + 2), 0, r.vocab_size)
    extra = {}
    if r.family == "encdec":
        fd = r.frontend_dim or r.d_model
        extra["frames"] = jax.random.normal(key, (B, 8, fd))
    ref, _ = T.forward(r, PCFG, params, {"tokens": toks, **extra}, mode="train")
    cache = T.init_cache(r, B, MAX, enc_len=8 if r.family == "encdec" else 0)
    lg, cache, _ = T.forward(r, PCFG, params, {"tokens": toks[:, :S], **extra},
                             mode="prefill", cache=cache,
                             lengths=jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(ref[:, S - 1], np.float32),
                               rtol=3e-2, atol=3e-2)
    for t in range(2):
        pos = S + t
        lg, cache = T.forward(r, PCFG, params,
                              {"tokens": toks[:, pos:pos + 1]}, mode="decode",
                              cache=cache, write_pos=jnp.asarray(pos),
                              lengths=jnp.full((B,), pos + 1, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(ref[:, pos], np.float32),
                                   rtol=3e-2, atol=3e-2)


def test_mla_decode_absorbed_matches(key):
    # looser tolerance: absorbed decode reorders bf16 matmuls
    r = reduced(ARCHS["deepseek-v2-236b"])
    params = init_params(T.model_defs(r), key)
    B, S, MAX = 2, 16, 32
    toks = jax.random.randint(key, (B, S + 2), 0, r.vocab_size)
    ref, _ = T.forward(r, PCFG, params, {"tokens": toks}, mode="train")
    cache = T.init_cache(r, B, MAX)
    lg, cache, _ = T.forward(r, PCFG, params, {"tokens": toks[:, :S]},
                             mode="prefill", cache=cache,
                             lengths=jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(ref[:, S - 1], np.float32),
                               rtol=3e-2, atol=3e-2)
    lg, _ = T.forward(r, PCFG, params, {"tokens": toks[:, S:S + 1]},
                      mode="decode", cache=cache, write_pos=jnp.asarray(S),
                      lengths=jnp.full((B,), S + 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(ref[:, S], np.float32),
                               rtol=8e-2, atol=8e-2)


class TestAttentionMath:
    def test_flash_fwd_bwd_vs_naive(self, rng):
        q = jnp.asarray(rng.normal(size=(2, 50, 4, 16)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(2, 50, 2, 16)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(2, 50, 2, 16)).astype(np.float32))
        lens = jnp.asarray([30, 50], jnp.int32)

        def lc(q, k, v):
            return jnp.sum(A.chunked_attention(
                q, k, v, causal=True, chunk_q=16, chunk_k=16, lengths=lens) ** 2)

        def ln(q, k, v):
            return jnp.sum(A.naive_attention(q, k, v, causal=True,
                                             lengths=lens) ** 2)

        np.testing.assert_allclose(lc(q, k, v), ln(q, k, v), rtol=1e-5)
        gc = jax.grad(lc, (0, 1, 2))(q, k, v)
        gn = jax.grad(ln, (0, 1, 2))(q, k, v)
        for a, b in zip(gc, gn):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_decode_attention_vs_naive(self, rng):
        q = jnp.asarray(rng.normal(size=(2, 1, 4, 16)).astype(np.float32))
        kc = jnp.asarray(rng.normal(size=(2, 24, 2, 16)).astype(np.float32))
        vc = jnp.asarray(rng.normal(size=(2, 24, 2, 16)).astype(np.float32))
        lens = jnp.asarray([10, 24], jnp.int32)
        out = A.decode_attention(q, kc, vc, lens)
        expect = A.naive_attention(q, kc, vc, causal=False, lengths=lens)
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


class TestSSD:
    def test_chunked_equals_naive_recurrence(self, rng):
        b, s, h, p, n, Q = 2, 37, 3, 4, 8, 8
        x = jnp.asarray(rng.normal(size=(b, s, h, p)).astype(np.float32))
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)).astype(np.float32))
        Amat = -jnp.asarray(rng.uniform(0.5, 2.0, h).astype(np.float32))
        B = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
        C = jnp.asarray(rng.normal(size=(b, s, n)).astype(np.float32))
        y, final = S._ssd_chunked(x, dt, Amat, B, C, Q)

        # naive per-step recurrence
        state = np.zeros((b, h, p, n), np.float32)
        ys = np.zeros((b, s, h, p), np.float32)
        for t in range(s):
            dA = np.exp(np.asarray(dt[:, t]) * np.asarray(Amat)[None])
            dBx = np.einsum("bn,bh,bhp->bhpn", np.asarray(B[:, t]),
                            np.asarray(dt[:, t]), np.asarray(x[:, t]))
            state = state * dA[:, :, None, None] + dBx
            ys[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(C[:, t]), state)
        np.testing.assert_allclose(np.asarray(y, np.float32), ys, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(final), state, rtol=2e-4, atol=2e-4)

    def test_prefill_state_continues_decode(self, key):
        r = reduced(ARCHS["mamba2-1.3b"])
        params = init_params(T.model_defs(r), key)
        toks = jax.random.randint(key, (1, 20), 0, r.vocab_size)
        ref, _ = T.forward(r, PCFG, params, {"tokens": toks}, mode="train")
        cache = T.init_cache(r, 1, 32)
        lg, cache, _ = T.forward(r, PCFG, params, {"tokens": toks[:, :19]},
                                 mode="prefill", cache=cache,
                                 lengths=jnp.asarray([19], jnp.int32))
        lg2, _ = T.forward(r, PCFG, params, {"tokens": toks[:, 19:20]},
                           mode="decode", cache=cache,
                           write_pos=jnp.asarray(19),
                           lengths=jnp.asarray([20], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg2[:, 0], np.float32),
                                   np.asarray(ref[:, 19], np.float32),
                                   rtol=3e-2, atol=3e-2)


class TestMoE:
    def test_shard_map_matches_dense_oracle(self, key):
        """EP dispatch on a 1x1 mesh (E_local == E) vs the dense path.

        With ample capacity and no drops the two must agree closely."""
        from repro.distributed.sharding import sharding_env
        from repro.launch.mesh import make_local_mesh
        r = reduced(ARCHS["llama4-scout-17b-a16e"])
        p = init_params(M.moe_defs(r), key)
        x = jax.random.normal(key, (2, 16, r.d_model), jnp.float32) \
            .astype(jnp.bfloat16)
        import dataclasses
        r_big_cap = dataclasses.replace(
            r, moe=dataclasses.replace(r.moe, capacity_factor=8.0))
        dense_out, dense_aux = M.moe_layer(
            r_big_cap, ParallelConfig(moe_impl="dense"), p, x)
        mesh = make_local_mesh(data=1, model=1)
        with sharding_env(mesh, fsdp=False):
            ep_out, ep_aux = M.moe_layer(
                r_big_cap, ParallelConfig(moe_impl="shard_map"), p, x)
        np.testing.assert_allclose(np.asarray(dense_out, np.float32),
                                   np.asarray(ep_out, np.float32),
                                   rtol=3e-2, atol=3e-2)
        np.testing.assert_allclose(float(dense_aux), float(ep_aux), rtol=1e-3)

    def test_capacity_drops_are_bounded(self, key):
        r = reduced(ARCHS["deepseek-v2-236b"])
        p = init_params(M.moe_defs(r), key)
        x = jax.random.normal(key, (2, 32, r.d_model)).astype(jnp.bfloat16)
        from repro.distributed.sharding import sharding_env
        from repro.launch.mesh import make_local_mesh
        with sharding_env(make_local_mesh(1, 1), fsdp=False):
            out, aux = M.moe_layer(r, ParallelConfig(moe_impl="shard_map"), p, x)
        assert bool(jnp.isfinite(out).all())
        assert float(aux) > 0.5  # load-balance loss in a sane range

"""Fault tolerance: checkpoint/restart with bit-exact resume, failure
injection, straggler detection, elastic mesh restore."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCHS, OptimizerConfig, ParallelConfig, ShapeConfig, reduced
from repro.data.pipeline import PipelineConfig, SyntheticLM
from repro.models import transformer as T
from repro.models.params import init_params
from repro.runtime.fault_tolerance import (FailureInjector, HeartbeatMonitor,
                                           InjectedFailure, Supervisor)
from repro.training.train_step import make_train_step


@pytest.fixture()
def tiny_setup(key, tmp_path):
    r = reduced(ARCHS["stablelm-3b"], num_layers=2, d_model=32, d_ff=64,
                vocab_size=128, num_heads=2, num_kv_heads=2, head_dim=16)
    pcfg = ParallelConfig(remat="none", attention_impl="naive")
    init_state, step = make_train_step(
        r, pcfg, OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=50))
    state = init_state(init_params(T.model_defs(r), key))
    data = SyntheticLM(r, ShapeConfig("t", 32, 4, "train"), PipelineConfig(seed=5))
    jstep = jax.jit(step)

    def step_fn(st, batch):
        return jstep(st, {k: jnp.asarray(v) for k, v in batch.items()})

    return r, state, step_fn, data, str(tmp_path / "ckpt")


class TestCheckpointer:
    def test_roundtrip(self, tiny_setup):
        _, state, _, _, d = tiny_setup
        ck = Checkpointer(d)
        ck.save(7, state, blocking=True)
        restored, step = ck.load(state)
        assert step == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save_and_gc(self, tiny_setup):
        _, state, _, _, d = tiny_setup
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, state)
        ck.wait()
        assert ck.all_steps() == [3, 4]

    def test_elastic_restore_reshards(self, tiny_setup):
        """Save unsharded, restore with a device_put sharding_fn — the
        elastic-rescale path (mesh-shape-agnostic on-disk format)."""
        _, state, _, _, d = tiny_setup
        ck = Checkpointer(d)
        ck.save(1, state, blocking=True)
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((1,), ("data",))
        fn = lambda name, arr: jax.device_put(
            arr, NamedSharding(mesh, P()))
        restored, _ = ck.load(state, sharding_fn=fn)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSupervisor:
    def test_restart_resumes_bit_exact(self, tiny_setup):
        r, state0, step_fn, data, d = tiny_setup
        # run 1: no failures
        sup = Supervisor(Checkpointer(d + "_a"), ckpt_every=5)
        _, rep_clean = sup.run(state0, step_fn, data.batch, 20)
        # run 2: failures at steps 7 and 13
        sup2 = Supervisor(Checkpointer(d + "_b"), ckpt_every=5,
                          injector=FailureInjector(fail_at=[7, 13]))
        _, rep_ft = sup2.run(state0, step_fn, data.batch, 20)
        assert rep_ft.restarts == 2
        assert rep_ft.resumed_from == [5, 10]
        # deterministic data + restart => identical loss curve
        for s in sorted(rep_clean.losses):
            assert abs(rep_clean.losses[s] - rep_ft.losses[s]) < 1e-5, s

    def test_exceeding_max_restarts_raises(self, tiny_setup):
        _, state, step_fn, data, d = tiny_setup
        inj = FailureInjector(fail_at=[3])

        class AlwaysFail(FailureInjector):
            def check(self, step):
                if step == 3:
                    raise InjectedFailure("always")

        sup = Supervisor(Checkpointer(d), ckpt_every=100, max_restarts=2,
                         injector=AlwaysFail())
        with pytest.raises(InjectedFailure):
            sup.run(state, step_fn, data.batch, 10)


class TestHeartbeat:
    def test_straggler_detection(self):
        mon = HeartbeatMonitor(straggler_factor=5.0, window=16)
        for i in range(10):
            mon.last_beat = time.monotonic() - 0.01   # normal 10ms steps
            assert not mon.beat(i)
        mon.last_beat = time.monotonic() - 1.0        # 100x slower
        assert mon.beat(11)
        assert 11 in mon.stragglers

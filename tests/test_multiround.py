"""Round-equivalence harness for the multi-round engine loop.

The multi-round features (mixed chunk+decode rounds, the K-blocked
``lax.while_loop`` decode) change WHEN work is dispatched, never WHAT is
computed: token streams must stay bit-identical to the round-at-a-time
oracles, per-sequence arena contents must match round for round, and a
stopped sequence must neither emit post-stop tokens nor leak pages.
Property-based sweeps run through ``hypothesis`` when installed, else
the ``_compat`` fixed-example fallback."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # pragma: no cover
    from _compat import given, settings, st

from repro.configs import ARCHS, ParallelConfig, reduced
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request

PCFG = ParallelConfig(attention_impl="naive", remat="none")
KS = (1, 3, 8)


@pytest.fixture(scope="module")
def model():
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=1)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def state_models():
    """Tiny state-arena layouts: pure-SSM (mamba2-style) and the
    attention/MoE-interleaved hybrid (jamba-style), SSD chunk size 4 so
    chunked prefill is legal."""
    out = {}
    for fam, arch, kw in (("ssm", "mamba2-1.3b", dict(num_layers=2)),
                          ("hybrid", "jamba-1.5-large-398b",
                           dict(num_layers=4, attn_every=4))):
        cfg = reduced(ARCHS[arch], **kw)
        cfg = dataclasses.replace(
            cfg, ssm=dataclasses.replace(cfg.ssm, chunk_size=4))
        out[fam] = (cfg, init_params(T.model_defs(cfg),
                                     jax.random.PRNGKey(0)))
    return out


def _engine(cfg, params, *, K=1, fused=True, chunk=None):
    return PagedEngine(cfg, params, pcfg=PCFG, page_size=4, num_pages=128,
                       fused=fused, fused_prefill=fused,
                       max_prefill_chunk=chunk,
                       decode_block_rounds=K if fused else 1)


def _submit(eng, cfg, seed, n_reqs, budget, eos_map=None):
    rng = np.random.default_rng(seed)
    for i in range(n_reqs):
        plen = int(rng.integers(2, 12))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        eng.submit(Request(i, prompt, max_new_tokens=budget,
                           temperature=0.0,
                           eos_token_id=(eos_map or {}).get(i)))


def _first_occurrence_eos(stream, pos):
    """Walk ``pos`` down to 0 until the token there has no earlier
    occurrence — every engine stops at an EOS token's FIRST emission,
    so only such positions give a well-defined expected stream."""
    for p in range(pos, -1, -1):
        if stream.index(stream[p]) == p:
            return stream[p], p
    return stream[0], 0


def _seq_kv(eng, rid):
    """Per-sequence committed KV, gathered page by page: page
    *assignment* legitimately differs across K (block reservation
    changes allocator order), page *contents* must not."""
    seq = eng.cache.seqs[rid]
    out = []
    for arena in (eng.cache.k_arena, eng.cache.v_arena):
        g = jnp.asarray(arena[:, np.asarray(seq.pages)], jnp.float32)
        L = g.shape[0]
        out.append(np.asarray(g.reshape(L, -1, *g.shape[3:])[:, :seq.length]))
    return out


class TestRoundEquivalence:
    """Token streams are bit-identical across eager / single-round-fused
    / K-round-fused engines, EOS and budgets included."""

    @settings(max_examples=6, deadline=None)
    @given(n_reqs=st.integers(1, 3), seed=st.integers(0, 10_000),
           budget=st.integers(3, 10), use_eos=st.booleans(),
           chunk=st.sampled_from([None, 4]))
    def test_fuzz_streams_identical(self, model, n_reqs, seed, budget,
                                    use_eos, chunk):
        cfg, params = model
        ref_eng = _engine(cfg, params, K=1)
        _submit(ref_eng, cfg, seed, n_reqs, budget)
        ref = ref_eng.run()
        eos_map, expect = None, ref
        if use_eos:
            rng = np.random.default_rng(seed + 1)
            eos_map, expect = {}, {}
            for i, stream in ref.items():
                pos = int(rng.integers(0, len(stream)))
                eos_map[i], cut = _first_occurrence_eos(stream, pos)
                expect[i] = stream[:cut + 1]
        runs = [("eager", _engine(cfg, params, fused=False))]
        runs += [(f"K{k}", _engine(cfg, params, K=k, chunk=chunk))
                 for k in KS]
        for name, eng in runs:
            _submit(eng, cfg, seed, n_reqs, budget, eos_map=eos_map)
            got = eng.run()
            assert got == expect, (name, got, expect)
            assert eng.cache.pages_in_use == 0, name

    def test_arena_parity_mid_flight(self, model):
        """Stop every engine after the SAME number of rounds mid-stream:
        token counts, sequence lengths, and per-sequence arena KV must
        line up round for round — K-variants bit-identical (the masked
        write-back keeps dead-row scatters structural no-ops), fused vs
        eager at bf16 resolution."""
        cfg, params = model
        states = {}
        for name, eng in [("eager", _engine(cfg, params, fused=False))] + [
                (f"K{k}", _engine(cfg, params, K=k)) for k in KS]:
            _submit(eng, cfg, seed=7, n_reqs=2, budget=32)
            eng.run(max_rounds=7)
            assert sorted(eng.active) == [0, 1], name
            states[name] = (
                {r: list(eng.active[r].out_tokens) for r in eng.active},
                {r: eng.cache.seqs[r].length for r in eng.active},
                {r: _seq_kv(eng, r) for r in eng.active})
        toks1, lens1, kv1 = states["K1"]
        for k in (3, 8):
            toksk, lensk, kvk = states[f"K{k}"]
            assert toksk == toks1 and lensk == lens1
            for r in kv1:
                for a, b in zip(kv1[r], kvk[r]):
                    np.testing.assert_array_equal(a, b)
        tokse, lense, kve = states["eager"]
        assert tokse == toks1 and lense == lens1
        for r in kv1:
            for a, b in zip(kv1[r], kve[r]):
                np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)


class TestHybridRoundEquivalence:
    """The zoo-wide extension of the harness above: SSM and hybrid
    engines run the same gauntlet — token streams bit-identical across
    eager / K-fused / chunked, EOS and budget truncation included, zero
    leaked KV pages AND state-arena slots — and the per-sequence
    state-arena rows themselves line up mid-flight."""

    @settings(max_examples=3, deadline=None)
    @given(family=st.sampled_from(["ssm", "hybrid"]),
           seed=st.integers(0, 10_000), budget=st.integers(3, 8),
           use_eos=st.booleans(), chunk=st.sampled_from([None, 4]))
    def test_fuzz_streams_identical(self, state_models, family, seed,
                                    budget, use_eos, chunk):
        cfg, params = state_models[family]
        ref_eng = _engine(cfg, params, K=1)
        _submit(ref_eng, cfg, seed, 2, budget)
        ref = ref_eng.run()
        eos_map, expect = None, ref
        if use_eos:
            rng = np.random.default_rng(seed + 1)
            eos_map, expect = {}, {}
            for i, stream in ref.items():
                pos = int(rng.integers(0, len(stream)))
                eos_map[i], cut = _first_occurrence_eos(stream, pos)
                expect[i] = stream[:cut + 1]
        runs = [("eager", _engine(cfg, params, fused=False))]
        runs += [(f"K{k}", _engine(cfg, params, K=k, chunk=chunk))
                 for k in KS]
        for name, eng in runs:
            _submit(eng, cfg, seed, 2, budget, eos_map=eos_map)
            got = eng.run()
            assert got == expect, (family, name, got, expect)
            assert eng.cache.pages_in_use == 0, (family, name)
            assert eng.cache.state.rows_in_use == 0, (family, name)
            assert eng.cache.stats["state_pages"] == 0, (family, name)

    def test_state_arena_parity_mid_flight(self, state_models):
        """Same-round stop on the hybrid layout: per-sequence state rows
        bit-identical across K (the masked write-back keeps dead-row
        scatters structural no-ops), eager at arena resolution."""
        cfg, params = state_models["hybrid"]
        states = {}
        for name, eng in [("eager", _engine(cfg, params, fused=False))] + [
                (f"K{k}", _engine(cfg, params, K=k)) for k in KS]:
            _submit(eng, cfg, seed=7, n_reqs=2, budget=32)
            eng.run(max_rounds=7)
            assert sorted(eng.active) == [0, 1], name
            conv, ssm = eng.cache.state.gather([0, 1])
            states[name] = (
                {r: list(eng.active[r].out_tokens) for r in (0, 1)},
                np.asarray(jnp.asarray(conv, jnp.float32)),
                np.asarray(ssm))
        toks1, conv1, ssm1 = states["K1"]
        for k in (3, 8):
            toksk, convk, ssmk = states[f"K{k}"]
            assert toksk == toks1
            np.testing.assert_array_equal(conv1, convk)
            np.testing.assert_array_equal(ssm1, ssmk)
        tokse, conve, ssme = states["eager"]
        assert tokse == toks1
        # eager vs fused: attention's reduction order differs between
        # the scan and the unrolled oracle, and the recurrence carries
        # that bf16-level divergence forward round over round — so the
        # bound is loose, backed by a tight-agreement majority (row
        # aliasing or stale state would blow out both)
        np.testing.assert_allclose(conve, conv1, rtol=0.3, atol=0.3)
        np.testing.assert_allclose(ssme, ssm1, rtol=0.3, atol=0.3)
        for got, ref in ((conve, conv1), (ssme, ssm1)):
            tight = np.abs(got - ref) <= 2e-2 + 2e-2 * np.abs(ref)
            assert tight.mean() > 0.9, tight.mean()


class TestStopDetection:
    """In-loop stop edge cases: no post-stop tokens, no leaked pages."""

    def _streams(self, model, **kw):
        cfg, params = model
        eng = _engine(cfg, params, **{k: v for k, v in kw.items()
                                      if k in ("K", "fused", "chunk")})
        _submit(eng, cfg, kw.get("seed", 0), kw.get("n_reqs", 1),
                kw.get("budget", 12), eos_map=kw.get("eos_map"))
        res = eng.run()
        return res, eng

    def test_eos_on_first_token_of_block(self, model):
        """EOS landing on a K-block's FIRST in-loop round: the loop must
        stop the row there (no K-1 ghost tokens) and the host must not
        replay past it."""
        ref, _ = self._streams(model, K=1, budget=16)
        # round 1 = prefill + single decode; the K-block starts at
        # stream position 2 — force EOS exactly there
        eos, cut = _first_occurrence_eos(ref[0], 2)
        got, eng = self._streams(model, K=8, budget=16, eos_map={0: eos})
        assert got[0] == ref[0][:cut + 1]
        # nothing post-stop: decode emitted exactly the stream minus the
        # prefill's first token
        assert eng.stats["tokens_out"] == len(got[0]) - 1
        assert eng.cache.pages_in_use == 0

    def test_all_rows_stop_same_round(self, model):
        """Every sequence exhausting its budget in the same in-loop
        round: the while_loop exits early, counts stay exact."""
        ref, _ = self._streams(model, K=1, n_reqs=3, budget=6)
        got, eng = self._streams(model, K=8, n_reqs=3, budget=6)
        assert got == ref
        assert all(len(v) == 6 for v in got.values())
        assert eng.stats["multi_round_blocks"] >= 1
        assert eng.cache.pages_in_use == 0

    def test_budget_exhaustion_mid_block(self, model):
        """A token budget that is not a multiple of K dies mid-block;
        the consumed-rounds accounting must match the tokens emitted."""
        ref, _ = self._streams(model, K=1, budget=11)
        got, eng = self._streams(model, K=8, budget=11)
        assert got == ref and len(got[0]) == 11
        assert eng.stats["decode_rounds"] == eng.stats["tokens_out"]
        assert eng.cache.pages_in_use == 0

    def test_admission_between_blocks(self, model):
        """A request arriving between K-blocks: the engine drops back to
        admission rounds, the newcomer prefills, and both streams stay
        identical across K (same mid-run submission schedule)."""
        cfg, params = model
        streams = {}
        for k in KS:
            eng = _engine(cfg, params, K=k)
            _submit(eng, cfg, seed=3, n_reqs=1, budget=24)
            eng.run(max_rounds=9)       # past at least one K-block
            rng = np.random.default_rng(99)
            eng.submit(Request(1, rng.integers(0, cfg.vocab_size, 6)
                               .astype(np.int32), max_new_tokens=8,
                               temperature=0.0))
            res = eng.run()
            assert eng.cache.pages_in_use == 0
            streams[k] = res
        assert streams[1] == streams[3] == streams[8]
        assert len(streams[1][0]) == 24 and len(streams[1][1]) == 8

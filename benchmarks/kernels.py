"""Kernel micro-benchmarks (CPU wall-times are NOT TPU predictions; they
exercise the code paths and report derived bandwidth-style metrics for
relative comparisons: pim copy/init vs naive jnp, TRNG rate, attention
impl variants)."""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def timeit(fn, *args, reps=10, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def main(out=sys.stdout):
    print("name,us_per_call,derived", file=out)

    # pim_copy vs naive gather-copy (arena is donated: thread it through,
    # as the serving engine does)
    from repro.kernels.rowclone import ops as rc
    import time as _time
    src = jnp.arange(8, dtype=jnp.int32)
    dst = jnp.arange(8, 16, dtype=jnp.int32)
    moved = 8 * 16384 * 4

    def timed_threaded(fn, reps=10):
        a = jnp.zeros((64, 16384), jnp.float32)
        a = jax.block_until_ready(fn(a))  # warmup + compile
        t0 = _time.perf_counter()
        for _ in range(reps):
            a = fn(a)
        jax.block_until_ready(a)
        return (_time.perf_counter() - t0) / reps * 1e6

    us = timed_threaded(lambda a: rc.pim_page_copy(a, src, dst))
    print(f"pim_page_copy_jnp,{us:.1f},{moved/us/1e3:.2f}GB/s", file=out)

    arena = jnp.zeros((64, 16384), jnp.float32)
    naive = jax.jit(lambda a: a.at[dst].set(a[src] * 1.0 + 0.0))
    us = timeit(naive, arena)
    print(f"naive_gather_copy,{us:.1f},{moved/us/1e3:.2f}GB/s", file=out)

    us = timed_threaded(lambda a: rc.pim_page_init(a, dst, 0.0))
    print(f"pim_page_init,{us:.1f},{moved/us/1e3:.2f}GB/s", file=out)

    # looped vs batched dispatch: the per-layer Python loop the serving
    # path used to run vs one fused launch over a (layers, pages, elems)
    # arena.  Reports dispatch counts and wall time per logical op-batch.
    L, P, E = 8, 64, 4096
    src_b = jnp.arange(4, dtype=jnp.int32)
    dst_b = jnp.arange(4, 8, dtype=jnp.int32)

    def looped_copy(a):   # L separate launches (the old path)
        for l in range(L):
            a = a.at[l].set(rc.pim_page_copy(a[l], src_b, dst_b))
        return a

    def batched_copy(a):  # 1 launch for all layers
        return rc.pim_page_copy_batched(a, src_b, dst_b)

    def timed_threaded_3d(fn, reps=10):
        a = jnp.zeros((L, P, E), jnp.float32)
        a = jax.block_until_ready(fn(a))
        t0 = _time.perf_counter()
        for _ in range(reps):
            a = fn(a)
        jax.block_until_ready(a)
        return (_time.perf_counter() - t0) / reps * 1e6

    us_loop = timed_threaded_3d(looped_copy)
    us_bat = timed_threaded_3d(batched_copy)
    print(f"page_copy_looped_{L}layers,{us_loop:.1f},{L}_dispatches", file=out)
    print(f"page_copy_batched_{L}layers,{us_bat:.1f},1_dispatch", file=out)
    print(f"page_copy_batch_speedup,{us_loop/us_bat:.2f},x", file=out)

    def looped_init(a):
        for l in range(L):
            a = a.at[l].set(rc.pim_page_init(a[l], dst_b, 0.0))
        return a

    us_loop = timed_threaded_3d(looped_init)
    us_bat = timed_threaded_3d(lambda a: rc.pim_page_init_batched(a, dst_b, 0.0))
    print(f"page_init_looped_{L}layers,{us_loop:.1f},{L}_dispatches", file=out)
    print(f"page_init_batched_{L}layers,{us_bat:.1f},1_dispatch", file=out)
    print(f"page_init_batch_speedup,{us_loop/us_bat:.2f},x", file=out)

    # KV scatter: B token slots across all layers in one launch vs B*L
    # per-slot dynamic-update launches
    B, S = 16, 16
    pages_b = jnp.arange(B, dtype=jnp.int32) % P
    slots_b = jnp.arange(B, dtype=jnp.int32) % S
    new_b = jnp.ones((L, B, E // S), jnp.float32)

    def looped_scatter(a):
        # the old engine path: one EAGER full-arena update per token
        # (B separate dispatches, each materializing the arena)
        for b in range(B):
            a = a.at[:, int(pages_b[b]), int(slots_b[b])].set(new_b[:, b])
        return a

    def timed_threaded_4d(fn, reps=10):
        a = jnp.zeros((L, P, S, E // S), jnp.float32)
        a = jax.block_until_ready(fn(a))
        t0 = _time.perf_counter()
        for _ in range(reps):
            a = fn(a)
        jax.block_until_ready(a)
        return (_time.perf_counter() - t0) / reps * 1e6

    us_loop = timed_threaded_4d(looped_scatter)
    us_bat = timed_threaded_4d(
        lambda a: rc.pim_kv_scatter(a, pages_b, slots_b, new_b))
    print(f"kv_write_looped_{B}tokens,{us_loop:.1f},{B}_updates", file=out)
    print(f"kv_scatter_batched_{B}tokens,{us_bat:.1f},1_dispatch", file=out)
    print(f"kv_scatter_batch_speedup,{us_loop/us_bat:.2f},x", file=out)

    # model-face dispatch accounting: POC handshakes looped vs batched
    from repro.core import (DRAMGeometry, EndToEndCosts, MemoryController,
                            SimulatedDRAM)
    mc = MemoryController(SimulatedDRAM(DRAMGeometry(4, 32)))
    costs = EndToEndCosts(mc)
    for n in (1, 8, 64):
        looped_ns = n * costs.rowclone_copy_ns(False)
        batched_ns = costs.rowclone_copy_batched_ns(n, False)
        print(f"poc_copy_looped_n{n},{looped_ns/1e3:.2f}us,{n}_handshakes",
              file=out)
        print(f"poc_copy_batched_n{n},{batched_ns/1e3:.2f}us,1_handshake",
              file=out)

    # pallas interpret-mode path (correctness-path cost, not TPU perf)
    from repro.kernels.rowclone import rowclone as rck
    x = jnp.ones((256, 1024), jnp.float32)
    us = timeit(lambda v: rck.copy_2d(v, interpret=True), x)
    print(f"pallas_copy_interpret,{us:.1f},", file=out)

    # D-RaNGe generator
    from repro.kernels.drange import ops as dr
    seed = jnp.asarray([1, 2], jnp.uint32)
    us = timeit(lambda s: dr.pim_random_u32(s, 256, 256), seed)
    rate = 256 * 256 * 32 / us  # bits/us
    print(f"pim_random_u32,{us:.1f},{rate:.0f}Mb/s", file=out)

    # attention impls (tiny shapes; relative only)
    from repro.models import attention as A
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 256, 8, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 256, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 256, 2, 64)).astype(np.float32))
    naive_fn = jax.jit(lambda q, k, v: A.naive_attention(q, k, v, causal=True))
    chunk_fn = jax.jit(lambda q, k, v: A.chunked_attention(
        q, k, v, causal=True, chunk_q=128, chunk_k=128))
    us = timeit(naive_fn, q, k, v)
    print(f"attention_naive_256,{us:.1f},", file=out)
    us = timeit(chunk_fn, q, k, v)
    print(f"attention_chunked_256,{us:.1f},", file=out)


if __name__ == "__main__":
    main()

# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import kernels, paper_tables, roofline_report, serving_e2e
    sections = [
        ("paper_tables (RowClone + D-RaNGe reproduction)", paper_tables.main),
        ("kernels", kernels.main),
        ("serving_e2e", serving_e2e.main),
        ("roofline_report (from dry-run artifacts)", roofline_report.main),
    ]
    failed = []
    for name, fn in sections:
        print(f"### {name}")
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print()
    if failed:
        print(f"FAILED sections: {failed}")
        sys.exit(1)
    print("ALL BENCHMARK SECTIONS OK")


if __name__ == '__main__':
    main()

"""End-to-end serving comparison (paper's system-level claim, transposed
to the TPU framework), eight tables:

1. RowClone-backed paged KV management (CoW fork + prefix sharing +
   pim_init page recycling) vs a naive engine that re-prefills shared
   prefixes — the paper's copy/init table at the system level.

2. Fused single-dispatch decode round (jitted scan-over-layers,
   in-kernel self-token merge, in-jit scatter + sampling) vs the
   pre-fusion eager layer loop: decode tokens/s, kernel dispatches per
   round, and jit retrace counts.

3. Fused bucketed prefill (one jitted dispatch per length-bucket batch,
   length-masked flash attention, in-jit KV scatter) vs the eager
   per-request path (un-jitted ``T.forward`` per prompt): prefill
   tokens/s, time-to-first-token for the batch, and prefill jit traces.

4. Chunked prefill under mixed traffic: a long prompt arrives while
   short requests are mid-decode.  Chunked (``max_prefill_chunk``)
   streams the prompt across rounds so decodes keep emitting; the
   monolithic engine makes them wait behind the whole prefill.  Reports
   the long prompt's TTFT and the in-flight decodes' p99 inter-token
   latency for both schedulers.

5. Multi-round decode blocking (``decode_block_rounds``): tokens/s and
   dispatches-per-token for K ∈ {1, 4, 8}.  K=1 is one fused dispatch
   per round; K>1 runs up to K decode rounds inside one jitted
   ``lax.while_loop`` dispatch, so dispatches-per-token drops below 1.
   Dispatch counts come from ``PimOpQueue.snapshot()``/``delta()`` —
   the same source of truth the regression tests pin.

6. Tensor-parallel sharded serving: mesh {1, 2, 4} × logit collective
   {psum, psum_compressed} → decode tokens/s, batch TTFT, dispatches
   per round (still ONE — the shard_map program spans all shards), and
   the per-shard ``launches_by_owner`` breakdown.  mesh=1 runs
   in-process; mesh>1 cells run in a subprocess with
   ``--xla_force_host_platform_device_count`` and are recorded as
   skipped on boxes under 4 cores (XLA host collectives spin-wait and
   deadlock there).

7. Open-system saturation sweep: Poisson arrivals at >= 3 rates through
   the async front door (``repro.serving.server.AsyncServer``) on a
   shared-system-prompt trace.  Per rate: goodput-under-SLO (requests
   admitted, completed, AND inside their deadline, per second), shed
   fraction, TTFT/ITL p99s, the radix prefix cache's token hit-rate,
   and the recorded trace replayed into RowClone-vs-CPU savings
   (``replay_on_device``) — the open-loop numbers table 4's closed-loop
   scenario cannot show.

8. Ambit zero-compare serving account: the multi-tenant shared-prefix
   workload with ``PagedKVCache.enable_zero_scan()`` on — sequence
   frees zero-scan their dying pages (already-zero tails skip their
   init launch), the prefix-cache teardown audits the init-on-free
   invariant in-arena, and the recorded trace replays on the
   cycle-accurate DDR3 twin (tRAS-corrected precharges + periodic
   refresh, zero scans priced as Ambit TRA OR-reduce sequences):
   RowClone+Ambit vs all-CPU end-to-end totals.

9. Paged hybrid serving (jamba-style: mamba + attention + MoE layers in
   one stack): the paper-scale 100k-token-prompt scenario, clipped to
   the CPU host, streams through the chunked scheduler while short
   requests decode in flight.  Reports serving tokens/s, the decode
   round's dispatch count (ONE ``fused_decode`` — the SSM state scatter
   and MoE routing ride the same jit), and the recorded trace replayed
   into state-arena RowClone savings: copy-on-fork rows as batched
   RowClone copies, init-on-free rows as RowClone-Init, the
   slot-granular ``SSM_STATE_WRITE`` stream priced as CPU traffic on
   both accounts (the capability fallback the model face reports).

Metrics print as ``name,us_per_call,derived`` CSV and the fusion numbers
are also written to ``BENCH_serving.json`` so CI tracks them per PR.
Pass ``--smoke`` for the CI-sized configuration.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request

# anchored to the repo root so the tracked snapshot updates no matter
# which directory the benchmark runs from; smoke runs write a separate
# file so the CI-sized numbers never overwrite the full-config snapshot
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_serving.json")
BENCH_JSON_SMOKE = os.path.join(_ROOT, "BENCH_serving.smoke.json")


def _decode_throughput(cfg, params, rng, *, fused: bool, n_reqs: int,
                       prompt_len: int, new_tokens: int, page_size: int):
    """Decode tokens/s + dispatches/round for one engine mode.

    Warmup batch first (pays jit traces), then a timed batch on the same
    engine: a dispatch-count probe over two mid-flight rounds, then the
    remaining rounds under the clock (decode only — prefills excluded).
    """
    eng = PagedEngine(cfg, params, page_size=page_size, num_pages=256,
                      fused=fused)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_reqs)]
    for i, p in enumerate(prompts):                       # warmup batch
        eng.submit(Request(i, p, max_new_tokens=new_tokens, temperature=0.0))
    eng.run()
    for i, p in enumerate(prompts):                       # timed batch
        eng.submit(Request(n_reqs + i, p, max_new_tokens=new_tokens,
                           temperature=0.0))
    while eng.queue:
        eng._prefill(eng.queue.pop(0))
    probe_rounds = 2
    base_launch = eng.cache.queue.stats["launches"]
    launches_by_kind = []        # per-round API-level dispatch accounting
    for _ in range(probe_rounds):
        before = dict(eng.cache.queue.launches_by_kind)
        eng._decode_round()
        after = eng.cache.queue.launches_by_kind
        launches_by_kind.append(
            {k: after[k] - before.get(k, 0) for k in after
             if after[k] - before.get(k, 0)})
    dispatches = (eng.cache.queue.stats["launches"] - base_launch) / probe_rounds
    base_tok = eng.stats["tokens_out"]
    t0 = time.perf_counter()
    eng.run()                                             # decode to done
    dt = time.perf_counter() - t0
    decoded = eng.stats["tokens_out"] - base_tok
    return {
        "tok_s": decoded / dt if dt > 0 else float("inf"),
        "decoded_tokens": decoded,
        "dispatches_per_round": dispatches,
        "launches_by_kind_per_round": launches_by_kind,
        "jit_traces": eng.stats["jit_traces"],
    }


def _prefill_throughput(cfg, params, rng, *, fused_prefill: bool,
                        n_reqs: int, lengths, page_size: int):
    """Prefill tokens/s + time-to-first-token for one prefill mode.

    Warmup batch first (the fused path pays one jit trace per distinct
    length bucket), then a timed batch on the same engine: the clock
    covers exactly the prefill round — when it returns, every request
    in the batch has its first token, so the elapsed time IS the
    batch's time-to-first-token.
    """
    eng = PagedEngine(cfg, params, page_size=page_size, num_pages=256,
                      fused_prefill=fused_prefill)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lengths for _ in range(n_reqs)]
    for i, p in enumerate(prompts):                       # warmup batch
        eng.submit(Request(i, p, max_new_tokens=1, temperature=0.0))
    eng.run()
    for i, p in enumerate(prompts):                       # timed batch
        eng.submit(Request(len(prompts) + i, p, max_new_tokens=1,
                           temperature=0.0))
    before = dict(eng.cache.queue.launches_by_kind)
    t0 = time.perf_counter()
    eng._prefill_round()
    ttft = time.perf_counter() - t0
    after = eng.cache.queue.launches_by_kind
    launches = {k: after[k] - before.get(k, 0) for k in after
                if after[k] - before.get(k, 0)}
    toks = sum(len(p) for p in prompts)
    eng.run()                                             # drain
    return {
        "tok_s": toks / ttft if ttft > 0 else float("inf"),
        "ttft_ms": ttft * 1e3,
        "prefill_tokens": toks,
        "launches_by_kind": launches,
        "prefill_jit_traces": eng.stats["prefill_jit_traces"],
    }


def _block_decode_sweep(cfg, params, rng, *, ks, n_reqs, prompt_len,
                        new_tokens, page_size):
    """Table-5 scenario: pure-decode throughput and dispatch cost vs the
    decode block size K.  One engine per K; warmup batch pays the jit
    traces (including the while_loop block step), then a timed batch is
    prefilled outside the clock and decoded to completion under it.
    Dispatches are measured as a queue-level snapshot/delta over the
    timed decode window, so the dispatches-per-token figure counts every
    launch kind — not just the block steps."""
    out = {}
    for k in ks:
        eng = PagedEngine(cfg, params, page_size=page_size, num_pages=256,
                          fused=True, decode_block_rounds=k)
        prompts = [rng.integers(0, cfg.vocab_size, prompt_len)
                   .astype(np.int32) for _ in range(n_reqs)]
        # warmup batch admitted exactly like the timed batch (prefill
        # drained before any decode) so the K-blocks hit the same
        # block-table-width buckets — otherwise the timed window pays a
        # bucket-boundary retrace the warmup never saw
        for rep in range(2):
            for i, p in enumerate(prompts):
                eng.submit(Request(rep * n_reqs + i,
                                   p, max_new_tokens=new_tokens,
                                   temperature=0.0))
            while eng.queue:             # prefill outside the clock
                eng._prefill(eng.queue.pop(0))
            if rep == 0:                                  # warmup batch
                eng.run()
        before = eng.cache.queue.snapshot()
        base_tok = eng.stats["tokens_out"]
        t0 = time.perf_counter()
        eng.run()                                         # decode to done
        dt = time.perf_counter() - t0
        decoded = eng.stats["tokens_out"] - base_tok
        launches = eng.cache.queue.delta(before)
        total = sum(launches.values())
        out[f"K{k}"] = {
            "tok_s": round(decoded / dt if dt > 0 else float("inf"), 2),
            "decoded_tokens": decoded,
            "dispatches_per_token": round(total / max(decoded, 1), 4),
            "launches_by_kind": launches,
            "multi_round_blocks": eng.stats["multi_round_blocks"],
            "block_jit_traces": eng.stats["block_jit_traces"],
        }
    return out


def _mixed_long_prompt(cfg, params, rng, *, chunk, n_decode, decode_new,
                       long_len, page_size):
    """Table-4 scenario: ``n_decode`` short requests decode in flight
    when a ``long_len``-token prompt arrives.  ``chunk=None`` runs the
    monolithic scheduler (the whole prompt prefills in one round);
    otherwise the chunked scheduler streams it ``chunk`` tokens per
    round, decode interleaved.

    Runs the scenario four times on one engine: rep 0 is warmup (pays
    the jit traces), reps 1-3 are measured round by round — the long
    prompt's TTFT (submit -> first token) and the decodes' per-round
    inter-token gaps while any request is still running.  Per rep the
    p99 over those gaps is the starvation number chunking bounds; the
    reported figure is the BEST rep (the systematic prefill-round stall
    shows in every rep, host-load noise spikes do not).
    """
    eng = PagedEngine(cfg, params, page_size=page_size, num_pages=256,
                      max_prefill_chunk=chunk)
    shorts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
              for _ in range(n_decode)]
    long_prompt = rng.integers(0, cfg.vocab_size, long_len).astype(np.int32)
    reps = 4
    ttfts: list = []
    p99s: list = []
    means: list = []
    n_gaps = chunks_per_rep = 0
    for rep in range(reps):              # rep 0 = warmup (pays traces)
        base = rep * (n_decode + 1)
        base_chunks = eng.stats["prefill_chunks"]
        for i, p in enumerate(shorts):
            eng.submit(Request(base + i, p, max_new_tokens=decode_new,
                               temperature=0.0))
        eng.run(max_rounds=2)            # prefill shorts, start decoding
        lid = base + n_decode
        eng.submit(Request(lid, long_prompt, max_new_tokens=1,
                           temperature=0.0))
        t_submit = prev = time.perf_counter()
        counted = {base + i: len(eng.active[base + i].out_tokens)
                   for i in range(n_decode) if base + i in eng.active}
        ttft_ms = None
        gaps: list = []
        while eng.queue or eng.active or eng._chunk_q:
            done = eng.run(max_rounds=1)
            now = time.perf_counter()
            emitted = False
            for rid in counted:
                n = (len(eng.active[rid].out_tokens) if rid in eng.active
                     else len(done.get(rid, [])) or counted[rid])
                emitted |= n > counted[rid]
                counted[rid] = max(counted[rid], n)
            if emitted:
                gaps.append((now - prev) * 1e3)
            if ttft_ms is None and (lid in done or lid in eng.active):
                ttft_ms = (now - t_submit) * 1e3
            prev = now
        if rep:                          # warmup rep is discarded
            ttfts.append(ttft_ms)
            p99s.append(float(np.percentile(gaps, 99)))
            means.append(float(np.mean(gaps)))
            n_gaps = len(gaps)
            chunks_per_rep = eng.stats["prefill_chunks"] - base_chunks
    # decode_stall_rounds deliberately not reported: the engine counter
    # needs a chunk budget to define "over budget", which the monolithic
    # arm (chunk=None) doesn't have — the eager-oracle contrast is
    # regression-tested in tests/test_prefill.py instead, and the
    # starvation story here is told by the p99 gap
    return {
        "ttft_long_ms": round(min(ttfts), 3),
        "decode_itl_p99_ms": round(min(p99s), 3),
        "decode_itl_mean_ms": round(min(means), 3),
        "itl_samples_per_rep": n_gaps,
        "measured_reps": reps - 1,
        "prefill_chunks_per_rep": chunks_per_rep,
    }


def _open_loop_table(cfg, params, *, smoke: bool) -> dict:
    """Table-7 sweep: one open-loop Poisson trace per arrival rate.

    Each rate gets a fresh chunked engine with the radix prefix cache
    and trace recording on, warmed outside the measured trace (one
    throwaway request pays the jit compiles).  The trace itself is the
    multi-tenant workload from :func:`shared_prefix_prompts` — same
    system prompt, per-request tails — driven by
    :func:`poisson_open_loop` under the server's TTFT-SLO admission.
    Afterwards the engine's recorded arena schedule replays on the
    DDR3 twin, pricing every prefix hit as batched RowClone vs the CPU
    re-prefill it avoided."""
    import asyncio

    from repro.launch.serve_async import (poisson_open_loop,
                                          shared_prefix_prompts)
    from repro.serving.server import AsyncServer
    from repro.serving.trace import replay_on_device

    rates = (4.0, 16.0, 64.0)
    n_reqs = 8 if smoke else 24
    prefix_len, tail_len = (16, 4) if smoke else (32, 8)
    max_new = 4 if smoke else 12
    chunk = 16 if smoke else 32
    ttft_slo_ms = 4000.0 if smoke else 2000.0
    deadline_ms = 8000.0 if smoke else 5000.0

    async def run_rate(rate: float) -> dict:
        eng = PagedEngine(cfg, params, page_size=4, num_pages=256,
                          max_prefill_chunk=chunk, prefix_cache=True,
                          record_trace=True)
        eng.submit(Request(10**6,
                           np.arange(prefix_len + tail_len,
                                     dtype=np.int32) % cfg.vocab_size,
                           max_new_tokens=2, temperature=0.0))
        eng.run()                             # warmup: pays the compiles
        prompts = shared_prefix_prompts(n_reqs, cfg.vocab_size,
                                        prefix_len=prefix_len,
                                        tail_len=tail_len)
        srv = AsyncServer(eng, ttft_slo_ms=ttft_slo_ms)
        async with srv:
            res = await poisson_open_loop(srv, prompts, rate,
                                          max_new_tokens=max_new,
                                          deadline_ms=deadline_ms)
        res.pop("streams")
        admitted = srv.stats["admitted"]
        prompt_toks = max(admitted, 1) * (prefix_len + tail_len)
        res["prefix_hit_rate"] = round(
            eng.stats["prefix_hit_tokens"] / prompt_toks, 4)
        res["prefix"] = {k: eng.stats[k] for k in
                         ("prefix_hits", "prefix_hit_tokens",
                          "prefix_evictions")}
        rep = replay_on_device(eng.cache.trace)
        res["replay_speedup"] = rep["speedup"]
        res["prefix_rowclone_ns"] = {
            "cpu_memcpy": rep["cpu_ns"]["prefix_hit_memcpy"],
            "pim_rowclone": rep["pim_ns"]["prefix_hit_rowclone"],
        }
        for k in ("goodput_rps", "goodput_tok_s", "wall_s"):
            res[k] = round(res[k], 3)
        return res

    return {"config": {"requests": n_reqs, "prefix_len": prefix_len,
                       "tail_len": tail_len, "max_new": max_new,
                       "chunk": chunk, "ttft_slo_ms": ttft_slo_ms,
                       "deadline_ms": deadline_ms},
            "rates": {f"rate{r:g}": asyncio.run(run_rate(r))
                      for r in rates}}


def _ambit_table(cfg, params, *, smoke: bool) -> dict:
    """Table-8 scenario: the multi-tenant shared-prefix workload with
    the Ambit zero-compare paths ON (``PagedKVCache.enable_zero_scan``).

    Every sequence free zero-scans its dying pages (already-zero block
    tails skip their init launch), and the prefix-cache teardown audits
    that every freed page really zeroed — the init-on-free security
    invariant verified in-arena.  The recorded trace then replays on the
    cycle-accurate DDR3 twin: RowClone copies/inits price as violated-
    timing AAP sequences, zero scans as Ambit TRA OR-reduces, and the
    timed face now charges tRAS-corrected precharges plus periodic
    refresh — the end-to-end PiM-vs-CPU totals for a real serving
    schedule."""
    from repro.launch.serve_async import shared_prefix_prompts
    from repro.serving.trace import replay_on_device

    n_reqs = 6 if smoke else 16
    prefix_len, tail_len = (16, 4) if smoke else (32, 8)
    max_new = 4 if smoke else 12
    eng = PagedEngine(cfg, params, page_size=4, num_pages=256,
                      max_prefill_chunk=(16 if smoke else 32),
                      prefix_cache=True, record_trace=True)
    eng.cache.enable_zero_scan()
    # warmup outside the recorded workload would pollute the trace; the
    # compile cost lands in wall time only, and this table reports the
    # replayed device-time account, not host throughput
    prompts = shared_prefix_prompts(n_reqs, cfg.vocab_size,
                                    prefix_len=prefix_len,
                                    tail_len=tail_len)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=max_new, temperature=0.0))
    eng.run()
    evicted = eng.cache.clear_prefix()      # teardown + zero-leak audit
    rep = replay_on_device(eng.cache.trace)
    return {
        "config": {"requests": n_reqs, "prefix_len": prefix_len,
                   "tail_len": tail_len, "max_new": max_new},
        "zero_scan": {k: eng.cache.stats[k] for k in
                      ("init_skips_zero", "zero_audit_pages",
                       "zero_audit_failures")},
        "scan_launches": eng.cache.queue.launches_by_kind.get(
            "page_zero_scan", 0),
        "prefix_nodes_evicted": evicted,
        "trace_counts": rep["counts"],
        "device_stats": rep["device_stats"],
        "pim_ns": rep["pim_ns"],
        "cpu_ns": rep["cpu_ns"],
        "speedup": rep["speedup"],
    }


def _hybrid_long_prompt(rng, *, smoke: bool) -> dict:
    """Table-9 scenario: a jamba-style hybrid stack (mamba + attention +
    MoE sublayers, one paged state arena next to the KV pair) serves the
    paper-scale long-prompt workload — ``long_len`` clipped from the
    100k-token scenario to what the CPU host's naive-attention oracle
    can sweep — chunked through the mixed scheduler while short requests
    decode in flight.

    Three numbers: serving tokens/s over the long prompt's lifetime, a
    two-round pure-decode dispatch probe (the hybrid round must stay ONE
    ``fused_decode``), and the recorded arena schedule replayed on the
    DDR3 twin — a mid-flight fork/free probe puts copy-on-fork and
    init-on-free state rows on the trace so the replay prices them as
    RowClone traffic against the CPU row memcpy/calloc baseline."""
    from repro.serving.trace import replay_on_device

    cfg = reduced(ARCHS["jamba-1.5-large-398b"], num_layers=4,
                  attn_every=4)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(2))
    chunk = 32 if smoke else 256          # multiples of ssm.chunk_size
    long_len = 64 if smoke else 4096
    n_decode = 2 if smoke else 3
    decode_new = 8 if smoke else 24
    num_pages = 64 if smoke else 768
    eng = PagedEngine(cfg, params, page_size=8, num_pages=num_pages,
                      max_prefill_chunk=chunk, record_trace=True)
    # warmup request pays the fused decode/prefill/chunk/mixed traces
    eng.submit(Request(10**6, rng.integers(0, cfg.vocab_size, 16)
                       .astype(np.int32), max_new_tokens=4,
                       temperature=0.0))
    eng.run()
    for i in range(n_decode):             # short requests mid-decode
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 16)
                           .astype(np.int32), max_new_tokens=decode_new,
                           temperature=0.0))
    eng.run(max_rounds=2)
    # dispatch probe: two pure-decode hybrid rounds
    before = eng.cache.queue.snapshot()
    eng.run(max_rounds=2)
    probe = eng.cache.queue.delta(before)
    # beam-fork probe: copy-on-fork + init-on-free state rows land on
    # the trace (the replay prices them as RowClone vs CPU row memcpy)
    live = sorted(eng.active)[0]
    eng.cache.fork(live, 10**6 + 1)
    eng.cache.free(10**6 + 1)
    # the long hybrid prompt arrives; timed to completion
    lid = 10**6 + 2
    eng.submit(Request(lid, rng.integers(0, cfg.vocab_size, long_len)
                       .astype(np.int32), max_new_tokens=decode_new,
                       temperature=0.0))
    base_tok = eng.stats["tokens_out"]
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    toks = eng.stats["tokens_out"] - base_tok
    rep = replay_on_device(eng.cache.trace)
    return {
        "config": {"long_len": long_len, "chunk": chunk,
                   "n_decode": n_decode, "decode_new": decode_new},
        "tok_s": round((toks + long_len) / dt if dt > 0
                       else float("inf"), 2),
        "decode_tokens": toks,
        "prefill_chunks": eng.stats["prefill_chunks"],
        "mixed_dispatches": eng.stats["mixed_dispatches"],
        "dispatches_per_round": sum(probe.values()) / 2,
        "probe_launches_by_kind": probe,
        "state_stats": {"state_forks": eng.stats["state_forks"],
                        "prefix_declined_ssm":
                            eng.stats["prefix_declined_ssm"]},
        "state_replay_ns": {
            k: rep["pim_ns"][k] for k in
            ("state_rowclone_copy", "state_rowclone_init",
             "state_write_cpu")},
        "state_replay_cpu_ns": {
            k: rep["cpu_ns"][k] for k in
            ("state_memcpy", "state_calloc", "state_write_cpu")},
        "replay_speedup": {k: rep["speedup"][k] for k in
                           ("state_copy", "state_init", "end_to_end")},
    }


def _mesh_row_local(world: int, compressed: bool, smoke: bool) -> dict:
    """Measure one (mesh, collective) cell IN THIS PROCESS — requires
    ``jax.device_count() >= world``.  Same shape as table 2: warmup
    batch pays the traces, then a timed batch gives batch TTFT (the
    prefill round), a two-round dispatch probe, and decode tokens/s.
    The per-shard attribution comes straight from
    ``PimOpQueue.snapshot(by_owner=True)`` over the timed window."""
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    mesh = make_local_mesh(model=world)
    n_reqs, new_tokens = (2, 8) if smoke else (4, 16)
    eng = PagedEngine(cfg, params, page_size=4, num_pages=256, mesh=mesh,
                      compressed_collectives=compressed)
    prompts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
               for _ in range(n_reqs)]
    # two warmup batches: the first pays the jit traces, the second pays
    # the one-time XLA relowering for the post-round arena shardings
    # (sharded arrays returned by the fused step key the executable
    # cache differently from the freshly device_put arenas — no Python
    # retrace, but one extra compile on the first post-warmup round)
    for rep in range(2):
        for i, p in enumerate(prompts):
            eng.submit(Request(rep * n_reqs + i, p,
                               max_new_tokens=new_tokens, temperature=0.0))
        eng._prefill_round()
        eng.run()
    for i, p in enumerate(prompts):                       # timed batch
        eng.submit(Request(2 * n_reqs + i, p, max_new_tokens=new_tokens,
                           temperature=0.0))
    owner_base = eng.cache.queue.snapshot(by_owner=True)
    t0 = time.perf_counter()
    eng._prefill_round()
    ttft = time.perf_counter() - t0
    probe_rounds = 2
    base_launch = eng.cache.queue.stats["launches"]
    for _ in range(probe_rounds):
        eng._decode_round()
    dispatches = (eng.cache.queue.stats["launches"]
                  - base_launch) / probe_rounds
    base_tok = eng.stats["tokens_out"]
    t0 = time.perf_counter()
    eng.run()                                             # decode to done
    dt = time.perf_counter() - t0
    decoded = eng.stats["tokens_out"] - base_tok
    return {
        "mesh": world,
        "collective": "psum_compressed" if compressed else "psum",
        "decode_tok_s": round(decoded / dt if dt > 0 else float("inf"), 2),
        "ttft_ms": round(ttft * 1e3, 3),
        "dispatches_per_round": dispatches,
        "launches_by_owner": eng.cache.queue.delta(owner_base,
                                                   by_owner=True),
    }


def _mesh_table(smoke: bool) -> dict:
    """Table 6 sweep.  mesh=1 in-process; mesh>1 needs N host devices,
    which only exist under ``--xla_force_host_platform_device_count``
    set before jax imports — so those cells run in a subprocess that
    imports this module and calls :func:`_mesh_row_local`."""
    rows: dict = {}
    src = os.path.join(_ROOT, "src")
    for world in (1, 2, 4):
        for compressed in (False, True):
            key = f"mesh{world}_" + ("psum_compressed" if compressed
                                     else "psum")
            if world == 1:
                rows[key] = _mesh_row_local(1, compressed, smoke)
            elif (os.cpu_count() or 1) < 4:
                rows[key] = {"skipped":
                             "host-mesh collectives need >=4 cores"}
            else:
                prog = textwrap.dedent(f"""
                    import os, sys, json
                    os.environ["XLA_FLAGS"] = (
                        "--xla_force_host_platform_device_count={world}")
                    sys.path.insert(0, {os.path.dirname(os.path.abspath(__file__))!r})
                    import serving_e2e
                    row = serving_e2e._mesh_row_local(
                        {world}, {compressed}, {smoke})
                    print("ROW=" + json.dumps(row))
                """)
                env = dict(os.environ, PYTHONPATH=src, JAX_PLATFORMS="cpu")
                res = subprocess.run([sys.executable, "-c", prog], env=env,
                                     capture_output=True, text=True,
                                     timeout=900)
                if res.returncode != 0:
                    rows[key] = {"error": (res.stderr or res.stdout)[-500:]}
                    continue
                line = [ln for ln in res.stdout.splitlines()
                        if ln.startswith("ROW=")][-1]
                rows[key] = json.loads(line[len("ROW="):])
    return rows


def main(out=sys.stdout, smoke: bool = False):
    print("name,us_per_call,derived", file=out)
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    N, NEW, PS = (2, 6, 8) if smoke else (6, 4, 8)

    # ---- table 1: shared-prefix workload WITH pim page ops ------------- #
    t0 = time.perf_counter()
    eng = PagedEngine(cfg, params, page_size=PS, num_pages=128)
    for i in range(N):
        eng.submit(Request(i, prompt, max_new_tokens=NEW, temperature=0.0,
                           share_with=0 if i else None,
                           shared_len=(len(prompt) // PS) * PS if i else 0))
    res = eng.run()
    us_pim = (time.perf_counter() - t0) * 1e6
    kv_bytes_per_tok = (cfg.num_layers * 2 * cfg.num_kv_heads
                        * cfg.resolved_head_dim * 2)
    shared_toks = (len(prompt) // PS) * PS * (N - 1)
    saved = shared_toks * kv_bytes_per_tok
    print(f"serve_pim_prefix_sharing,{us_pim:.0f},"
          f"prefill_kv_bytes_saved={saved}", file=out)
    print(f"serve_pim_stats,0,prefix_hits={eng.cache.stats['prefix_hits']}"
          f";cow={eng.cache.stats['cow_copies']}"
          f";zeroed={eng.cache.stats['pages_zeroed']}", file=out)

    # naive: every request prefills its full prompt (no sharing)
    t0 = time.perf_counter()
    eng2 = PagedEngine(cfg, params, page_size=PS, num_pages=128)
    for i in range(N):
        eng2.submit(Request(i, prompt, max_new_tokens=NEW, temperature=0.0))
    res2 = eng2.run()
    us_naive = (time.perf_counter() - t0) * 1e6
    print(f"serve_naive_no_sharing,{us_naive:.0f},"
          f"speedup={us_naive/us_pim:.2f}x", file=out)
    assert res[0] == res2[0]

    # ---- table 2: fused single-dispatch decode round vs eager loop ----- #
    dec = dict(n_reqs=(2 if smoke else 4), prompt_len=16,
               new_tokens=(8 if smoke else 16), page_size=4)
    fstats = _decode_throughput(cfg, params, rng, fused=True, **dec)
    estats = _decode_throughput(cfg, params, rng, fused=False, **dec)
    speedup = fstats["tok_s"] / estats["tok_s"]
    print(f"decode_fused,{1e6/max(fstats['tok_s'],1e-9):.0f},"
          f"tok_s={fstats['tok_s']:.1f}"
          f";dispatches_per_round={fstats['dispatches_per_round']:.1f}"
          f";jit_traces={fstats['jit_traces']}", file=out)
    print(f"decode_eager,{1e6/max(estats['tok_s'],1e-9):.0f},"
          f"tok_s={estats['tok_s']:.1f}"
          f";dispatches_per_round={estats['dispatches_per_round']:.1f}",
          file=out)
    print(f"decode_fusion_speedup,0,{speedup:.2f}x", file=out)

    # ---- table 3: fused bucketed prefill vs eager per-request path ----- #
    pre = dict(n_reqs=(2 if smoke else 4), lengths=(16, 32), page_size=4)
    pstats = _prefill_throughput(cfg, params, rng, fused_prefill=True, **pre)
    qstats = _prefill_throughput(cfg, params, rng, fused_prefill=False, **pre)
    pspeed = pstats["tok_s"] / qstats["tok_s"]
    print(f"prefill_fused,{1e6/max(pstats['tok_s'],1e-9):.0f},"
          f"tok_s={pstats['tok_s']:.1f};ttft_ms={pstats['ttft_ms']:.1f}"
          f";jit_traces={pstats['prefill_jit_traces']}", file=out)
    print(f"prefill_eager,{1e6/max(qstats['tok_s'],1e-9):.0f},"
          f"tok_s={qstats['tok_s']:.1f};ttft_ms={qstats['ttft_ms']:.1f}",
          file=out)
    print(f"prefill_fusion_speedup,0,{pspeed:.2f}x", file=out)

    # ---- table 4: chunked prefill under long-prompt mixed traffic ------ #
    # full config: the long prompt must be long enough that one chunk
    # round (O(long*chunk) attention) clearly beats the monolithic
    # prefill round (O(long^2)) — below ~1k tokens the per-chunk gather
    # overhead and round-time noise can invert the p99 comparison on
    # CPU; decode_new must exceed long_len/chunk so the short requests
    # are still decoding while every chunk streams through
    mix = dict(n_decode=(2 if smoke else 3),
               decode_new=(12 if smoke else 40),
               long_len=(64 if smoke else 1024), page_size=8)
    chunk_size = 16 if smoke else 32
    cstats = _mixed_long_prompt(cfg, params, rng, chunk=chunk_size, **mix)
    mstats = _mixed_long_prompt(cfg, params, rng, chunk=None, **mix)
    itl_ratio = mstats["decode_itl_p99_ms"] / max(cstats["decode_itl_p99_ms"],
                                                  1e-9)
    print(f"mixed_chunked,0,ttft_long_ms={cstats['ttft_long_ms']:.1f}"
          f";itl_p99_ms={cstats['decode_itl_p99_ms']:.2f}"
          f";chunks={cstats['prefill_chunks_per_rep']}", file=out)
    print(f"mixed_monolithic,0,ttft_long_ms={mstats['ttft_long_ms']:.1f}"
          f";itl_p99_ms={mstats['decode_itl_p99_ms']:.2f}", file=out)
    print(f"mixed_itl_p99_improvement,0,{itl_ratio:.2f}x", file=out)

    # ---- table 5: multi-round decode blocking, dispatches/token vs K --- #
    blk = dict(ks=(1, 4, 8), n_reqs=(2 if smoke else 4), prompt_len=8,
               new_tokens=(16 if smoke else 32), page_size=4)
    bstats = _block_decode_sweep(cfg, params, rng, **blk)
    for key, s in bstats.items():
        print(f"decode_block_{key},{1e6/max(s['tok_s'],1e-9):.0f},"
              f"tok_s={s['tok_s']:.1f}"
              f";dispatches_per_token={s['dispatches_per_token']:.3f}"
              f";multi_round_blocks={s['multi_round_blocks']}", file=out)
    blk_ratio = (bstats["K1"]["dispatches_per_token"]
                 / max(bstats["K8"]["dispatches_per_token"], 1e-9))
    print(f"decode_block_dispatch_reduction,0,{blk_ratio:.2f}x", file=out)

    # ---- table 6: tensor-parallel mesh x logit-collective sweep -------- #
    mrows = _mesh_table(smoke)
    for key, row in mrows.items():
        if "decode_tok_s" in row:
            print(f"sharded_{key},{1e6/max(row['decode_tok_s'],1e-9):.0f},"
                  f"tok_s={row['decode_tok_s']:.1f}"
                  f";ttft_ms={row['ttft_ms']:.1f}"
                  f";dispatches_per_round={row['dispatches_per_round']:.1f}",
                  file=out)
        else:
            note = row.get("skipped", row.get("error", ""))
            print(f"sharded_{key},0,skipped={note}", file=out)

    # ---- table 7: open-loop Poisson sweep, goodput under SLO ----------- #
    orows = _open_loop_table(cfg, params, smoke=smoke)
    for key, row in orows["rates"].items():
        print(f"open_loop_{key},0,goodput_rps={row['goodput_rps']:.2f}"
              f";rejected={row['rejected']}/{row['requests']}"
              f";ttft_p99_ms={row['ttft_p99_ms'] or float('nan'):.1f}"
              f";prefix_hit_rate={row['prefix_hit_rate']:.3f}"
              f";prefix_rowclone_speedup="
              f"{row['replay_speedup']['prefix'] or float('nan'):.1f}x",
              file=out)

    # ---- table 8: Ambit zero-compare + timed-face replay totals -------- #
    arows = _ambit_table(cfg, params, smoke=smoke)
    z = arows["zero_scan"]
    print(f"ambit_zero_scan,0,"
          f"init_skips_zero={z['init_skips_zero']}"
          f";audit_pages={z['zero_audit_pages']}"
          f";audit_failures={z['zero_audit_failures']}"
          f";scan_launches={arows['scan_launches']}", file=out)
    e2e = arows["speedup"]["end_to_end"] or float("nan")
    zsc = arows["speedup"]["zero_scan"] or float("nan")
    print(f"ambit_replay_totals,0,"
          f"pim_total_ns={arows['pim_ns']['total']:.0f}"
          f";cpu_total_ns={arows['cpu_ns']['total']:.0f}"
          f";end_to_end={e2e:.2f}x;zero_scan={zsc:.2f}x"
          f";refreshes={arows['device_stats']['refreshes']}", file=out)

    # ---- table 9: jamba-style hybrid long-prompt serving --------------- #
    hrows = _hybrid_long_prompt(rng, smoke=smoke)
    print(f"hybrid_long_prompt,0,tok_s={hrows['tok_s']:.1f}"
          f";long_len={hrows['config']['long_len']}"
          f";dispatches_per_round={hrows['dispatches_per_round']:.1f}"
          f";prefill_chunks={hrows['prefill_chunks']}", file=out)
    hsp = hrows["replay_speedup"]
    print(f"hybrid_state_replay,0,"
          f"state_copy={(hsp['state_copy'] or float('nan')):.1f}x"
          f";state_init={(hsp['state_init'] or float('nan')):.1f}x"
          f";state_write_cpu_ns="
          f"{hrows['state_replay_ns']['state_write_cpu']:.0f}", file=out)

    bench = {
        "config": {"arch": "granite-3-8b (reduced)", "smoke": smoke, **dec,
                   "prefill": pre},
        "decode_tok_s_fused": round(fstats["tok_s"], 2),
        "decode_tok_s_eager": round(estats["tok_s"], 2),
        "decode_fusion_speedup": round(speedup, 2),
        "dispatches_per_round_fused": fstats["dispatches_per_round"],
        "dispatches_per_round_eager": estats["dispatches_per_round"],
        # opcode-level dispatch accounting per probed round (pimolib v2:
        # PimOpQueue.launches_by_kind is the one source of truth)
        "launches_by_kind_per_round_fused": fstats["launches_by_kind_per_round"],
        "launches_by_kind_per_round_eager": estats["launches_by_kind_per_round"],
        "jit_traces_fused": fstats["jit_traces"],
        "decoded_tokens": fstats["decoded_tokens"],
        # fused bucketed prefill vs the eager per-request oracle
        "prefill_tok_s_fused": round(pstats["tok_s"], 2),
        "prefill_tok_s_eager": round(qstats["tok_s"], 2),
        "prefill_fusion_speedup": round(pspeed, 2),
        "prefill_ttft_ms_fused": round(pstats["ttft_ms"], 3),
        "prefill_ttft_ms_eager": round(qstats["ttft_ms"], 3),
        "prefill_launches_by_kind_fused": pstats["launches_by_kind"],
        "prefill_launches_by_kind_eager": qstats["launches_by_kind"],
        "prefill_jit_traces_fused": pstats["prefill_jit_traces"],
        "prefill_tokens": pstats["prefill_tokens"],
        # table 4: long-prompt mixed traffic, chunked vs monolithic
        "mixed_config": {**mix, "max_prefill_chunk": chunk_size},
        "mixed_chunked": cstats,
        "mixed_monolithic": mstats,
        "mixed_itl_p99_improvement": round(itl_ratio, 2),
        # table 5: multi-round decode blocking (decode_block_rounds=K)
        "block_decode_config": {k: v for k, v in blk.items() if k != "ks"},
        "block_decode_sweep": bstats,
        "block_decode_dispatch_reduction": round(blk_ratio, 2),
        # table 6: tensor-parallel mesh x collective sweep (mesh>1 cells
        # record a skip note on hosts below 4 cores)
        "mesh_sweep": mrows,
        # table 7: open-loop Poisson sweep through the async server —
        # goodput under SLO, prefix-cache hit rate, replayed RowClone
        # savings per arrival rate
        "open_loop_sweep": orows,
        # table 8: Ambit zero-compare consumer + cycle-accurate replay
        # (tRAS-corrected + refresh-inclusive PiM totals vs all-CPU)
        "ambit_zero_scan": arows,
        # table 9: jamba-style hybrid long-prompt serving — one dispatch
        # per hybrid decode round, state-arena RowClone replay savings
        "hybrid_serving": hrows,
    }
    path = BENCH_JSON_SMOKE if smoke else BENCH_JSON
    with open(path, "w") as f:
        json.dump(bench, f, indent=2)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)

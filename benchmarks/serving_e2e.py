"""End-to-end serving comparison (paper's system-level claim, transposed
to the TPU framework): RowClone-backed paged KV management (CoW fork +
prefix sharing + pim_init page recycling) vs a naive engine that
re-prefills shared prefixes and copies caches through compute.

Metric: modeled data-movement bytes through the compute units + measured
engine statistics.  Mirrors the paper's copy/init table at the system
level (Table: serving with in-memory page ops)."""

from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request


def main(out=sys.stdout):
    print("name,us_per_call,derived", file=out)
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    N, NEW, PS = 6, 4, 8

    # shared-prefix workload WITH pim page ops
    t0 = time.perf_counter()
    eng = PagedEngine(cfg, params, page_size=PS, num_pages=128)
    for i in range(N):
        eng.submit(Request(i, prompt, max_new_tokens=NEW, temperature=0.0,
                           share_with=0 if i else None,
                           shared_len=(len(prompt) // PS) * PS if i else 0))
    res = eng.run()
    us_pim = (time.perf_counter() - t0) * 1e6
    kv_bytes_per_tok = (cfg.num_layers * 2 * cfg.num_kv_heads
                        * cfg.resolved_head_dim * 2)
    shared_toks = (len(prompt) // PS) * PS * (N - 1)
    saved = shared_toks * kv_bytes_per_tok
    print(f"serve_pim_prefix_sharing,{us_pim:.0f},"
          f"prefill_kv_bytes_saved={saved}", file=out)
    print(f"serve_pim_stats,0,prefix_hits={eng.cache.stats['prefix_hits']}"
          f";cow={eng.cache.stats['cow_copies']}"
          f";zeroed={eng.cache.stats['pages_zeroed']}", file=out)

    # naive: every request prefills its full prompt (no sharing)
    t0 = time.perf_counter()
    eng2 = PagedEngine(cfg, params, page_size=PS, num_pages=128)
    for i in range(N):
        eng2.submit(Request(i, prompt, max_new_tokens=NEW, temperature=0.0))
    res2 = eng2.run()
    us_naive = (time.perf_counter() - t0) * 1e6
    print(f"serve_naive_no_sharing,{us_naive:.0f},"
          f"speedup={us_naive/us_pim:.2f}x", file=out)
    assert res[0] == res2[0]


if __name__ == "__main__":
    main()

"""End-to-end serving comparison (paper's system-level claim, transposed
to the TPU framework), three tables:

1. RowClone-backed paged KV management (CoW fork + prefix sharing +
   pim_init page recycling) vs a naive engine that re-prefills shared
   prefixes — the paper's copy/init table at the system level.

2. Fused single-dispatch decode round (jitted scan-over-layers,
   in-kernel self-token merge, in-jit scatter + sampling) vs the
   pre-fusion eager layer loop: decode tokens/s, kernel dispatches per
   round, and jit retrace counts.

3. Fused bucketed prefill (one jitted dispatch per length-bucket batch,
   length-masked flash attention, in-jit KV scatter) vs the eager
   per-request path (un-jitted ``T.forward`` per prompt): prefill
   tokens/s, time-to-first-token for the batch, and prefill jit traces.

Metrics print as ``name,us_per_call,derived`` CSV and the fusion numbers
are also written to ``BENCH_serving.json`` so CI tracks them per PR.
Pass ``--smoke`` for the CI-sized configuration.
"""

from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import transformer as T
from repro.models.params import init_params
from repro.serving.engine import PagedEngine, Request

# anchored to the repo root so the tracked snapshot updates no matter
# which directory the benchmark runs from; smoke runs write a separate
# file so the CI-sized numbers never overwrite the full-config snapshot
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_serving.json")
BENCH_JSON_SMOKE = os.path.join(_ROOT, "BENCH_serving.smoke.json")


def _decode_throughput(cfg, params, rng, *, fused: bool, n_reqs: int,
                       prompt_len: int, new_tokens: int, page_size: int):
    """Decode tokens/s + dispatches/round for one engine mode.

    Warmup batch first (pays jit traces), then a timed batch on the same
    engine: a dispatch-count probe over two mid-flight rounds, then the
    remaining rounds under the clock (decode only — prefills excluded).
    """
    eng = PagedEngine(cfg, params, page_size=page_size, num_pages=256,
                      fused=fused)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_reqs)]
    for i, p in enumerate(prompts):                       # warmup batch
        eng.submit(Request(i, p, max_new_tokens=new_tokens, temperature=0.0))
    eng.run()
    for i, p in enumerate(prompts):                       # timed batch
        eng.submit(Request(n_reqs + i, p, max_new_tokens=new_tokens,
                           temperature=0.0))
    while eng.queue:
        eng._prefill(eng.queue.pop(0))
    probe_rounds = 2
    base_launch = eng.cache.queue.stats["launches"]
    launches_by_kind = []        # per-round API-level dispatch accounting
    for _ in range(probe_rounds):
        before = dict(eng.cache.queue.launches_by_kind)
        eng._decode_round()
        after = eng.cache.queue.launches_by_kind
        launches_by_kind.append(
            {k: after[k] - before.get(k, 0) for k in after
             if after[k] - before.get(k, 0)})
    dispatches = (eng.cache.queue.stats["launches"] - base_launch) / probe_rounds
    base_tok = eng.stats["tokens_out"]
    t0 = time.perf_counter()
    eng.run()                                             # decode to done
    dt = time.perf_counter() - t0
    decoded = eng.stats["tokens_out"] - base_tok
    return {
        "tok_s": decoded / dt if dt > 0 else float("inf"),
        "decoded_tokens": decoded,
        "dispatches_per_round": dispatches,
        "launches_by_kind_per_round": launches_by_kind,
        "jit_traces": eng.stats["jit_traces"],
    }


def _prefill_throughput(cfg, params, rng, *, fused_prefill: bool,
                        n_reqs: int, lengths, page_size: int):
    """Prefill tokens/s + time-to-first-token for one prefill mode.

    Warmup batch first (the fused path pays one jit trace per distinct
    length bucket), then a timed batch on the same engine: the clock
    covers exactly the prefill round — when it returns, every request
    in the batch has its first token, so the elapsed time IS the
    batch's time-to-first-token.
    """
    eng = PagedEngine(cfg, params, page_size=page_size, num_pages=256,
                      fused_prefill=fused_prefill)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lengths for _ in range(n_reqs)]
    for i, p in enumerate(prompts):                       # warmup batch
        eng.submit(Request(i, p, max_new_tokens=1, temperature=0.0))
    eng.run()
    for i, p in enumerate(prompts):                       # timed batch
        eng.submit(Request(len(prompts) + i, p, max_new_tokens=1,
                           temperature=0.0))
    before = dict(eng.cache.queue.launches_by_kind)
    t0 = time.perf_counter()
    eng._prefill_round()
    ttft = time.perf_counter() - t0
    after = eng.cache.queue.launches_by_kind
    launches = {k: after[k] - before.get(k, 0) for k in after
                if after[k] - before.get(k, 0)}
    toks = sum(len(p) for p in prompts)
    eng.run()                                             # drain
    return {
        "tok_s": toks / ttft if ttft > 0 else float("inf"),
        "ttft_ms": ttft * 1e3,
        "prefill_tokens": toks,
        "launches_by_kind": launches,
        "prefill_jit_traces": eng.stats["prefill_jit_traces"],
    }


def main(out=sys.stdout, smoke: bool = False):
    print("name,us_per_call,derived", file=out)
    cfg = reduced(ARCHS["granite-3-8b"], num_layers=2)
    params = init_params(T.model_defs(cfg), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    N, NEW, PS = (2, 6, 8) if smoke else (6, 4, 8)

    # ---- table 1: shared-prefix workload WITH pim page ops ------------- #
    t0 = time.perf_counter()
    eng = PagedEngine(cfg, params, page_size=PS, num_pages=128)
    for i in range(N):
        eng.submit(Request(i, prompt, max_new_tokens=NEW, temperature=0.0,
                           share_with=0 if i else None,
                           shared_len=(len(prompt) // PS) * PS if i else 0))
    res = eng.run()
    us_pim = (time.perf_counter() - t0) * 1e6
    kv_bytes_per_tok = (cfg.num_layers * 2 * cfg.num_kv_heads
                        * cfg.resolved_head_dim * 2)
    shared_toks = (len(prompt) // PS) * PS * (N - 1)
    saved = shared_toks * kv_bytes_per_tok
    print(f"serve_pim_prefix_sharing,{us_pim:.0f},"
          f"prefill_kv_bytes_saved={saved}", file=out)
    print(f"serve_pim_stats,0,prefix_hits={eng.cache.stats['prefix_hits']}"
          f";cow={eng.cache.stats['cow_copies']}"
          f";zeroed={eng.cache.stats['pages_zeroed']}", file=out)

    # naive: every request prefills its full prompt (no sharing)
    t0 = time.perf_counter()
    eng2 = PagedEngine(cfg, params, page_size=PS, num_pages=128)
    for i in range(N):
        eng2.submit(Request(i, prompt, max_new_tokens=NEW, temperature=0.0))
    res2 = eng2.run()
    us_naive = (time.perf_counter() - t0) * 1e6
    print(f"serve_naive_no_sharing,{us_naive:.0f},"
          f"speedup={us_naive/us_pim:.2f}x", file=out)
    assert res[0] == res2[0]

    # ---- table 2: fused single-dispatch decode round vs eager loop ----- #
    dec = dict(n_reqs=(2 if smoke else 4), prompt_len=16,
               new_tokens=(8 if smoke else 16), page_size=4)
    fstats = _decode_throughput(cfg, params, rng, fused=True, **dec)
    estats = _decode_throughput(cfg, params, rng, fused=False, **dec)
    speedup = fstats["tok_s"] / estats["tok_s"]
    print(f"decode_fused,{1e6/max(fstats['tok_s'],1e-9):.0f},"
          f"tok_s={fstats['tok_s']:.1f}"
          f";dispatches_per_round={fstats['dispatches_per_round']:.1f}"
          f";jit_traces={fstats['jit_traces']}", file=out)
    print(f"decode_eager,{1e6/max(estats['tok_s'],1e-9):.0f},"
          f"tok_s={estats['tok_s']:.1f}"
          f";dispatches_per_round={estats['dispatches_per_round']:.1f}",
          file=out)
    print(f"decode_fusion_speedup,0,{speedup:.2f}x", file=out)

    # ---- table 3: fused bucketed prefill vs eager per-request path ----- #
    pre = dict(n_reqs=(2 if smoke else 4), lengths=(16, 32), page_size=4)
    pstats = _prefill_throughput(cfg, params, rng, fused_prefill=True, **pre)
    qstats = _prefill_throughput(cfg, params, rng, fused_prefill=False, **pre)
    pspeed = pstats["tok_s"] / qstats["tok_s"]
    print(f"prefill_fused,{1e6/max(pstats['tok_s'],1e-9):.0f},"
          f"tok_s={pstats['tok_s']:.1f};ttft_ms={pstats['ttft_ms']:.1f}"
          f";jit_traces={pstats['prefill_jit_traces']}", file=out)
    print(f"prefill_eager,{1e6/max(qstats['tok_s'],1e-9):.0f},"
          f"tok_s={qstats['tok_s']:.1f};ttft_ms={qstats['ttft_ms']:.1f}",
          file=out)
    print(f"prefill_fusion_speedup,0,{pspeed:.2f}x", file=out)

    bench = {
        "config": {"arch": "granite-3-8b (reduced)", "smoke": smoke, **dec,
                   "prefill": pre},
        "decode_tok_s_fused": round(fstats["tok_s"], 2),
        "decode_tok_s_eager": round(estats["tok_s"], 2),
        "decode_fusion_speedup": round(speedup, 2),
        "dispatches_per_round_fused": fstats["dispatches_per_round"],
        "dispatches_per_round_eager": estats["dispatches_per_round"],
        # opcode-level dispatch accounting per probed round (pimolib v2:
        # PimOpQueue.launches_by_kind is the one source of truth)
        "launches_by_kind_per_round_fused": fstats["launches_by_kind_per_round"],
        "launches_by_kind_per_round_eager": estats["launches_by_kind_per_round"],
        "jit_traces_fused": fstats["jit_traces"],
        "decoded_tokens": fstats["decoded_tokens"],
        # fused bucketed prefill vs the eager per-request oracle
        "prefill_tok_s_fused": round(pstats["tok_s"], 2),
        "prefill_tok_s_eager": round(qstats["tok_s"], 2),
        "prefill_fusion_speedup": round(pspeed, 2),
        "prefill_ttft_ms_fused": round(pstats["ttft_ms"], 3),
        "prefill_ttft_ms_eager": round(qstats["ttft_ms"], 3),
        "prefill_launches_by_kind_fused": pstats["launches_by_kind"],
        "prefill_launches_by_kind_eager": qstats["launches_by_kind"],
        "prefill_jit_traces_fused": pstats["prefill_jit_traces"],
        "prefill_tokens": pstats["prefill_tokens"],
    }
    path = BENCH_JSON_SMOKE if smoke else BENCH_JSON
    with open(path, "w") as f:
        json.dump(bench, f, indent=2)
    print(f"# wrote {path}", file=out)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv)

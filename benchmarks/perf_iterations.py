"""§Perf hillclimbing harness: hypothesis -> change -> re-lower -> measure.

Each iteration re-runs a dry-run cell with a config variant
(`parallel_overrides`) and reports the roofline-term deltas vs the
stored baseline artifact.  Results land in experiments/perf/ with the
variant tag; the narrative log lives in EXPERIMENTS.md §Perf.

Run a single iteration:
  PYTHONPATH=src python -m benchmarks.perf_iterations \
      --arch granite-3-8b --shape decode_32k --variant kv_fp8

Variants are declared in VARIANTS below — each is (overrides, hypothesis).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
PERF = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")

VARIANTS = {
    # decode: KV cache in fp8 -> cache traffic (the dominant memory term
    # of decode) halves; collective term unchanged.
    "kv_fp8": (dict(kv_cache_dtype="float8_e4m3fn"),
               "halve decode memory term via fp8 KV cache"),
    # train: remat 'dots' keeps matmul outputs -> removes the fwd
    # recompute from the backward (flops -1/3) at higher live memory.
    "remat_dots": (dict(remat="dots"),
                   "cut compute term ~25-33% by saving matmul outputs"),
    "remat_none": (dict(remat="none"),
                   "no remat: lowest flops, highest memory (bound check)"),
    # attention tile size: diagonal-tile waste ~ c/(2s) of attention flops
    "attn_chunk_2048": (dict(attention_chunk=2048),
                        "smaller causal tiles -> less masked-tile waste"),
    # logits head in bf16 halves head bytes (quality note in EXPERIMENTS)
    "logits_bf16": (dict(logits_fp32=False),
                    "halve LM-head bytes (memory term) via bf16 logits"),
    # MoE: tighter capacity cuts expert GEMM volume proportionally
    "moe_cap_1_0": (dict(moe_capacity_factor=1.0),
                    "cut expert GEMM volume 20% via capacity factor 1.0"),
    # MoE EP combine in bf16: halves the dominant per-layer psum bytes
    "moe_psum_bf16": (dict(moe_psum_dtype="bfloat16"),
                      "halve MoE combine collective bytes via bf16 psum"),
    # combined best-of variants
    "combo_decode": (dict(kv_cache_dtype="float8_e4m3fn", logits_fp32=False),
                     "fp8 KV + bf16 logits: compound memory-term win"),
    "combo_moe_train": (dict(moe_psum_dtype="bfloat16", moe_capacity_factor=1.0,
                             remat="dots"),
                        "bf16 psum + capacity 1.0 + dots remat"),
    # no FSDP (pure TP + replicated params): kills per-layer all-gathers,
    # pays replicated-param memory (collective-term experiment)
    "no_fsdp": (dict(fsdp=False),
                "remove FSDP weight all-gathers -> collective term drops"),
    "mb1": (dict(microbatches=1), "single microbatch (memory experiment)"),
    # serving layout: params TP-only (replicated over data) — decode must
    # not re-all-gather FSDP weight shards every token
    "combo_serve": (dict(fsdp=False, kv_cache_dtype="float8_e4m3fn",
                         logits_fp32=False),
                    "TP-only serving layout + fp8 KV + bf16 logits"),
    # row-parallel attention: TP-shard the d_model dim when head counts
    # don't divide the axis (kills weight replication without FSDP)
    "rp_attn_serve": (dict(fsdp=False, row_parallel_attn=True),
                      "TP-only + row-parallel attn: no replication, no gathers"),
    "rp_combo_serve": (dict(fsdp=False, row_parallel_attn=True,
                            kv_cache_dtype="float8_e4m3fn", logits_fp32=False),
                       "row-parallel TP serving + fp8 KV + bf16 logits"),
}


def load_baseline(arch, shape, mesh="16x16"):
    p = os.path.join(ART, f"{arch}__{shape}__{mesh}.json")
    return json.load(open(p))


def compare(base, new):
    rows = []
    b, n = base["roofline"], new["roofline"]
    for k in ("t_compute_s", "t_memory_s", "t_collective_s"):
        delta = (n[k] - b[k]) / b[k] if b[k] else 0.0
        rows.append((k, b[k], n[k], delta))
    rows.append(("roofline_fraction", b["roofline_fraction"],
                 n["roofline_fraction"],
                 (n["roofline_fraction"] - b["roofline_fraction"])
                 / max(b["roofline_fraction"], 1e-12)))
    rows.append(("bottleneck", b["bottleneck"], n["bottleneck"], ""))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    args = ap.parse_args()

    from repro.launch.dryrun import run_cell  # sets XLA_FLAGS first
    overrides, hypothesis = VARIANTS[args.variant]
    base = load_baseline(args.arch, args.shape)
    rec = run_cell(args.arch, args.shape, multi_pod=False, out_dir=PERF,
                   parallel_overrides=overrides, tag=f"__{args.variant}")
    print(f"# hypothesis: {hypothesis}")
    print("metric,baseline,variant,delta")
    for k, b, n, d in compare(base, rec):
        if isinstance(d, float):
            print(f"{k},{b:.6g},{n:.6g},{d:+.1%}")
        else:
            print(f"{k},{b},{n},")
    print(f"peak_bytes,{base['memory']['peak_bytes_per_device']},"
          f"{rec['memory']['peak_bytes_per_device']},")


if __name__ == "__main__":
    main()

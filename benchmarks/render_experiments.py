"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts."""

from __future__ import annotations

import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def load_all():
    cells = {}
    for f in glob.glob(os.path.join(ART, "*.json")):
        d = json.load(open(f))
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def dryrun_table(cells):
    lines = ["| arch | shape | mesh | compile (s) | params | peak GiB/dev (HLO-CPU) | analytic GiB/dev | fits | collectives (ag/ar/rs/a2a/cp) |",
             "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh) in sorted(cells):
        d = cells[(arch, shape, mesh)]
        m = d["memory"]
        c = d.get("collectives", {})
        cc = (f"{c.get('all-gather',0)/2**30:.2f}/{c.get('all-reduce',0)/2**30:.2f}/"
              f"{c.get('reduce-scatter',0)/2**30:.2f}/{c.get('all-to-all',0)/2**30:.2f}/"
              f"{c.get('collective-permute',0)/2**30:.2f} GiB")
        lines.append(
            f"| {arch} | {shape} | {mesh} | {d['compile_s']:.0f} | "
            f"{d['params']/1e9:.1f}B | {fmt_bytes(m['peak_bytes_per_device'])} | "
            f"{fmt_bytes(m['analytic_bytes_per_device'])} | "
            f"{'Y' if m['fits_16GiB_analytic'] else 'N'} | {cc} |")
    return "\n".join(lines)


def roofline_table(cells):
    lines = ["| arch | shape | t_compute | t_memory | t_collective | bound | useful frac | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, mesh) in sorted(cells):
        if mesh != "16x16":
            continue
        d = cells[(arch, shape, mesh)]
        r = d.get("roofline")
        if not r:
            continue
        lines.append(
            f"| {arch} | {shape} | {r['t_compute_s']:.3g}s | {r['t_memory_s']:.3g}s | "
            f"{r['t_collective_s']:.3g}s | **{r['bottleneck']}** | "
            f"{r['useful_fraction']:.3f} | {r['roofline_fraction']:.4f} |")
    return "\n".join(lines)


def interesting(cells):
    """Pick hillclimb candidates: worst roofline frac, most collective-
    bound, most paper-representative (decode w/ KV paging)."""
    rows = []
    for (arch, shape, mesh), d in cells.items():
        if mesh != "16x16" or "roofline" not in d:
            continue
        r = d["roofline"]
        t = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        rows.append({
            "cell": f"{arch}/{shape}",
            "frac": r["roofline_fraction"],
            "coll_share": r["t_collective_s"] / t if t else 0,
            "bottleneck": r["bottleneck"],
        })
    rows.sort(key=lambda x: x["frac"])
    print("\nworst roofline fraction:")
    for r in rows[:5]:
        print("  ", r)
    rows.sort(key=lambda x: -x["coll_share"])
    print("most collective-bound:")
    for r in rows[:5]:
        print("  ", r)


if __name__ == "__main__":
    cells = load_all()
    print(f"{len(cells)} artifacts\n")
    print("### Dry-run table\n")
    print(dryrun_table(cells))
    print("\n### Roofline table (single-pod)\n")
    print(roofline_table(cells))
    interesting(cells)

"""Reproduction of the paper's quantitative results (PiDRAM §5).

Table 1 — RowClone end-to-end speedups over CPU copy (memcpy) and
initialization (calloc), with and without cache-coherence maintenance.
Table 2 — D-RaNGe latency / sustained throughput.

All numbers are computed forward from the memory-controller timing model
of the FPGA prototype (Rocket @ 50 MHz, DDR3-800; repro.core.timing) and
cross-checked against functional execution on the simulated DRAM device.
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core import (DRAMGeometry, DRangeTRNG, DeviceLib, EndToEndCosts,
                        MemoryController, PimOpsController, SimulatedDRAM,
                        allocator_from_subarray_map, characterize,
                        discover_subarrays)

PAPER = {
    "copy_no_coherence": 118.5,
    "init_no_coherence": 88.7,
    "copy_coherence": 14.6,
    "init_coherence": 12.6,
    "drange_latency_ns": 220.0,
    "drange_throughput_mbps": 8.30,
}


def rowclone_table():
    dev = SimulatedDRAM(DRAMGeometry(num_subarrays=8, rows_per_subarray=32))
    mc = MemoryController(dev)
    costs = EndToEndCosts(mc)
    rows = []
    sp = costs.speedups()
    for k in ("copy_no_coherence", "init_no_coherence",
              "copy_coherence", "init_coherence"):
        rows.append((k, sp[k], PAPER[k], abs(sp[k] - PAPER[k]) / PAPER[k]))
    return rows, costs


def drange_table():
    dev = SimulatedDRAM(DRAMGeometry(num_subarrays=8, rows_per_subarray=32))
    mc = MemoryController(dev)
    costs = EndToEndCosts(mc)
    rows = [
        ("drange_latency_ns", costs.drange_latency_ns(), PAPER["drange_latency_ns"]),
        ("drange_throughput_mbps", costs.drange_throughput_mbps(),
         PAPER["drange_throughput_mbps"]),
    ]
    # functional cross-check: the TRNG actually produces balanced bits
    poc = PimOpsController(mc)
    cmap = characterize(mc, rows=list(range(24)), n_bits=1024, samples=60)
    trng = DRangeTRNG(poc, cmap)
    bits = trng.random_bits(2048)
    rows.append(("drange_ones_fraction", float(bits.mean()), 0.5))
    return rows


def functional_check():
    """RowClone actually moves the data (same subarray) on the device."""
    dev = SimulatedDRAM(DRAMGeometry(num_subarrays=4, rows_per_subarray=16))
    mc = MemoryController(dev)
    smap = discover_subarrays(mc, max_rows=32)
    alloc = allocator_from_subarray_map(smap)
    lib = DeviceLib(PimOpsController(mc), alloc)
    src, dst = alloc.alloc_copy_pair(1)
    pat = np.random.default_rng(0).integers(0, 256, dev.geometry.row_bytes,
                                            dtype=np.uint8)
    dev.write_row(src.rows[0], pat)
    rec = lib.copy(src, dst)
    ok = rec.ok and (dev.read_row(dst.rows[0]) == pat).all()
    return ok, smap.num_groups, smap.trials


def main(out=sys.stdout):
    print("name,value,paper,rel_err", file=out)
    rows, _ = rowclone_table()
    worst = 0.0
    for k, v, p, e in rows:
        worst = max(worst, e)
        print(f"rowclone_{k},{v:.2f},{p},{e:.4f}", file=out)
    for item in drange_table():
        k, v, p = item
        e = abs(v - p) / p if p else 0.0
        print(f"{k},{v:.3f},{p},{e:.4f}", file=out)
    ok, groups, trials = functional_check()
    print(f"functional_rowclone_ok,{int(ok)},1,0", file=out)
    print(f"subarray_groups_discovered,{groups},4,0", file=out)
    print(f"subarray_discovery_trials,{trials},,", file=out)
    assert worst < 0.10, f"paper-number reproduction off by {worst:.1%}"


if __name__ == "__main__":
    main()

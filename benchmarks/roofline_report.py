"""Render the §Roofline table from the dry-run artifacts
(experiments/dryrun/*.json).  One row per (arch x shape), single-pod."""

from __future__ import annotations

import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load(mesh="16x16", out_dir=ART):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        d = json.load(open(f))
        if "roofline" not in d:
            continue
        rows.append(d)
    return rows


def main(out=sys.stdout, markdown=False):
    rows = load()
    if markdown:
        print("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
              "bottleneck | useful | roofline | fits(analytic) |", file=out)
        print("|---|---|---|---|---|---|---|---|---|", file=out)
    else:
        print("name,us_per_call,derived", file=out)
    for d in rows:
        r = d["roofline"]
        m = d["memory"]
        if markdown:
            print(f"| {d['arch']} | {d['shape']} | {r['t_compute_s']:.3g} | "
                  f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
                  f"{r['bottleneck']} | {r['useful_fraction']:.3f} | "
                  f"{r['roofline_fraction']:.4f} | "
                  f"{m.get('fits_16GiB_analytic')} |", file=out)
        else:
            t_us = r['t_compute_s'] * 1e6
            print(f"roofline_{d['arch']}__{d['shape']},{t_us:.0f},"
                  f"bottleneck={r['bottleneck']}"
                  f";roofline_frac={r['roofline_fraction']:.4f}"
                  f";useful={r['useful_fraction']:.3f}", file=out)


if __name__ == "__main__":
    main(markdown="--md" in sys.argv)
